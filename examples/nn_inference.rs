//! END-TO-END VALIDATION (E12): MicroNet inference through the full
//! stack — build-time-trained weights (JAX, `make artifacts`), every
//! multiplication executed in-memory on the crossbar simulator (Q8.8
//! MultPIM batches across rows), soft errors injected in the gate
//! stream, reliability policies compared. Reports accuracy vs p_gate for
//! baseline / TMR, the in-simulator analogue of the paper's Fig. 4
//! bottom, and cross-checks the PJRT (AOT JAX/Pallas) forward pass.
//!
//! ```bash
//! make artifacts && cargo run --release --example nn_inference -- --samples 48
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §E12.

use anyhow::Result;
use remus::errs::ErrorModel;
use remus::mmpu::{Mmpu, MmpuConfig, ReliabilityPolicy};
use remus::nn::micronet::{EvalSet, MicroNet};
use remus::runtime::{Manifest, Runtime};
use remus::tmr::TmrMode;
use remus::util::cli::Args;
use remus::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let samples = args.get_or("samples", 48usize);

    let manifest = Manifest::load_default()?;
    let net = MicroNet::load(&manifest)?;
    let eval = EvalSet::load(&manifest)?.take(samples);
    println!(
        "MicroNet {}-{}-{} trained at build time; evaluating {} held-out samples\n",
        net.indim, net.hidden, net.classes, eval.n
    );

    // Float reference.
    let ref_logits = net.forward_f32(&eval.x, eval.n);
    let ref_acc = net.accuracy(&ref_logits, &eval.labels);
    println!("float32 reference accuracy: {:.1}%", 100.0 * ref_acc);

    // PJRT (AOT JAX/Pallas) cross-check with identity fault masks.
    if eval.n <= 64 {
        let mut rt = Runtime::new()?;
        let batch = 64;
        let mut x = eval.x.clone();
        x.resize(batch * net.indim, 0.0);
        let ones1 = vec![1f32; net.indim * net.hidden];
        let zeros1 = vec![0f32; net.indim * net.hidden];
        let ones2 = vec![1f32; net.hidden * net.classes];
        let zeros2 = vec![0f32; net.hidden * net.classes];
        let logits = rt.run_micronet(
            batch, &x, &net.w1, &net.b1, &net.w2, &net.b2, &ones1, &zeros1, &ones2, &zeros2,
        )?;
        let acc = net.accuracy(&logits[..eval.n * net.classes], &eval.labels);
        println!("PJRT (AOT Pallas) accuracy:  {:.1}%  (platform: {})", 100.0 * acc, rt.platform());
    }

    // The full in-memory path across p_gate and policies.
    let mut t = Table::new(
        "in-memory inference accuracy (every multiply on the crossbar)",
        &["p_gate", "baseline", "serial TMR"],
    );
    for &p in &[0.0, 1e-6, 1e-5, 1e-4] {
        let mut row = vec![if p == 0.0 { "0".into() } else { format!("{p:.0e}") }];
        for tmr in [TmrMode::Off, TmrMode::Serial] {
            let mut mmpu = Mmpu::new(MmpuConfig {
                rows: 128,
                cols: 2048,
                num_crossbars: 1,
                policy: ReliabilityPolicy { ecc_m: None, tmr },
                errors: if p == 0.0 { ErrorModel::none() } else { ErrorModel::direct_only(p) },
                seed: 0xE2E,
            });
            let logits = net.forward_mmpu(&mut mmpu, &eval.x, eval.n)?;
            let acc = net.accuracy(&logits, &eval.labels);
            row.push(format!("{:.1}%", 100.0 * acc));
        }
        t.row(&row);
    }
    t.print();
    println!(
        "\nshape check (paper Fig. 4 bottom): baseline accuracy collapses with p_gate;\n\
         TMR holds it at/near the clean accuracy until far higher error rates."
    );
    Ok(())
}
