//! Fig. 3 live: the same single-row function across all crossbar rows,
//! executed unreliably (a), with serial TMR (b), and with parallel TMR
//! (c), under an aggressive gate-error rate so failures are visible.
//! Also demonstrates the ECC scrub loop repairing retention damage.
//!
//! ```bash
//! cargo run --release --example reliable_vector_mult -- --p-gate 5e-5
//! ```

use anyhow::Result;
use remus::ecc::DiagonalEcc;
use remus::errs::{ErrorModel, Injector};
use remus::mmpu::{controller::quick_exec, FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;
use remus::util::bitmat::BitMatrix;
use remus::util::cli::Args;
use remus::util::rng::Pcg64;
use remus::util::table::Table;

fn main() -> Result<()> {
    let args = Args::from_env();
    let p_gate = args.get_or("p-gate", 5e-5);
    let items = args.get_or("items", 48usize);
    let trials = args.get_or("trials", 8u64);

    let a: Vec<u64> = (0..items as u64).map(|i| (i * 37) % 65536).collect();
    let b: Vec<u64> = (0..items as u64).map(|i| (i * 91 + 5) % 65536).collect();

    println!("16-bit vector multiplication, {items} elements, p_gate = {p_gate}\n");
    let mut t = Table::new(
        "Fig 3: unreliable baseline vs TMR strategies",
        &["mode", "wrong/total", "compute_cycles", "area_cols"],
    );
    for (name, tmr) in [
        ("(a) unreliable", TmrMode::Off),
        ("(b) serial TMR", TmrMode::Serial),
        ("(c) semi-parallel TMR", TmrMode::SemiParallel),
    ] {
        let mut wrong = 0usize;
        let mut cycles = 0;
        for seed in 0..trials {
            let r = quick_exec(
                FunctionKind::Mul(16),
                ReliabilityPolicy { ecc_m: None, tmr },
                ErrorModel::direct_only(p_gate),
                seed,
                &a,
                &b,
            )?;
            wrong += r
                .values
                .iter()
                .zip(a.iter().zip(&b))
                .filter(|(&v, (&x, &y))| v != x * y)
                .count();
            cycles = r.compute_cycles;
        }
        t.row(&[
            name.into(),
            format!("{wrong}/{}", items as u64 * trials),
            cycles.to_string(),
            "-".into(),
        ]);
    }
    t.print();

    // --- ECC scrub demo (indirect errors) -----------------------------
    println!("\nECC scrub loop under retention drift (64x64 array, m=16):");
    let n = 64;
    let mut rng = Pcg64::new(3, 0);
    let golden = BitMatrix::from_fn(n, n, |_, _| rng.bernoulli(0.5));
    let mut state = golden.clone();
    let mut ecc = DiagonalEcc::new(n, n, 16);
    ecc.encode(&state);
    let mut inj = Injector::new(
        ErrorModel { lambda_retention: 1e-6, ..ErrorModel::none() },
        11,
        0,
    );
    for epoch in 1..=5 {
        inj.retention(n * n, 1000.0, |i| state.flip(i / n, i % n));
        let before: usize = (0..n)
            .flat_map(|r| (0..n).map(move |c| (r, c)))
            .filter(|&(r, c)| state.get(r, c) != golden.get(r, c))
            .count();
        let out = ecc.correct(&mut state);
        let after: usize = (0..n)
            .flat_map(|r| (0..n).map(move |c| (r, c)))
            .filter(|&(r, c)| state.get(r, c) != golden.get(r, c))
            .count();
        println!(
            "  epoch {epoch}: {before} flipped -> scrub corrected {} (uncorrectable blocks: {}) -> {after} remain",
            out.corrected_bits.len(),
            out.uncorrectable_blocks.len()
        );
    }
    Ok(())
}
