//! Quickstart: build an mMPU, run reliable in-memory arithmetic.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks through the library's layers: a raw crossbar gate (Fig. 1), a
//! synthesized vector multiplication, soft-error injection, and the two
//! reliability mechanisms (diagonal ECC + serial TMR) fixing what the
//! errors break.

use anyhow::Result;
use remus::errs::ErrorModel;
use remus::isa::microop::MicroOp;
use remus::isa::program::Step;
use remus::mmpu::{controller::quick_exec, FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;
use remus::xbar::{Crossbar, Gate};

fn main() -> Result<()> {
    // --- 1. stateful logic on a raw crossbar (paper Fig. 1a) ---------
    println!("== 1. row-parallel MAGIC NOR on a 1024x64 crossbar ==");
    let mut x = Crossbar::new(1024, 64);
    for r in 0..1024 {
        x.state_mut().set(r, 0, r % 2 == 0);
        x.state_mut().set(r, 1, r % 3 == 0);
    }
    x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2)), None)?;
    println!(
        "   1024 NOR gates in {} cycle(s); energy {:.1} pJ",
        x.stats.cycles, x.stats.energy_pj
    );

    // --- 2. vectored 16-bit multiplication, no errors -----------------
    println!("\n== 2. in-memory vector multiply (MultPIM-style, partitions) ==");
    let a: Vec<u64> = (1..=8).collect();
    let b: Vec<u64> = (1..=8).map(|i| 1000 + i).collect();
    let clean = quick_exec(
        FunctionKind::Mul(16),
        ReliabilityPolicy::none(),
        ErrorModel::none(),
        1,
        &a,
        &b,
    )?;
    println!("   {:?} (x) {:?}", a, b);
    println!("   = {:?} in {} crossbar cycles", clean.values, clean.compute_cycles);
    assert!(clean.values.iter().zip(a.iter().zip(&b)).all(|(&v, (&x, &y))| v == x * y));

    // --- 3. what soft errors do to it ---------------------------------
    println!("\n== 3. direct soft errors at p_gate = 1e-4 (unprotected) ==");
    let noisy = quick_exec(
        FunctionKind::Mul(16),
        ReliabilityPolicy::none(),
        ErrorModel::direct_only(1e-4),
        7,
        &a,
        &b,
    )?;
    let wrong = noisy.values.iter().zip(a.iter().zip(&b)).filter(|(&v, (&x, &y))| v != x * y).count();
    println!("   {wrong}/8 products corrupted: {:?}", noisy.values);

    // --- 4. the paper's fix: TMR + diagonal ECC ------------------------
    println!("\n== 4. serial TMR + diagonal ECC at the same p_gate ==");
    let safe = quick_exec(
        FunctionKind::Mul(16),
        ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Serial },
        ErrorModel::direct_only(1e-4),
        4,
        &a,
        &b,
    )?;
    let wrong = safe.values.iter().zip(a.iter().zip(&b)).filter(|(&v, (&x, &y))| v != x * y).count();
    println!("   {wrong}/8 products corrupted after per-bit Minority3 voting");
    println!(
        "   cost: {} compute cycles (~3x) + {} ECC extension cycles",
        safe.compute_cycles, safe.ecc_cycles
    );
    println!("\nNext: examples/reliable_vector_mult.rs, examples/nn_inference.rs, cargo bench");
    Ok(())
}
