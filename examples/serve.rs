//! Serving demo: the coordinator under a synthetic open-loop load —
//! mixed request kinds, dynamic batching, least-loaded routing, latency
//! percentiles, with and without reliability on the request path.
//!
//! The load generator is [`Submitter`]-generic: pass `--shards
//! host:port,host:port` (endpoints running `remus fabric-serve`) and the
//! same load drives a sharded fabric fleet through the consistent-hash
//! router instead of an in-process coordinator.
//!
//! ```bash
//! cargo run --release --example serve -- --requests 8192 --workers 4
//! cargo run --release --example serve -- --shards 127.0.0.1:4870,127.0.0.1:4871
//! # registration-based discovery: no static shard list, shards
//! # announce themselves (remus fabric-serve --register <printed addr>)
//! cargo run --release --example serve -- --listen-reg 127.0.0.1:0
//! ```

use anyhow::Result;
use remus::coordinator::{Coordinator, CoordinatorConfig, Submitter};
use remus::errs::ErrorModel;
use remus::fabric::{Router, RouterConfig};
use remus::mmpu::{FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;
use remus::util::cli::Args;
use remus::util::table::Table;
use std::time::{Duration, Instant};

fn run_load(label: &str, sub: &dyn Submitter, requests: u64, t: &mut Table) -> Result<()> {
    let kinds = [FunctionKind::Mul(16), FunctionKind::Add(16), FunctionKind::Xor(16)];
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let kind = kinds[(i % 3) as usize];
            (i, kind, sub.submit(kind, i % 1000, (i * 7 + 3) % 1000))
        })
        .collect();
    let mut correct = 0u64;
    let mut errors = 0u64;
    for (i, kind, rx) in rxs {
        let r = rx.recv()?;
        if !r.is_ok() {
            // Infrastructure error results are not wrong *values* — keep
            // them out of the corruption count this demo is about.
            errors += 1;
            continue;
        }
        let (a, b) = (i % 1000, (i * 7 + 3) % 1000);
        correct += (r.value == kind.reference(a, b)) as u64;
    }
    if errors > 0 {
        eprintln!("[{label}] {errors} requests returned error results");
    }
    let dt = t0.elapsed();
    let m = sub.metrics();
    t.row(&[
        label.into(),
        format!("{:.0}", requests as f64 / dt.as_secs_f64()),
        format!("{}/{}", correct, requests),
        format!("{:.1}", m.mean_batch_size()),
        m.latency_percentile_us(50.0).to_string(),
        m.latency_percentile_us(99.0).to_string(),
    ]);
    Ok(())
}

fn run_coordinator(
    label: &str,
    policy: ReliabilityPolicy,
    errors: ErrorModel,
    requests: u64,
    workers: usize,
    t: &mut Table,
) -> Result<()> {
    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        rows: 64,
        cols: 1024,
        policy,
        errors,
        max_batch: 64,
        max_wait: Duration::from_micros(300),
        ..Default::default()
    })?;
    run_load(label, &coord, requests, t)?;
    coord.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let requests = args.get_or("requests", 8192u64);
    let workers = args.get_or("workers", 4usize);
    let mut t = Table::new(
        "coordinator under load",
        &["policy", "req/s", "correct", "mean_batch", "p50_us", "p99_us"],
    );
    // Remote mode: the identical load through the fabric router, over a
    // static shard list and/or registration-discovered shards.
    if args.get("shards").is_some() || args.get("listen-reg").is_some() {
        let addrs: Vec<String> = args
            .get("shards")
            .map(|s| s.split(',').map(str::to_string).collect())
            .unwrap_or_default();
        let cfg = RouterConfig {
            listen: args.get("listen-reg").map(str::to_string),
            ..Default::default()
        };
        let router = Router::with_config(&addrs, cfg)?;
        let min = args.get_or("min-shards", addrs.len().max(1));
        router.announce_and_wait(min, Duration::from_secs(30), "serve example");
        println!(
            "open-loop load: {requests} mixed requests over {} shards\n",
            router.shard_count()
        );
        run_load("fabric (remote policy)", &router, requests, &mut t)?;
        let m = router.metrics();
        println!("fleet shards: {} total, {} down", m.shards_total, m.shards_down);
        router.shutdown();
        t.print();
        return Ok(());
    }
    println!("open-loop load: {requests} mixed requests, {workers} workers\n");
    run_coordinator(
        "unprotected",
        ReliabilityPolicy::none(),
        ErrorModel::none(),
        requests,
        workers,
        &mut t,
    )?;
    run_coordinator(
        "p=1e-5, no protection",
        ReliabilityPolicy::none(),
        ErrorModel::direct_only(1e-5),
        requests,
        workers,
        &mut t,
    )?;
    run_coordinator(
        "p=1e-5, serial TMR",
        ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Serial },
        ErrorModel::direct_only(1e-5),
        requests,
        workers,
        &mut t,
    )?;
    t.print();
    println!("\nTMR restores correctness at ~1/3 the throughput — the paper's trade.");
    Ok(())
}
