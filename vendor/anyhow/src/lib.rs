//! Minimal offline substitute for the `anyhow` crate.
//!
//! Implements exactly the subset the `remus` workspace uses: an opaque
//! [`Error`] carrying a chain of context strings, the [`Result`] alias,
//! the [`Context`] extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Like the real crate, `Error`
//! deliberately does NOT implement `std::error::Error`, which is what
//! lets the blanket `From<E: std::error::Error>` conversion coexist with
//! the reflexive `From<Error>` used by `?`.
//!
//! Formatting matches anyhow's conventions closely enough for logs and
//! tests: `{}` prints the outermost message, `{:#}` prints the whole
//! chain joined by `": "`, and `{:?}` prints the outermost message
//! followed by a `Caused by:` list.

use std::fmt;

/// An opaque error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause_msg(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn context_chain_formats() {
        let e = failing().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            let parsed: u32 = "7".parse()?; // std error converts via From
            Ok(parsed + x)
        }
        assert_eq!(f(1).unwrap(), 8);
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(format!("{}", f(5).unwrap_err()).contains("Condition failed"));
    }

    #[test]
    fn std_error_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner io");
        let e: Error = io.into();
        let e = e.context("reading file");
        assert_eq!(format!("{e:#}"), "reading file: inner io");
    }
}
