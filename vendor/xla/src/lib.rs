//! Offline **stub** of the `xla` PJRT binding.
//!
//! The build image has neither the XLA runtime nor network access to the
//! real binding crate, so this stub provides the exact API surface
//! `remus::runtime` compiles against. The contract: `PjRtClient::cpu()`
//! always fails, so `remus::runtime::Runtime::new()` returns `Err` and
//! every artifact-dependent caller (benches, `#[ignore]`d integration
//! tests) takes its graceful-skip path. No other constructor can be
//! reached with a failed client, so the remaining methods are
//! unreachable at runtime but fully typed.

use std::fmt;

/// Stub error type (implements `std::error::Error` so callers can wrap
/// it with `anyhow::Context`).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Self(format!(
            "{what}: PJRT backend unavailable (in-tree xla stub; install the real \
             xla binding and rebuild to enable the AOT executor)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. `cpu()` always errors in the stub.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_builders_are_inert() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
