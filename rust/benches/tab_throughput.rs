//! E11 — §IV: "~100 TB/s for 8192 crossbars, each 1024x1024, consuming
//! only 1 GB" — the bitlet-style throughput model, plus what the
//! reliability mechanisms do to deliverable throughput.

use remus::analysis::overhead::suite_overhead;
use remus::bench_harness::header;
use remus::bitlet::BitletModel;
use remus::util::table::Table;

fn main() {
    header("tab_throughput", "§IV: fleet throughput model (~100 TB/s) + reliability cost");

    let m = BitletModel::paper();
    println!(
        "fleet: {} crossbars x {}x{} = {} MiB @ {} MHz",
        m.crossbars, m.rows, m.cols, m.total_bytes() >> 20, m.freq_mhz
    );
    println!("peak row-parallel throughput: {:.1} TB/s (paper: ~100 TB/s)\n", m.peak_tb_per_sec());

    let mut t = Table::new(
        "function-level fleet throughput (items/s, rows full)",
        &["function", "cycles", "baseline", "with ECC", "serial TMR", "parallel TMR"],
    );
    let (rows, _) = suite_overhead(16);
    for r in rows.iter().filter(|r| ["add32", "multpim16", "multpim32", "xor32"].iter().any(|n| r.name.contains(n))) {
        let base = m.function_throughput(r.base_cycles, m.rows);
        let ecc = m.function_throughput(r.base_cycles + r.ecc_cycles, m.rows);
        let tmr_s = m.function_throughput(3 * r.base_cycles, m.rows);
        let tmr_p = m.function_throughput(r.base_cycles, m.rows) / 3.0; // 3x area -> 1/3 capacity
        t.row(&[
            r.name.clone(),
            r.base_cycles.to_string(),
            format!("{base:.2e}"),
            format!("{ecc:.2e}"),
            format!("{tmr_s:.2e}"),
            format!("{tmr_p:.2e}"),
        ]);
    }
    t.print();
    let _ = t.write_csv("tab_throughput.csv");
    println!("note: TMR costs ~3x throughput either way (time or area); ECC costs the");
    println!("      verify+update tail only — the high-throughput reliability argument.");
}
