//! E8 — the paper's §IV claim: diagonal ECC adds a "moderate latency
//! overhead of 26 % on average" across the function mix. Regenerates the
//! per-function overhead table from the cost model AND measures it
//! end-to-end through the controller (wall clock + cycle accounting).

use remus::analysis::overhead::suite_overhead;
use remus::bench_harness::{bench, header, throughput};
use remus::errs::ErrorModel;
use remus::mmpu::{controller::quick_exec, FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;
use remus::util::table::Table;

fn main() {
    header("tab_ecc_overhead", "§IV: ECC latency overhead, 26% average (paper)");

    for m in [8usize, 16, 32] {
        let (rows, avg) = suite_overhead(m);
        let mut t = Table::new(
            &format!("per-function ECC latency overhead, block m={m}"),
            &["function", "base_cycles", "ecc_cycles", "overhead_%"],
        );
        for r in &rows {
            t.row(&[
                r.name.clone(),
                r.base_cycles.to_string(),
                r.ecc_cycles.to_string(),
                format!("{:.1}", r.overhead_pct),
            ]);
        }
        t.print();
        println!("m={m}: suite average = {avg:.1}%  (paper: 26% @ m~16)\n");
        if m == 16 {
            let _ = t.write_csv("tab_ecc_overhead.csv");
        }
    }

    // End-to-end measured cycles through the controller.
    let a: Vec<u64> = (0..32).collect();
    let b: Vec<u64> = (0..32).map(|i| i + 9).collect();
    let mut t = Table::new(
        "controller-measured compute vs ECC extension cycles (32 items)",
        &["function", "compute_cycles", "ecc_cycles", "overhead_%"],
    );
    for kind in [FunctionKind::Xor(32), FunctionKind::Add(32), FunctionKind::Mul(16)] {
        let r = quick_exec(
            kind,
            ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Off },
            ErrorModel::none(),
            7,
            &a,
            &b,
        )
        .unwrap();
        t.row(&[
            kind.name(),
            r.compute_cycles.to_string(),
            r.ecc_cycles.to_string(),
            format!("{:.1}", 100.0 * r.ecc_cycles as f64 / r.compute_cycles as f64),
        ]);
    }
    t.print();

    // Wall-clock impact of maintaining ECC in the simulator.
    let run = |ecc: Option<usize>| {
        move || {
            let _ = quick_exec(
                FunctionKind::Mul(16),
                ReliabilityPolicy { ecc_m: ecc, tmr: TmrMode::Off },
                ErrorModel::none(),
                3,
                &[7; 32],
                &[9; 32],
            )
            .unwrap();
        }
    };
    let r0 = bench("controller mul16 x32 rows (no ECC)", 32, run(None));
    throughput(&r0, "mult", 32.0);
    let r1 = bench("controller mul16 x32 rows (ECC m=16)", 32, run(Some(16)));
    throughput(&r1, "mult", 32.0);
}
