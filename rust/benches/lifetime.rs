//! §Lifetime — the long-run degradation harness (EXPERIMENTS.md
//! §Lifetime): simulated weight corruption on the real ECC machinery vs
//! the closed-form `nn::degradation` model, plus the wear-out curve of
//! the health subsystem's endurance process.
//!
//! Writes `BENCH_lifetime.json` for CI archival.

use remus::analysis::lifetime::{simulate, LifetimeConfig};
use remus::bench_harness::{bench, header, json_begin, json_end, throughput};
use remus::health::WearModel;

fn main() {
    json_begin("lifetime");
    header("lifetime", "EXPERIMENTS.md §Lifetime: degradation vs closed form");

    // Smaller than the `remus lifetime` default: the harness executes
    // the closure ~12 times (warmup + samples).
    let cfg = LifetimeConfig { cols: 512, batches: 256, record_every: 64, ..Default::default() };
    println!(
        "array {}x{} (m={}), p_input={:.1e}, {} batches, scrub every batch",
        cfg.rows, cfg.cols, cfg.m, cfg.p_input, cfg.batches
    );
    let mut report = None;
    let r = bench("lifetime sim, 128x512, 256 scrubbed batches", 1, || {
        report = Some(simulate(&cfg));
    });
    throughput(&r, "batch", cfg.batches as f64);
    let report = report.expect("bench ran at least once");

    println!("\n  batch | base sim | base model | blk sim | blk model | eccw sim | eccw model");
    for p in &report.points {
        println!(
            "  {:>5} | {:>8.0} | {:>10.1} | {:>7.0} | {:>9.1} | {:>8.0} | {:>10.1}",
            p.batch,
            p.sim_baseline_weights,
            p.model_baseline_weights,
            p.sim_failed_blocks,
            p.model_failed_blocks,
            p.sim_ecc_weights,
            p.model_ecc_weights
        );
    }
    let (rel_base, rel_blocks) = report.final_errors();
    println!(
        "\n  final relative error vs closed form: baseline {:.1}% (gate <= 10%) | \
         failed blocks {:.1}% (MC tolerance <= 25%)",
        rel_base * 100.0,
        rel_blocks * 100.0
    );

    // Wear-out curve: dead-cell fraction vs mean switches per cell.
    let wear = WearModel::rram();
    println!("\n  endurance model (lognormal, median {:.1e}):", wear.endurance_mean);
    for exp in [7.0f64, 7.5, 8.0, 8.5, 9.0] {
        let s = 10f64.powf(exp);
        let dead = wear.dead_fraction(s) * 100.0;
        println!("    {s:>9.2e} switches/cell -> {dead:>8.4}% cells dead");
    }

    json_end();
}
