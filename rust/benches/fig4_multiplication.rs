//! E5 — Fig. 4 (top): 32-bit multiplication failure probability vs
//! p_gate for the unreliable baseline, the proposed TMR (non-ideal
//! in-memory Minority3 voting) and the ideal-voting TMR (dashed line).
//!
//! Method = the paper's: Monte-Carlo fault injection on the real MultPIM
//! micro-code measures logical masking; binomial extrapolation covers
//! the un-simulatable rates; direct MC validates the model where
//! feasible. Expected shape: baseline linear in p_gate; TMR quadratic
//! until the voting term takes over near p_gate ~ 1e-9.

use remus::analysis::fig4::MultReliability;
use remus::bench_harness::{bench, header, throughput};
use remus::util::stats::logspace;
use remus::util::table::{sci, Table};

fn main() {
    header("fig4_multiplication", "Fig 4 (top): p_mult vs p_gate, baseline / TMR / TMR-ideal");

    let trials = std::env::var("REMUS_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    let mut rel = None;
    let r = bench("measure masking constants (32-bit MultPIM)", trials as u64, || {
        rel = Some(MultReliability::measure(32, trials, 0xF164));
    });
    throughput(&r, "fault-injection run", trials as f64 * 1.25);
    let rel = rel.unwrap();
    println!(
        "G = {} gate executions/multiplication, alpha = {:.3}, gamma = {:.3}",
        rel.gates, rel.alpha, rel.gamma
    );

    let grid = logspace(1e-10, 1e-4, 13);
    let mut t = Table::new(
        "Fig 4 top series (CSV mirrored to fig4_top.csv)",
        &["p_gate", "baseline", "tmr", "tmr_ideal"],
    );
    for row in rel.series(&grid) {
        t.row(&[sci(row.p_gate), sci(row.baseline), sci(row.tmr), sci(row.tmr_ideal)]);
    }
    t.print();
    let _ = t.write_csv("fig4_top.csv");

    // Model validation at simulatable rates.
    let mut v = Table::new(
        "model vs direct Monte-Carlo (validation points)",
        &["p_gate", "model_base", "mc_base [95% CI]", "model_tmr", "mc_tmr [95% CI]"],
    );
    for &p in &[1e-4, 3e-5, 1e-5] {
        let (mb, lb, hb) = rel.mc_baseline(p, 4000, 11);
        let (mt, lt, ht) = rel.mc_tmr(p, 4000, 13);
        v.row(&[
            sci(p),
            sci(rel.p_mult(p)),
            format!("{} [{},{}]", sci(mb), sci(lb), sci(hb)),
            sci(rel.p_tmr(p)),
            format!("{} [{},{}]", sci(mt), sci(lt), sci(ht)),
        ]);
    }
    v.print();

    // Paper anchors.
    println!("\npaper anchors @ p_gate = 1e-9:");
    println!("  baseline p_mult = {} (paper-implied ~7.3e-6)", sci(rel.p_mult(1e-9)));
    println!("  TMR p_mult      = {} (voting-dominated, paper-implied ~1.1e-7)", sci(rel.p_tmr(1e-9)));
    println!(
        "  crossover: voting > quadratic at p <= {}",
        sci(grid.iter().copied().find(|&p| rel.p_vote(p) > rel.p_tmr_ideal(p)).unwrap_or(0.0))
    );
}
