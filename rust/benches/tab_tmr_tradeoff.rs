//! E9 — the §V TMR trade-off: serial = 3x latency / ~1x area;
//! parallel = ~1x latency / 3x area; semi-parallel = 1x/1x at 1/3
//! throughput. Measured on the real crossbar simulator for the adder and
//! the MultPIM multiplier (cycles = crossbar cycle accounting, area =
//! columns, throughput = items per execution).

use remus::arith::adder::ripple_adder;
use remus::arith::multiplier::multpim_program;
use remus::bench_harness::header;
use remus::isa::program::Program;
use remus::tmr::{TmrEngine, TmrMode};
use remus::util::table::Table;
use remus::xbar::{Crossbar, Partitions};

fn measure(prog: &Program, mode: TmrMode) -> (u64, u32, usize) {
    let rows = 64;
    let width = match mode {
        TmrMode::Serial => TmrEngine::serial_layout(prog).width as usize,
        TmrMode::Parallel => (3 * prog.width + prog.output_cols.len() as u32 + 2) as usize,
        _ => prog.width as usize,
    };
    let mut x = Crossbar::new(rows, width);
    if mode != TmrMode::Parallel && prog.partition_starts.len() > 1 {
        let mut starts = prog.partition_starts.clone();
        starts.retain(|&s| (s as usize) < width);
        x.set_col_partitions(Partitions::new(width as u32, starts));
    }
    let run = TmrEngine::new(mode).execute(&mut x, prog, None).unwrap();
    (run.cycles, run.area_cols, run.items)
}

fn main() {
    header("tab_tmr_tradeoff", "§V: TMR latency/area/throughput trade-off (Fig 3)");

    let mut t = Table::new(
        "measured on the crossbar simulator (64 rows)",
        &["function", "mode", "cycles", "latency_x", "area_cols", "area_x", "items", "thru_x"],
    );
    for (name, prog) in [
        ("add32", ripple_adder(32).0),
        ("multpim8", multpim_program(8).0),
        ("multpim16", multpim_program(16).0),
    ] {
        let (base_cycles, base_area, base_items) = measure(&prog, TmrMode::Off);
        for mode in [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel] {
            // Parallel mode needs zipped-step structure; the MultPIM
            // programs already use partition concurrency per copy, which
            // composes (3N partitions) but needs width 3x: skip parallel
            // for multpim16 at 64 rows if too wide for the demo budget.
            if mode == TmrMode::Parallel && prog.width > 300 {
                continue;
            }
            let (cycles, area, items) = measure(&prog, mode);
            t.row(&[
                name.to_string(),
                format!("{mode:?}"),
                cycles.to_string(),
                format!("{:.2}", cycles as f64 / base_cycles as f64),
                area.to_string(),
                format!("{:.2}", area as f64 / base_area as f64),
                items.to_string(),
                format!("{:.2}", items as f64 / base_items as f64),
            ]);
        }
    }
    t.print();
    let _ = t.write_csv("tab_tmr_tradeoff.csv");
    println!("paper: serial 3x latency / 1x area; parallel 1x latency / 3x area;");
    println!("       semi-parallel 1x/1x at 1/3 throughput (voting via Minority3)");
}
