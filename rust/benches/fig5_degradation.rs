//! E7 — Fig. 5: expected corrupted weights over T batches under indirect
//! access errors, baseline (no ECC) vs the diagonal mMPU ECC.
//! Anchors: baseline ~all 62M weights corrupted by T = 1e7 (p_input =
//! 1e-8 curve); ECC ~1 corrupted weight at T = 1e7 with p_input = 1e-9.
//! Plus a small-scale *simulated* validation of the analytical model on
//! a real crossbar with real retention injection + ECC scrubbing.

use remus::bench_harness::{bench, header};
use remus::ecc::DiagonalEcc;
use remus::errs::{ErrorModel, Injector};
use remus::nn::degradation::DegradationModel;
use remus::util::bitmat::BitMatrix;
use remus::util::rng::Pcg64;
use remus::util::table::{sci, Table};

fn main() {
    header("fig5_degradation", "Fig 5: weight corruption over batches, baseline vs mMPU ECC");

    let model = DegradationModel::paper();
    let mut t = Table::new(
        "Fig 5 series (CSV mirrored to fig5.csv)",
        &["p_input", "batches", "baseline", "ecc"],
    );
    for &p in &[1e-10, 1e-9, 1e-8] {
        let mut batches = 1e0;
        while batches <= 1e8 {
            t.row(&[
                sci(p),
                format!("{batches:.0e}"),
                sci(model.expected_corrupted_baseline(p, batches)),
                sci(model.expected_corrupted_ecc(p, batches)),
            ]);
            batches *= 10.0;
        }
    }
    t.print();
    let _ = t.write_csv("fig5.csv");

    println!("\npaper anchors:");
    println!(
        "  baseline @ p=1e-8, T=1e7: {:.1}% of weights corrupted (paper: ~all)",
        100.0 * model.expected_corrupted_baseline(1e-8, 1e7) / model.weights
    );
    println!(
        "  ECC @ p=1e-9, T=1e7: {:.2} corrupted weights (paper: ~1)",
        model.expected_corrupted_ecc(1e-9, 1e7)
    );

    // --- micro-validation on a real simulated crossbar ---------------
    // 128x128 array, per-"batch" access errors at a large p_input so the
    // effect is measurable; ECC scrubbed every batch. Compare corrupted-
    // weight counts with the analytical model after T batches.
    let n = 128;
    let m = 16;
    let p_input = 2e-5;
    let t_batches = 200u64;
    let weights = (n * n / 32) as f64;
    let golden = {
        let mut rng = Pcg64::new(4, 0);
        BitMatrix::from_fn(n, n, |_, _| rng.bernoulli(0.5))
    };
    let mut base_state = golden.clone();
    let mut ecc_state = golden.clone();
    let mut ecc = DiagonalEcc::new(n, n, m);
    ecc.encode(&ecc_state);
    let mut inj = Injector::new(ErrorModel::indirect_only(p_input), 9, 0);
    let r = bench("simulate 200 batches w/ ECC scrub (128x128)", t_batches, || {
        let mut b = golden.clone();
        let mut e = golden.clone();
        let mut ecc2 = DiagonalEcc::new(n, n, m);
        ecc2.encode(&e);
        for _ in 0..t_batches {
            inj.input_drifts(n * n, |i| b.flip(i / n, i % n));
            inj.input_drifts(n * n, |i| e.flip(i / n, i % n));
            ecc2.correct(&mut e);
        }
        base_state = b;
        ecc_state = e;
    });
    let _ = r;
    let corrupted = |s: &BitMatrix| -> usize {
        let mut words = 0;
        for wr in 0..n / 32 {
            for r0 in 0..n {
                let mut bad = false;
                for k in 0..32 {
                    if s.get(r0, wr * 32 + k) != golden.get(r0, wr * 32 + k) {
                        bad = true;
                    }
                }
                words += bad as usize;
            }
        }
        words
    };
    let model_small = DegradationModel { weights, bits: 32.0, m: m as f64 };
    let mut v = Table::new(
        "micro-validation: simulated vs analytical (p_input=2e-5, T=200, 512 weights)",
        &["", "simulated", "analytical"],
    );
    v.row(&[
        "baseline corrupted".into(),
        corrupted(&base_state).to_string(),
        format!("{:.1}", model_small.expected_corrupted_baseline(p_input, t_batches as f64)),
    ]);
    v.row(&[
        "ECC corrupted".into(),
        corrupted(&ecc_state).to_string(),
        format!("{:.1}", model_small.expected_corrupted_ecc(p_input, t_batches as f64)),
    ]);
    v.print();
}
