//! E10 — §V per-bit vs per-element voting: per-bit strictly dominates
//! (they differ exactly where per-element is undefined), demonstrated
//! exhaustively for 4-bit outputs and statistically for 64-bit, plus the
//! paper's 1000/0100/0010 example.

use remus::bench_harness::{bench, header, throughput};
use remus::tmr::voting::{per_bit_vote_word, per_element_vote};
use remus::util::rng::Pcg64;
use remus::util::table::Table;

fn main() {
    header("tab_voting", "§V: per-bit vs per-element voting comparison");

    println!("paper example: copies 1000 / 0100 / 0010 (truth 0000)");
    println!("  per-element: {:?} (undefined -> error)", per_element_vote(0b1000, 0b0100, 0b0010));
    println!("  per-bit:     {:04b} (correct)\n", per_bit_vote_word(0b1000, 0b0100, 0b0010));

    // Exhaustive 4-bit: for every (truth, e1, e2, e3) single-bit-error
    // pattern, compare success rates.
    let mut pb_ok = 0u64;
    let mut pe_ok = 0u64;
    let mut total = 0u64;
    for truth in 0..16u64 {
        for e1 in 0..4 {
            for e2 in 0..4 {
                for e3 in 0..4 {
                    let a = truth ^ (1 << e1);
                    let b = truth ^ (1 << e2);
                    let c = truth ^ (1 << e3);
                    total += 1;
                    if per_bit_vote_word(a, b, c) == truth {
                        pb_ok += 1;
                    }
                    if per_element_vote(a, b, c) == Some(truth) {
                        pe_ok += 1;
                    }
                }
            }
        }
    }
    let mut t = Table::new(
        "exhaustive: one single-bit error per copy (4-bit outputs)",
        &["scheme", "correct", "total", "success_%"],
    );
    t.row(&["per-bit".into(), pb_ok.to_string(), total.to_string(), format!("{:.1}", 100.0 * pb_ok as f64 / total as f64)]);
    t.row(&["per-element".into(), pe_ok.to_string(), total.to_string(), format!("{:.1}", 100.0 * pe_ok as f64 / total as f64)]);
    t.print();
    assert!(pb_ok > pe_ok);

    // Statistical 64-bit with Poisson-ish multi-bit errors.
    let mut rng = Pcg64::new(2, 0);
    let trials = 200_000u64;
    let mut pb = 0u64;
    let mut pe = 0u64;
    for _ in 0..trials {
        let truth = rng.next_u64();
        let mut corrupt = |rng: &mut Pcg64| {
            let mut v = truth;
            let flips = rng.below(3);
            for _ in 0..flips {
                v ^= 1 << rng.below(64);
            }
            v
        };
        let (a, b, c) = (corrupt(&mut rng), corrupt(&mut rng), corrupt(&mut rng));
        pb += (per_bit_vote_word(a, b, c) == truth) as u64;
        pe += (per_element_vote(a, b, c) == Some(truth)) as u64;
    }
    println!(
        "\n64-bit statistical (0-2 random flips/copy, {trials} trials): per-bit {:.3}% vs per-element {:.3}%",
        100.0 * pb as f64 / trials as f64,
        100.0 * pe as f64 / trials as f64
    );
    assert!(pb >= pe);

    let r = bench("per_bit_vote_word", 1_000_000, || {
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            acc ^= per_bit_vote_word(i, i.wrapping_mul(3), i.wrapping_mul(7));
        }
        std::hint::black_box(acc);
    });
    throughput(&r, "vote", 1e6);
}
