//! §Perf — the hot-path microbenchmarks tracked in EXPERIMENTS.md §Perf:
//! raw row-parallel gate application, error sampling, whole-program
//! execution (compiled plan vs legacy interpreter vs PJRT), operand
//! marshalling, and the coordinator request path.
//!
//! Writes `BENCH_perf_hotpath.json` (per-bench ns/iter + throughput) for
//! CI archival — see `bench_harness::json_begin`.

use remus::arith::multiplier::multpim_program;
use remus::bench_harness::{bench, header, json_begin, json_end, json_scalar, throughput};
use remus::errs::{ErrorModel, Injector};
use remus::isa::microop::MicroOp;
use remus::isa::program::Step;
use remus::xbar::{Crossbar, Gate, Partitions};

fn main() {
    json_begin("perf_hotpath");
    header("perf_hotpath", "EXPERIMENTS.md §Perf: simulator hot paths");

    // --- L3 hot path 1: row-parallel gate application ----------------
    let rows = 1024;
    let mut x = Crossbar::new(rows, 64);
    for r in 0..rows {
        x.state_mut().set(r, 0, r % 2 == 0);
        x.state_mut().set(r, 1, r % 3 == 0);
    }
    let step = Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2));
    let iters = 100_000u64;
    let r = bench("in-row NOR, 1024 rows (clean)", iters, || {
        for _ in 0..iters {
            x.apply_step(&step, None).unwrap();
        }
    });
    throughput(&r, "gate", iters as f64);
    throughput(&r, "row-gate-bit", iters as f64 * rows as f64);

    // --- with error injection at realistic p -------------------------
    let mut inj = Injector::new(ErrorModel::direct_only(1e-6), 1, 0);
    let r = bench("in-row NOR, 1024 rows (p_gate=1e-6)", iters, || {
        for _ in 0..iters {
            x.apply_step(&step, Some(&mut inj)).unwrap();
        }
    });
    throughput(&r, "row-gate-bit", iters as f64 * rows as f64);

    let mut inj = Injector::new(ErrorModel::direct_only(1e-3), 1, 0);
    let r = bench("in-row NOR, 1024 rows (p_gate=1e-3)", iters, || {
        for _ in 0..iters {
            x.apply_step(&step, Some(&mut inj)).unwrap();
        }
    });
    throughput(&r, "row-gate-bit", iters as f64 * rows as f64);

    // --- L3 hot path 2: full MultPIM-32 program, 128 rows -------------
    // The serving path: plan compiled ONCE (validation + operand
    // resolution hoisted out), then executed via run_plan. The legacy
    // per-step interpreter line quantifies the §Perf win.
    let (prog, lay) = multpim_program(32);
    let mut x = Crossbar::new(128, lay.width as usize);
    x.set_col_partitions(Partitions::new(lay.width, lay.partition_starts.clone()));
    for r0 in 0..128 {
        for k in 0..32usize {
            x.state_mut().set(r0, lay.a_cols[k] as usize, (r0 + k) % 2 == 0);
            x.state_mut().set(r0, lay.b_cols[k] as usize, (r0 * k) % 3 == 0);
        }
    }
    let ops = prog.num_ops() as f64;
    let plan = x.compile_plan(&prog).expect("multpim plan");
    let r = bench("MultPIM-32 program, 128 rows (clean)", 1, || {
        x.run_plan(&plan, None).unwrap();
    });
    throughput(&r, "micro-op", ops);
    throughput(&r, "mult", 128.0);
    let r = bench("MultPIM-32 legacy uncompiled, 128 rows", 1, || {
        x.run_program_uncompiled(&prog, None).unwrap();
    });
    throughput(&r, "mult", 128.0);
    let r = bench("MultPIM-32 compile_plan (one-time cost)", 1, || {
        std::hint::black_box(x.compile_plan(&prog).unwrap());
    });
    throughput(&r, "compile", 1.0);
    let mut inj = Injector::new(ErrorModel::direct_only(1e-6), 2, 0);
    let r = bench("MultPIM-32 program, 128 rows (p=1e-6)", 1, || {
        x.run_plan(&plan, Some(&mut inj)).unwrap();
    });
    throughput(&r, "mult", 128.0);

    // --- operand marshalling: word-parallel vs per-bit ----------------
    {
        use remus::mmpu::{FunctionKind, FunctionSpec, Mmpu, MmpuConfig, ReliabilityPolicy};
        let cfg = MmpuConfig {
            rows: 64,
            cols: 512,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 7,
            ..Default::default()
        };
        let func = FunctionSpec::build(FunctionKind::Mul(8));
        let a: Vec<u64> = (0..64).map(|i| i * 37 % 251).collect();
        let b: Vec<u64> = (0..64).map(|i| (i * 3 + 11) % 251).collect();
        let mut mmpu = Mmpu::new(cfg.clone());
        let iters = 200u64;
        let r = bench("exec_vector mul8 batch 64 (compiled+word)", iters, || {
            for _ in 0..iters {
                mmpu.exec_vector(0, &func, &a, &b).unwrap();
            }
        });
        throughput(&r, "mult", iters as f64 * 64.0);
        let mut mmpu = Mmpu::new(cfg);
        let r = bench("exec_vector mul8 batch 64 (legacy per-bit)", iters, || {
            for _ in 0..iters {
                mmpu.exec_vector_legacy(0, &func, &a, &b).unwrap();
            }
        });
        throughput(&r, "mult", iters as f64 * 64.0);
    }

    // --- §Perf list scheduling: scheduled vs serial per kind ----------
    // The tracked `scheduled_vs_serial` family (EXPERIMENTS.md §Perf):
    // identical inputs through the same Mmpu shape, once with the
    // serial program-order plans and once list-scheduled on a 64-way
    // uniform partition grid (8-col segments at 512 cols — fine enough
    // that narrow functions span several segments). The packing-factor
    // scalars come from the
    // compiled plans themselves (micro-ops / bundles), so the
    // acceptance bar (> 1.0 for multi-gate arithmetic kinds) is
    // checked against the schedule, not against timing noise.
    {
        use remus::isa::ScheduleConfig;
        use remus::mmpu::{
            CompiledFunction, FunctionKind, FunctionSpec, Mmpu, MmpuConfig, ReliabilityPolicy,
        };
        use remus::tmr::TmrMode;
        let kinds: &[(&str, FunctionKind, u64)] = &[
            ("add8", FunctionKind::Add(8), 0xFF),
            ("mul8", FunctionKind::Mul(8), 0xFF),
            ("mul4-naive", FunctionKind::MulNaive(4), 0xF),
            ("xor8", FunctionKind::Xor(8), 0xFF),
        ];
        let (rows, cols) = (64usize, 512usize);
        let mk = |sched: ScheduleConfig| MmpuConfig {
            rows,
            cols,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 9,
            schedule: sched,
            ..Default::default()
        };
        for &(name, kind, mask) in kinds {
            let func = FunctionSpec::build(kind);
            let a: Vec<u64> = (0..64).map(|i| (i * 37 + 3) & mask).collect();
            let b: Vec<u64> = (0..64).map(|i| (i * 3 + 11) & mask).collect();
            let iters = 100u64;
            let mut serial = Mmpu::new(mk(ScheduleConfig::off()));
            let r = bench(&format!("sched {name} batch 64 (serial)"), iters, || {
                for _ in 0..iters {
                    serial.exec_vector(0, &func, &a, &b).unwrap();
                }
            });
            throughput(&r, "op", iters as f64 * 64.0);
            let mut packed = Mmpu::new(mk(ScheduleConfig::packed(64)));
            let r = bench(&format!("sched {name} batch 64 (packed64)"), iters, || {
                for _ in 0..iters {
                    packed.exec_vector(0, &func, &a, &b).unwrap();
                }
            });
            throughput(&r, "op", iters as f64 * 64.0);
            let cs = CompiledFunction::build(kind, rows, cols, TmrMode::Off, ScheduleConfig::off())
                .unwrap();
            let cp =
                CompiledFunction::build(kind, rows, cols, TmrMode::Off, ScheduleConfig::packed(64))
                    .unwrap();
            json_scalar(
                &format!("sched packing factor {name}"),
                "ops/bundle",
                cp.tmr.num_ops() as f64 / cp.tmr.num_bundles() as f64,
            );
            json_scalar(
                &format!("sched cycles saved {name}"),
                "cycle",
                cs.tmr.num_bundles().saturating_sub(cp.tmr.num_bundles()) as f64,
            );
        }
    }

    // --- MC engine: single-lane interpreter ---------------------------
    use remus::analysis::lane::{FaultPlan, LaneSim};
    let mut rng = remus::util::rng::Pcg64::new(5, 0);
    let r = bench("LaneSim MultPIM-32 single lane (random faults p=1e-6)", 100, || {
        for _ in 0..100 {
            let mut lane = LaneSim::new(lay.width as usize);
            lane.load(&lay.a_cols, 0xDEADBEEF);
            lane.load(&lay.b_cols, 0x12345678);
            lane.run(&prog, FaultPlan::Random { p: 1e-6, rng: &mut rng });
        }
    });
    throughput(&r, "mult-campaign-trial", 100.0);

    // --- PJRT executor (if artifacts present) -------------------------
    if let Ok(mut rt) = remus::runtime::Runtime::new() {
        use remus::runtime::XlaCrossbar;
        let (prog8, lay8) = multpim_program(8);
        let mut xla = XlaCrossbar::new(128, 128);
        for r0 in 0..128 {
            for k in 0..8usize {
                xla.state_mut().set(r0, lay8.a_cols[k] as usize, (r0 + k) % 2 == 0);
                xla.state_mut().set(r0, lay8.b_cols[k] as usize, (r0 * k) % 5 == 0);
            }
        }
        // warm compile
        xla.run_program(&mut rt, &prog8).unwrap();
        let r = bench("PJRT gate-scan MultPIM-8, 128 rows", 1, || {
            xla.run_program(&mut rt, &prog8).unwrap();
        });
        throughput(&r, "mult", 128.0);
        // native comparison
        let mut xn = Crossbar::new(128, 128);
        xn.set_col_partitions(Partitions::new(128, lay8.partition_starts.clone()));
        let r = bench("native  MultPIM-8, 128 rows", 1, || {
            xn.run_program(&prog8, None).unwrap();
        });
        throughput(&r, "mult", 128.0);
    } else {
        println!("(artifacts not built; skipping PJRT hot path — run `make artifacts`)");
    }

    // --- coordinator request path -------------------------------------
    use remus::coordinator::{Coordinator, CoordinatorConfig};
    use remus::mmpu::FunctionKind;
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        rows: 64,
        cols: 512,
        max_batch: 64,
        max_wait: std::time::Duration::from_micros(200),
        ..Default::default()
    })
    .unwrap();
    let n = 4096u64;
    let r = bench("coordinator: 4096 mul8 requests, 4 workers", n, || {
        let rxs: Vec<_> =
            (0..n).map(|i| coord.submit(FunctionKind::Mul(8), i % 251, (i * 3) % 251)).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
    });
    throughput(&r, "request", n as f64);
    let m = coord.metrics();
    println!(
        "      mean batch {:.1}, p50 {} us, p99 {} us, failed {}",
        m.mean_batch_size(),
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0),
        m.failed
    );
    coord.shutdown();
    json_end();
}
