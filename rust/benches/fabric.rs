//! §Scale — sharded fabric serving over loopback TCP (EXPERIMENTS.md
//! §Scale): the same open-loop request stream through (a) one
//! in-process coordinator and (b) a consistent-hash router over two
//! fabric server shards on loopback sockets. The delta between the two
//! rows is the wire + framing + fan-out cost; the per-shard row count
//! scales with the shard fleet.
//!
//! Writes `BENCH_fabric.json` for CI archival.

use std::time::Duration;

use remus::bench_harness::{bench, header, json_begin, json_end, throughput};
use remus::coordinator::{Coordinator, CoordinatorConfig, Submitter};
use remus::fabric::loadgen::{self, LoadgenConfig};
use remus::fabric::{FabricServer, Router};
use remus::mmpu::FunctionKind;

const REQUESTS: u64 = 4096;

fn shard_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 64,
        cols: 1024,
        max_batch: 64,
        max_wait: Duration::from_micros(300),
        seed,
        ..Default::default()
    }
}

/// Mixed-kind open-loop wave; returns the count of correct values.
/// (add8 and xor16 land on different shards of the 2-entry ring, so the
/// fabric rows exercise both servers.)
fn drive(sub: &dyn Submitter, requests: u64) -> u64 {
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let kind = if i % 2 == 0 { FunctionKind::Add(8) } else { FunctionKind::Xor(16) };
            let (a, b) = (i % 251, (i * 7) % 251);
            (kind, a, b, sub.submit(kind, a, b))
        })
        .collect();
    let mut ok = 0u64;
    for (kind, a, b, rx) in rxs {
        let want = kind.reference(a, b);
        if rx.recv().map(|r| r.is_ok() && r.value == want).unwrap_or(false) {
            ok += 1;
        }
    }
    ok
}

/// Informational open-loop row (EXPERIMENTS.md §Scale): a short paced
/// run at a fixed offered rate, reporting the latency percentiles the
/// closed-loop bench() rows cannot (they measure completion throughput,
/// which hides queueing). Not a bench() entry — a paced run's wall time
/// is fixed by its schedule, so median-of-runs is meaningless.
fn open_loop_row(label: &str, sub: &dyn Submitter) {
    let cfg = LoadgenConfig { qps: 4000.0, requests: 2048, seed: 0x10AD, ..Default::default() };
    let rep = loadgen::run(sub, &cfg);
    assert_eq!(rep.ok, rep.requests, "open-loop replies must all verify");
    println!(
        "  open-loop {label}: offered {:.0} qps, achieved {:.0} qps ({} stalls)",
        rep.offered_qps, rep.achieved_qps, rep.window_stalls
    );
    for (kind, k) in &rep.kinds {
        println!(
            "    {:<10} p50={}us p90={}us p99={}us max={}us (n={})",
            kind.name(),
            k.hist.percentile_us(50.0),
            k.hist.percentile_us(90.0),
            k.hist.percentile_us(99.0),
            k.hist.max_us(),
            k.hist.count()
        );
    }
}

fn main() {
    json_begin("fabric");
    header("fabric", "EXPERIMENTS.md §Scale: sharded serving over a loopback wire");

    // Baseline: the identical load on one in-process coordinator.
    let coord = Coordinator::start(shard_cfg(1)).expect("coordinator");
    let r = bench("in-process coordinator: 4096 add8+xor16, 2 workers", REQUESTS, || {
        assert_eq!(drive(&coord, REQUESTS), REQUESTS);
    });
    throughput(&r, "req", REQUESTS as f64);
    open_loop_row("in-process coordinator", &coord);
    coord.shutdown();

    // Two fabric shards on ephemeral loopback ports, one router.
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(1)).expect("shard 1");
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(2)).expect("shard 2");
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).expect("router");
    println!(
        "  (add8 -> shard {:?}, xor16 -> shard {:?})",
        router.shard_for(FunctionKind::Add(8)),
        router.shard_for(FunctionKind::Xor(16))
    );
    let r = bench("fabric router: 4096 add8+xor16, 2 loopback shards", REQUESTS, || {
        assert_eq!(drive(&router, REQUESTS), REQUESTS);
    });
    throughput(&r, "req", REQUESTS as f64);

    open_loop_row("fabric router (2 shards)", &router);
    let m = router.metrics();
    println!(
        "  fleet after bench: completed={} failed={} mean_batch={:.1} \
         hb pings={} pongs={} timeouts={}",
        m.completed,
        m.failed,
        m.mean_batch_size(),
        m.hb_pings,
        m.hb_pongs,
        m.hb_timeouts
    );
    router.shutdown();
    s1.shutdown();
    s2.shutdown();

    json_end();
}
