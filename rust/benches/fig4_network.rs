//! E6 — Fig. 4 (bottom): AlexNet/FloatPIM soft-error-induced
//! misclassification probability vs p_gate:
//! `1 - (1 - p_mask * p_mult)^M`, M = 612e6, p_mask = 0.03 %.
//! Anchors: baseline ~74 % at p_gate = 1e-9; TMR ~2 % (below the
//! network's inherent 27 % error).

use remus::analysis::fig4::MultReliability;
use remus::bench_harness::header;
use remus::nn::alexnet::AlexNetModel;
use remus::util::stats::logspace;
use remus::util::table::{sci, Table};

fn main() {
    header("fig4_network", "Fig 4 (bottom): NN failure probability vs p_gate");

    let trials = std::env::var("REMUS_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(4000);
    let rel = MultReliability::measure(32, trials, 0xF164);
    let model = AlexNetModel::paper();
    println!(
        "AlexNet model: W = {} weights, mults/sample (layer table) = {}, using paper M = {:.3e}, p_mask = {}",
        model.total_weights(),
        model.total_mults(),
        AlexNetModel::M_PAPER,
        model.p_mask
    );

    let grid = logspace(1e-10, 1e-4, 13);
    let mut t = Table::new(
        "Fig 4 bottom series (CSV mirrored to fig4_bottom.csv)",
        &["p_gate", "baseline", "tmr", "tmr_ideal"],
    );
    for row in rel.series(&grid) {
        t.row(&[
            sci(row.p_gate),
            sci(model.p_network(row.baseline)),
            sci(model.p_network(row.tmr)),
            sci(model.p_network(row.tmr_ideal)),
        ]);
    }
    t.print();
    let _ = t.write_csv("fig4_bottom.csv");

    let base9 = model.p_network(rel.p_mult(1e-9));
    let tmr9 = model.p_network(rel.p_tmr(1e-9));
    println!("\npaper anchors @ p_gate = 1e-9:");
    println!("  baseline misclassification = {:.1}% (paper: 74%)", 100.0 * base9);
    println!(
        "  TMR misclassification      = {:.2}% (paper: ~2%, inherent error 27%)",
        100.0 * tmr9
    );
    assert!(tmr9 < model.inherent_error, "TMR keeps compute error below inherent error");
}
