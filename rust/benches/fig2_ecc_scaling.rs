//! E2 — Fig. 2(a) vs 2(b): parity-update cost after in-row / in-column
//! parallel operations, naive horizontal vs diagonal ECC, swept over the
//! crossbar size n. Reproduces the O(n) vs O(1) asymmetry and measures
//! the actual simulator wall time of the two engines.

use remus::analysis::overhead::fig2_update_costs;
use remus::bench_harness::{bench, header, throughput};
use remus::ecc::{DiagonalEcc, HorizontalEcc};
use remus::util::bitmat::BitMatrix;
use remus::util::rng::Pcg64;
use remus::util::table::Table;

fn main() {
    header(
        "fig2_ecc_scaling",
        "Fig 2(a,b): naive horizontal O(n) in-column update vs diagonal O(1)",
    );

    // --- cycle-model table (the figure's content) -------------------
    let ns = [64usize, 128, 256, 512, 1024];
    let mut t = Table::new(
        "parity-update cycles after ONE in-column op (all columns)",
        &["n", "horizontal (Fig 2a)", "diagonal (Fig 2b)", "gap"],
    );
    for (n, h, d) in fig2_update_costs(&ns) {
        t.row(&[n.to_string(), h.to_string(), d.to_string(), format!("{}x", h / d)]);
    }
    t.print();
    println!("(in-row updates are O(1)={} cycles for BOTH codes)", 4);

    // --- engine wall-time at n = 512 --------------------------------
    let n = 512;
    let mut rng = Pcg64::new(1, 0);
    let state = BitMatrix::from_fn(n, n, |_, _| rng.bernoulli(0.5));
    let mut diag = DiagonalEcc::new(n, n, 16);
    diag.encode(&state);
    let mut horiz = HorizontalEcc::new(n, n, 8);
    horiz.encode(&state);
    let row = state.row_bitvec(5);
    let col = state.col_bitvec(5);

    let r = bench("diagonal.note_col_write (n=512)", 100, || {
        for _ in 0..100 {
            diag.note_col_write(5, &col, &col);
        }
    });
    throughput(&r, "update", 100.0);
    let r = bench("diagonal.note_row_write (n=512)", 100, || {
        for _ in 0..100 {
            diag.note_row_write(5, &row, &row);
        }
    });
    throughput(&r, "update", 100.0);
    let r = bench("horizontal.note_row_write (n=512)", 100, || {
        for _ in 0..100 {
            horiz.note_row_write(5, &row, &row);
        }
    });
    throughput(&r, "update", 100.0);
    let r = bench("diagonal.verify_all (n=512)", 1, || {
        let _ = diag.verify_all(&state);
    });
    throughput(&r, "verify", 1.0);
}
