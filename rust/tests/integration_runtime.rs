//! Integration: the PJRT path. The same encoded program bytes must drive
//! the native bit-packed simulator and the AOT (JAX/Pallas -> HLO -> PJRT)
//! executor to identical final states — clean and under identical
//! injected error masks. Requires `make artifacts`.

use remus::arith::adder::ripple_adder;
use remus::arith::multiplier::multpim_program;
use remus::ecc::DiagonalEcc;
use remus::errs::{ErrorModel, Injector};
use remus::isa::microop::Gate;
use remus::nn::micronet::MicroNet;
use remus::runtime::{Manifest, Runtime, XlaCrossbar};
use remus::util::bitmat::BitMatrix;
use remus::util::rng::Pcg64;
use remus::xbar::{Crossbar, Partitions};

fn runtime() -> Runtime {
    Runtime::new().expect("artifacts present? run `make artifacts`")
}

/// Native replay of an encoded program + explicit masks (reference for
/// the cross-validation).
fn native_replay(state: &BitMatrix, prog: &remus::isa::program::Program, masks: &[f32]) -> BitMatrix {
    let rows = state.rows();
    let mut out = state.clone();
    for (s, op) in prog.flatten().iter().enumerate() {
        // apply gate
        for r in 0..rows {
            let a = out.get(r, op.a as usize);
            let b = out.get(r, op.b as usize);
            let c = out.get(r, op.c as usize);
            let prev = out.get(r, op.out as usize);
            let mut v = op.gate.eval_bit(a, b, c, prev);
            if op.gate != Gate::Nop && masks[s * rows + r] > 0.5 {
                v = !v;
            }
            out.set(r, op.out as usize, v);
        }
    }
    out
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn pjrt_client_boots() {
    let rt = runtime();
    let platform = rt.platform().to_lowercase();
    assert!(platform == "cpu" || platform == "host", "platform = {platform}");
    assert!(rt.manifest().artifacts_of_kind("gate_scan").count() >= 2);
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn gate_scan_clean_matches_native_crossbar() {
    let (prog, lay) = ripple_adder(8);
    let mut rng = Pcg64::new(21, 0);
    // shapes must match an artifact exactly: 128x128 (s=256 fits 97 ops)
    let rows = 128;
    let mut init = BitMatrix::zeros(rows, 128);
    let pairs: Vec<(u64, u64)> =
        (0..rows).map(|_| (rng.next_u64() & 0xFF, rng.next_u64() & 0xFF)).collect();
    for (r, &(a, b)) in pairs.iter().enumerate() {
        for k in 0..8 {
            init.set(r, lay.a.col(k) as usize, (a >> k) & 1 == 1);
            init.set(r, lay.b.col(k) as usize, (b >> k) & 1 == 1);
        }
    }
    // Native path.
    let mut x = Crossbar::new(rows, 128);
    *x.state_mut() = init.clone();
    x.run_program(&prog, None).unwrap();
    // PJRT path.
    let mut rt = runtime();
    let mut xla = XlaCrossbar::new(rows, 128);
    *xla.state_mut() = init;
    xla.run_program(&mut rt, &prog).unwrap();
    assert_eq!(x.state(), xla.state(), "native and AOT paths must agree bit-exactly");
    for (r, &(a, b)) in pairs.iter().enumerate() {
        assert!(xla.state().get(r, lay.sum.col(0) as usize) == ((a + b) & 1 == 1), "row {r}");
    }
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn gate_scan_with_masks_matches_native_replay() {
    let (prog, _) = ripple_adder(8);
    let rows = 128;
    let mut rng = Pcg64::new(33, 1);
    let mut init = BitMatrix::zeros(rows, 128);
    for r in 0..rows {
        for c in 0..24 {
            init.set(r, c, rng.bernoulli(0.5));
        }
    }
    let mut rt = runtime();
    let mut xla = XlaCrossbar::new(rows, 128);
    *xla.state_mut() = init.clone();
    let enc = xla.encode_for(&rt, &prog).unwrap();
    // Random masks at 2 %.
    let masks: Vec<f32> =
        (0..enc.steps * rows).map(|_| if rng.bernoulli(0.02) { 1.0 } else { 0.0 }).collect();
    xla.run_program_with_masks(&mut rt, &prog, &masks).unwrap();
    let want = native_replay(&init, &prog, &masks);
    assert_eq!(xla.state(), &want, "masked execution must agree with native replay");
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn gate_scan_multpim8_product_via_pjrt() {
    let (prog, lay) = multpim_program(8);
    assert!(lay.width <= 128, "fits the 128-col artifact");
    let rows = 128;
    let mut rt = runtime();
    let mut xla = XlaCrossbar::new(rows, 128);
    let mut rng = Pcg64::new(55, 0);
    let pairs: Vec<(u64, u64)> =
        (0..rows).map(|_| (rng.next_u64() & 0xFF, rng.next_u64() & 0xFF)).collect();
    for (r, &(a, b)) in pairs.iter().enumerate() {
        for k in 0..8 {
            xla.state_mut().set(r, lay.a_cols[k] as usize, (a >> k) & 1 == 1);
            xla.state_mut().set(r, lay.b_cols[k] as usize, (b >> k) & 1 == 1);
        }
    }
    xla.run_program(&mut rt, &prog).unwrap();
    for (r, &(a, b)) in pairs.iter().enumerate() {
        let mut v = 0u64;
        for i in 0..16 {
            if xla.state().get(r, lay.result.col(i) as usize) {
                v |= 1 << i;
            }
        }
        assert_eq!(v, a * b, "row {r}: a whole 8-bit MultPIM through PJRT");
    }
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn gate_scan_error_sampling_statistics() {
    // The injector-driven mask generator fires at ~p_gate on logic steps.
    let (prog, _) = ripple_adder(8);
    let rows = 128;
    let rt = runtime();
    let xla = XlaCrossbar::new(rows, 128);
    let enc = xla.encode_for(&rt, &prog).unwrap();
    let mut inj = Injector::new(ErrorModel::direct_only(0.01), 1, 0);
    let masks = Runtime::sample_err_masks(&enc, rows, &mut inj);
    let ones: usize = masks.iter().filter(|&&v| v > 0.5).count();
    let sites = prog.logic_gates_per_lane() * rows;
    let expect = sites as f64 * 0.01;
    assert!((ones as f64) > expect * 0.5 && (ones as f64) < expect * 2.0, "{ones} vs {expect}");
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn vote3_artifact_matches_reference() {
    let mut rt = runtime();
    let (r, c) = (64, 64);
    let mut rng = Pcg64::new(77, 0);
    let mk = |rng: &mut Pcg64| BitMatrix::from_fn(r, c, |_, _| rng.bernoulli(0.5));
    let a = mk(&mut rng);
    let b = mk(&mut rng);
    let cc = mk(&mut rng);
    let zeros = vec![0f32; r * c];
    let got = rt.run_vote3(&a, &b, &cc, &zeros, &zeros).unwrap();
    for i in 0..r {
        for j in 0..c {
            let maj = (a.get(i, j) as u8 + b.get(i, j) as u8 + cc.get(i, j) as u8) >= 2;
            assert_eq!(got.get(i, j), maj, "({i},{j})");
        }
    }
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn diag_parity_artifact_matches_rust_ecc() {
    // The Pallas barrel-shift kernel and the rust DiagonalEcc must
    // produce identical diagonal parities.
    let mut rt = runtime();
    let (bsz, m) = (64, 16);
    let mut rng = Pcg64::new(88, 0);
    let blocks: Vec<f32> =
        (0..bsz * m * m).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let got = rt.run_diag_parity(&blocks, bsz, m).unwrap();
    for b in 0..bsz {
        let bm = BitMatrix::from_f32_row_major(m, m, &blocks[b * m * m..(b + 1) * m * m]);
        let mut ecc = DiagonalEcc::new(m, m, m);
        ecc.encode(&bm);
        // verify through syndromes: a clean encode must match the kernel's
        // parities; compare via re-derivation.
        for d in 0..m {
            let lead: bool = (0..m).fold(false, |acc, i| acc ^ bm.get(i, (i + d) % m));
            let cnt: bool = (0..m).fold(false, |acc, i| acc ^ bm.get(i, (d + m - i % m) % m));
            assert_eq!(got[b * 2 * m + d] > 0.5, lead, "block {b} lead {d}");
            assert_eq!(got[b * 2 * m + m + d] > 0.5, cnt, "block {b} counter {d}");
        }
    }
}

#[test]
#[ignore = "requires PJRT artifacts and the real xla binding (vendor/xla is an offline stub); run `make artifacts` and swap the dependency to enable"]
fn micronet_artifact_matches_rust_forward() {
    let manifest = Manifest::load_default().unwrap();
    let net = MicroNet::load(&manifest).unwrap();
    let mut rt = runtime();
    let batch = 64;
    let mut rng = Pcg64::new(99, 0);
    let x: Vec<f32> =
        (0..batch * net.indim).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
    let ones1 = vec![1f32; net.indim * net.hidden];
    let zeros1 = vec![0f32; net.indim * net.hidden];
    let ones2 = vec![1f32; net.hidden * net.classes];
    let zeros2 = vec![0f32; net.hidden * net.classes];
    let got = rt
        .run_micronet(batch, &x, &net.w1, &net.b1, &net.w2, &net.b2, &ones1, &zeros1, &ones2, &zeros2)
        .unwrap();
    let want = net.forward_f32(&x, batch);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-3, "{g} vs {w}");
    }
}
