//! Integration: the mMPU controller across reliability policies.

use remus::errs::ErrorModel;
use remus::mmpu::{controller::quick_exec, FunctionKind, Mmpu, MmpuConfig, ReliabilityPolicy};
use remus::mmpu::functions::FunctionSpec;
use remus::tmr::TmrMode;
use remus::util::rng::Pcg64;

#[test]
fn all_functions_all_policies_clean() {
    let mut rng = Pcg64::new(4, 0);
    for kind in [FunctionKind::Add(16), FunctionKind::Mul(8), FunctionKind::Xor(16)] {
        for tmr in [TmrMode::Off, TmrMode::Serial, TmrMode::SemiParallel] {
            for ecc in [None, Some(16)] {
                let a: Vec<u64> =
                    (0..12).map(|_| rng.next_u64() & ((1 << kind.operand_bits()) - 1)).collect();
                let b: Vec<u64> =
                    (0..12).map(|_| rng.next_u64() & ((1 << kind.operand_bits()) - 1)).collect();
                let r = quick_exec(
                    kind,
                    ReliabilityPolicy { ecc_m: ecc, tmr },
                    ErrorModel::none(),
                    9,
                    &a,
                    &b,
                )
                .unwrap_or_else(|e| panic!("{kind:?} {tmr:?} ecc={ecc:?}: {e:#}"));
                for i in 0..12 {
                    let want = match kind {
                        FunctionKind::Add(_) => a[i] + b[i],
                        FunctionKind::Mul(_) | FunctionKind::MulNaive(_) => a[i] * b[i],
                        FunctionKind::Xor(_) => a[i] ^ b[i],
                    };
                    assert_eq!(r.values[i], want, "{kind:?} {tmr:?} ecc={ecc:?} item {i}");
                }
            }
        }
    }
}

#[test]
fn parallel_tmr_through_controller() {
    let a: Vec<u64> = (0..8).map(|i| i * 3 + 1).collect();
    let b: Vec<u64> = (0..8).map(|i| i + 200).collect();
    let r = quick_exec(
        FunctionKind::Add(16),
        ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Parallel },
        ErrorModel::none(),
        3,
        &a,
        &b,
    )
    .unwrap();
    for i in 0..8 {
        assert_eq!(r.values[i], a[i] + b[i]);
    }
}

#[test]
fn reliability_policy_cycle_accounting() {
    let a: Vec<u64> = vec![5; 8];
    let b: Vec<u64> = vec![7; 8];
    let base = quick_exec(
        FunctionKind::Mul(8),
        ReliabilityPolicy::none(),
        ErrorModel::none(),
        1,
        &a,
        &b,
    )
    .unwrap();
    let tmr = quick_exec(
        FunctionKind::Mul(8),
        ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Serial },
        ErrorModel::none(),
        1,
        &a,
        &b,
    )
    .unwrap();
    let full = quick_exec(
        FunctionKind::Mul(8),
        ReliabilityPolicy::full(),
        ErrorModel::none(),
        1,
        &a,
        &b,
    )
    .unwrap();
    assert!(base.ecc_cycles == 0 && base.compute_cycles > 0);
    let ratio = tmr.compute_cycles as f64 / base.compute_cycles as f64;
    assert!((2.5..3.6).contains(&ratio), "serial TMR cycles x{ratio}");
    assert!(full.ecc_cycles > 0);
    // The headline combination: ECC cycles are a small fraction of the
    // multiplier's compute cycles.
    assert!((full.ecc_cycles as f64) < 0.3 * full.compute_cycles as f64);
}

#[test]
fn multi_crossbar_fleet_is_independent() {
    let cfg = MmpuConfig {
        rows: 16,
        cols: 512,
        num_crossbars: 3,
        policy: ReliabilityPolicy::none(),
        errors: ErrorModel::direct_only(1e-3),
        seed: 5,
        ..Default::default()
    };
    let mut mmpu = Mmpu::new(cfg);
    let func = FunctionSpec::build(FunctionKind::Mul(8));
    let a: Vec<u64> = (0..16).collect();
    let b: Vec<u64> = (0..16).map(|i| i + 3).collect();
    let mut flip_counts = vec![];
    for id in 0..3 {
        mmpu.exec_vector(id, &func, &a, &b).unwrap();
        flip_counts.push(mmpu.injector_counters(id).gate_flips);
    }
    // Independent error streams: overwhelmingly unlikely to be all equal
    // AND stats accumulate per crossbar.
    assert!(
        !(flip_counts[0] == flip_counts[1] && flip_counts[1] == flip_counts[2]),
        "streams must differ: {flip_counts:?}"
    );
    for id in 0..3 {
        assert!(mmpu.stats(id).cycles > 0);
    }
}

#[test]
fn mul_naive_baseline_agrees_with_multpim() {
    let a: Vec<u64> = (0..8).map(|i| i * 29 % 256).collect();
    let b: Vec<u64> = (0..8).map(|i| i * 31 % 256).collect();
    let fast = quick_exec(
        FunctionKind::Mul(8),
        ReliabilityPolicy::none(),
        ErrorModel::none(),
        2,
        &a,
        &b,
    )
    .unwrap();
    let naive = quick_exec(
        FunctionKind::MulNaive(8),
        ReliabilityPolicy::none(),
        ErrorModel::none(),
        2,
        &a,
        &b,
    )
    .unwrap();
    assert_eq!(fast.values, naive.values);
    assert!(naive.compute_cycles > 3 * fast.compute_cycles, "partitions win");
}
