//! Property tests for the fabric wire codec: every message type
//! (the v3 heartbeat `Ping`/`Pong`, the v5 telemetry frames —
//! traced submits, `Events`/`EventsReply`, `SpansReq`/`SpansReply` —
//! and the v6 epoch-stamped `EventsReply` included) survives
//! encode -> frame -> decode bit-exactly, v1..v5 frames still decode
//! under the v6 codec, and truncated or corrupted frames — truncated
//! pings, length-prefix lies and single-bit flips included — are
//! rejected with errors: never a panic, never an accidental parse.
//! Sealed frames (wire v4, `fabric::auth`) additionally detect
//! *every* single-bit flip, truncation and replay: a tampered sealed
//! frame can never open, so it can never decode to a different valid
//! message undetected (ISSUE 3 + ISSUE 5 + ISSUE 6 + ISSUE 7 + ISSUE
//! 8 satellites).

use remus::coordinator::{KindStats, MetricsSnapshot, WorkerHealth};
use remus::fabric::auth::{derive_keys, Psk, SEAL_OVERHEAD};
use remus::fabric::wire::{read_msg, write_msg, Msg, MAX_FRAME, MIN_WIRE_VERSION, WIRE_VERSION};
use remus::mmpu::functions::KIND_FAMILIES;
use remus::mmpu::FunctionKind;
use remus::telemetry::{Event, EventKind, Stage, TraceSpan};
use remus::testutil::prop::{Cases, Gen};

fn gen_kind(g: &mut Gen) -> FunctionKind {
    let bits = g.usize_in(1..=64) as u32;
    match g.usize_in(0..=3) {
        0 => FunctionKind::Add(bits),
        1 => FunctionKind::Mul(bits),
        2 => FunctionKind::MulNaive(bits),
        _ => FunctionKind::Xor(bits),
    }
}

fn gen_string(g: &mut Gen) -> String {
    let n = g.usize_in(0..=32);
    (0..n)
        .map(|_| {
            let c = g.u64_in(0..=27);
            match c {
                26 => ' ',
                27 => 'λ', // exercise multi-byte utf-8
                _ => (b'a' + c as u8) as char,
            }
        })
        .collect()
}

fn gen_snapshot(g: &mut Gen) -> MetricsSnapshot {
    let nbins = g.usize_in(0..=24);
    let nworkers = g.usize_in(0..=4);
    MetricsSnapshot {
        submitted: g.u64(),
        completed: g.u64(),
        failed: g.u64(),
        batches: g.u64(),
        batched_items: g.u64(),
        busy_ns: g.u64(),
        queue_depth: g.u64(),
        lat_bins: g.vec_u64(nbins),
        worker_health: (0..nworkers)
            .map(|_| WorkerHealth {
                batches: g.u64(),
                scrubs: g.u64(),
                corrected: g.u64(),
                uncorrectable: g.u64(),
                stuck_detected: g.u64(),
                remapped_rows: g.u64(),
                spares_left: g.u64(),
                policy_level: (g.u64_in(0..=2)) as u8,
                retired: g.bool(),
            })
            .collect(),
        lat_overflow: g.u64(),
        lat_max_us: g.u64(),
        uptime_ns: g.u64(),
        kind_stats: std::array::from_fn(|_| KindStats {
            submitted: g.u64(),
            completed: g.u64(),
            failed: g.u64(),
        }),
        shards_total: g.u64(),
        shards_down: g.u64(),
        hb_pings: g.u64(),
        hb_pongs: g.u64(),
        hb_timeouts: g.u64(),
        auth_rejects: g.u64(),
        plan_ops: g.u64(),
        plan_bundles: g.u64(),
    }
}

fn gen_event_kind(g: &mut Gen) -> EventKind {
    match g.usize_in(0..=13) {
        0 => EventKind::Scrub {
            worker: g.u64() as u32,
            corrected: g.u64(),
            detected: g.u64() as u32,
            remapped: g.u64() as u32,
        },
        1 => EventKind::StuckCell { worker: g.u64() as u32, cells: g.u64() },
        2 => EventKind::RowRemap { worker: g.u64() as u32, rows: g.u64() },
        3 => EventKind::PolicyEscalate { worker: g.u64() as u32, level: g.u64() as u8 },
        4 => EventKind::PolicyDeescalate { worker: g.u64() as u32, level: g.u64() as u8 },
        5 => EventKind::WorkerRetire { worker: g.u64() as u32 },
        6 => EventKind::SparePromote { unit: g.u64() as u32 },
        7 => EventKind::SpareDemote { unit: g.u64() as u32 },
        8 => EventKind::ShardDown { shard: g.u64() as u32 },
        9 => EventKind::ShardRevive { shard: g.u64() as u32 },
        10 => EventKind::HeartbeatTimeout { shard: g.u64() as u32 },
        11 => EventKind::FailoverReplay { shard: g.u64() as u32, replayed: g.u64() },
        12 => EventKind::AuthReject,
        _ => EventKind::ShardRestarted { shard: g.u64() as u32, epoch: g.u64() },
    }
}

fn gen_event(g: &mut Gen) -> Event {
    Event { seq: g.u64(), shard: g.u64() as u32, at_ns: g.u64(), kind: gen_event_kind(g) }
}

fn gen_span(g: &mut Gen) -> TraceSpan {
    TraceSpan { trace: g.u64(), stage: *g.pick(&Stage::ALL), start_ns: g.u64(), dur_ns: g.u64() }
}

fn gen_msg(g: &mut Gen) -> Msg {
    match g.usize_in(0..=15) {
        0 => Msg::Submit {
            id: g.u64(),
            kind: gen_kind(g),
            a: g.u64(),
            b: g.u64(),
            // Half untraced (v1-labeled frames), half traced (v5).
            trace: if g.bool() { g.u64() } else { 0 },
        },
        1 => {
            let error = if g.bool() { Some(gen_string(g)) } else { None };
            Msg::Result { id: g.u64(), value: g.u64(), latency_us: g.u64(), error }
        }
        2 => Msg::MetricsReq,
        3 => Msg::MetricsReply(gen_snapshot(g)),
        4 => Msg::HealthReq,
        5 => Msg::HealthReply {
            serving: g.bool(),
            workers: g.u64() as u32,
            routable: g.u64() as u32,
            retired: g.u64() as u32,
        },
        6 => Msg::Shutdown,
        7 => Msg::ShutdownAck,
        8 => Msg::Register {
            name: gen_string(g),
            addr: gen_string(g),
            spare: g.bool(),
            prev: if g.bool() { Some(g.u64() as u32) } else { None },
        },
        9 => Msg::Welcome { shard: g.u64() as u32, active: g.bool() },
        10 => Msg::Ping { nonce: g.u64() },
        11 => Msg::Pong { nonce: g.u64() },
        12 => Msg::Events { since: g.u64() },
        13 => {
            let n = g.usize_in(0..=8);
            Msg::EventsReply {
                latest: g.u64(),
                events: (0..n).map(|_| gen_event(g)).collect(),
                // Half epoch-less (v5-labeled frames), half epoch-
                // stamped (v6).
                boot_epoch: if g.bool() { g.u64_in(1..=u64::MAX) } else { 0 },
            }
        }
        14 => Msg::SpansReq,
        _ => {
            let n = g.usize_in(0..=8);
            Msg::SpansReply { spans: (0..n).map(|_| gen_span(g)).collect() }
        }
    }
}

#[test]
fn every_message_roundtrips_through_a_frame() {
    Cases::new(512).run(|g| {
        let msg = gen_msg(g);
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let mut r: &[u8] = &buf;
        let decoded = read_msg(&mut r).unwrap().expect("one frame");
        assert_eq!(decoded, msg);
        assert!(read_msg(&mut r).unwrap().is_none(), "clean EOF after the frame");
    });
}

#[test]
fn truncated_frames_error_without_panic() {
    Cases::new(256).run(|g| {
        let msg = gen_msg(g);
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        // Cut anywhere strictly inside the frame: mid-length-prefix,
        // mid-header, or mid-body — every cut must surface as Err (the
        // strict `len` prefix means a shorter valid message can never
        // hide inside a longer one's prefix).
        let cut = g.usize_in(1..=buf.len() - 1);
        let mut r: &[u8] = &buf[..cut];
        assert!(read_msg(&mut r).is_err(), "cut at {cut}/{} must error", buf.len());
        // Payload-level truncation (no length prefix) is also rejected.
        let payload = msg.to_bytes();
        let pcut = g.usize_in(0..=payload.len() - 1);
        assert!(Msg::from_bytes(&payload[..pcut]).is_err(), "payload cut at {pcut}");
    });
}

#[test]
fn garbage_frames_error_without_panic() {
    Cases::new(512).run(|g| {
        let n = g.usize_in(2..=64);
        let mut payload: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
        // Half the time force a valid version byte so decoding reaches
        // the type/body layers; decoding must still never panic.
        if g.bool() {
            payload[0] = WIRE_VERSION;
            let _ = Msg::from_bytes(&payload);
        } else {
            let _ = Msg::from_bytes(&payload);
        }
        // A wrong version is always rejected outright.
        payload[0] = WIRE_VERSION + 1 + (g.u64_in(0..=200) as u8);
        assert!(Msg::from_bytes(&payload).is_err());
    });
}

#[test]
fn version_mismatch_is_rejected() {
    // Every message, relabeled to a version outside the supported
    // range, must fail to decode — cleanly, never a panic or misparse.
    Cases::new(256).run(|g| {
        let msg = gen_msg(g);
        let mut too_new = msg.to_bytes();
        too_new[0] = WIRE_VERSION + 1 + (g.u64_in(0..=(254 - WIRE_VERSION) as u64) as u8);
        assert!(
            Msg::from_bytes(&too_new).is_err(),
            "version {} must be rejected",
            too_new[0]
        );
        let mut too_old = msg.to_bytes();
        too_old[0] = MIN_WIRE_VERSION - 1; // 0 is below every supported version
        assert!(Msg::from_bytes(&too_old).is_err());
    });
}

#[test]
fn v1_through_v6_frames_decode_compatibly_under_v7() {
    // v6 snapshots predate the packing counters (strip the trailing
    // 16 bytes), v4 ones also the observability counters (uptime +
    // histogram honesty + per-kind stats: strip 136), v3 ones also
    // the auth-reject counter (strip 144), v2 ones also the heartbeat
    // counters (strip 168), v1 ones also the fleet membership
    // counters (strip 184): relabel the version and the decode must
    // succeed with the missing fields defaulted to zero.
    Cases::new(256).run(|g| {
        let mut snap = gen_snapshot(g);
        let mut v6 = Msg::MetricsReply(snap.clone()).to_bytes();
        v6.truncate(v6.len() - 16);
        v6[0] = 6;
        snap.plan_ops = 0;
        snap.plan_bundles = 0;
        assert_eq!(Msg::from_bytes(&v6).unwrap(), Msg::MetricsReply(snap.clone()));
        let mut v4 = Msg::MetricsReply(snap.clone()).to_bytes();
        v4.truncate(v4.len() - 136);
        v4[0] = 4;
        snap.uptime_ns = 0;
        snap.lat_overflow = 0;
        snap.lat_max_us = 0;
        snap.kind_stats = [KindStats::default(); KIND_FAMILIES];
        assert_eq!(Msg::from_bytes(&v4).unwrap(), Msg::MetricsReply(snap.clone()));
        let mut v3 = Msg::MetricsReply(snap.clone()).to_bytes();
        v3.truncate(v3.len() - 144);
        v3[0] = 3;
        snap.auth_rejects = 0;
        assert_eq!(Msg::from_bytes(&v3).unwrap(), Msg::MetricsReply(snap.clone()));
        let mut v2 = Msg::MetricsReply(snap.clone()).to_bytes();
        v2.truncate(v2.len() - 168);
        v2[0] = 2;
        snap.hb_pings = 0;
        snap.hb_pongs = 0;
        snap.hb_timeouts = 0;
        assert_eq!(Msg::from_bytes(&v2).unwrap(), Msg::MetricsReply(snap.clone()));
        let mut v1 = Msg::MetricsReply(snap.clone()).to_bytes();
        v1.truncate(v1.len() - 184);
        v1[0] = 1;
        snap.shards_total = 0;
        snap.shards_down = 0;
        assert_eq!(Msg::from_bytes(&v1).unwrap(), Msg::MetricsReply(snap));
        // Fixed-layout messages decode identically under any version.
        let msg = Msg::Submit { id: g.u64(), kind: gen_kind(g), a: g.u64(), b: g.u64(), trace: 0 };
        let mut v1 = msg.to_bytes();
        v1[0] = 1;
        assert_eq!(Msg::from_bytes(&v1).unwrap(), msg);
        // A traced submit relabeled v1..v4 has trailing bytes those
        // layouts cannot express: a clean error, never a misparse.
        let traced = Msg::Submit {
            id: g.u64(),
            kind: gen_kind(g),
            a: g.u64(),
            b: g.u64(),
            trace: g.u64() | 1,
        };
        assert_eq!(traced.to_bytes()[0], 5, "traced submits are v5-stamped");
        for v in [1u8, 2, 3, 4] {
            let mut bytes = traced.to_bytes();
            bytes[0] = v;
            assert!(Msg::from_bytes(&bytes).is_err(), "trace id needs v5 (label v{v})");
        }
        // Telemetry control frames are v5-only: an older label is a
        // clean error, never a misparse.
        let v5_only = [
            Msg::Events { since: g.u64() },
            Msg::EventsReply { latest: g.u64(), events: vec![gen_event(g)], boot_epoch: 0 },
            Msg::SpansReq,
            Msg::SpansReply { spans: vec![gen_span(g)] },
        ];
        for m in v5_only {
            assert_eq!(m.to_bytes()[0], 5, "telemetry frames are v5-stamped");
            for v in [1u8, 2, 3, 4] {
                let mut bytes = m.to_bytes();
                bytes[0] = v;
                assert!(Msg::from_bytes(&bytes).is_err(), "{m:?} needs v5 (label v{v})");
            }
        }
        // The v6 trailing field (exact truncation offset: 8 bytes of
        // boot epoch behind the v5 body). An epoch-stamped reply is
        // v6-labeled and exactly 8 bytes longer than its epoch-less
        // twin; stripping those 8 bytes and relabeling v5 decodes to
        // the same reply with the epoch defaulted to 0 — how a v5
        // puller sees a v6 shard.
        let n = g.usize_in(0..=6);
        let latest = g.u64();
        let events: Vec<Event> = (0..n).map(|_| gen_event(g)).collect();
        let plain = Msg::EventsReply { latest, events: events.clone(), boot_epoch: 0 };
        let stamped = Msg::EventsReply {
            latest,
            events: events.clone(),
            boot_epoch: g.u64_in(1..=u64::MAX),
        };
        let pb = plain.to_bytes();
        let sb = stamped.to_bytes();
        assert_eq!(pb[0], 5, "epoch-less journal replies keep the v5 layout");
        assert_eq!(sb[0], 6, "epoch-stamped journal replies are v6-stamped");
        assert_eq!(sb.len(), pb.len() + 8, "the boot epoch is exactly 8 trailing bytes");
        assert_eq!(Msg::from_bytes(&sb).unwrap(), stamped);
        let mut stripped = sb.clone();
        stripped.truncate(sb.len() - 8);
        stripped[0] = 5;
        assert_eq!(Msg::from_bytes(&stripped).unwrap(), plain, "v6 -> v5 strips the epoch");
        // An epoch-stamped reply relabeled v1..v5 has trailing bytes
        // those layouts cannot express: a clean error, never a
        // misparse; and a v6 label *requires* the trailing field.
        for v in [1u8, 2, 3, 4, 5] {
            let mut bytes = sb.clone();
            bytes[0] = v;
            assert!(Msg::from_bytes(&bytes).is_err(), "boot epoch needs v6 (label v{v})");
        }
        let mut epochless_v6 = pb.clone();
        epochless_v6[0] = 6;
        assert!(
            Msg::from_bytes(&epochless_v6).is_err(),
            "a v6 label without the trailing epoch is a short frame"
        );
        // A prev-less Register still decodes as the v2 layout it keeps.
        let reg2 =
            Msg::Register { name: gen_string(g), addr: gen_string(g), spare: g.bool(), prev: None };
        assert_eq!(reg2.to_bytes()[0], 2, "prev-less Register stays v2-labeled");
        assert_eq!(Msg::from_bytes(&reg2.to_bytes()).unwrap(), reg2);
        // Registration frames are v2-only: a v1 label is a clean error.
        let mut v1reg = reg2.to_bytes();
        v1reg[0] = 1;
        assert!(Msg::from_bytes(&v1reg).is_err());
        let mut v1wel = Msg::Welcome { shard: g.u64() as u32, active: g.bool() }.to_bytes();
        v1wel[0] = 1;
        assert!(Msg::from_bytes(&v1wel).is_err());
        // Heartbeats and prev-carrying registrations are v3-only: older
        // labels are clean errors, never misparses.
        let reg3 = Msg::Register {
            name: gen_string(g),
            addr: gen_string(g),
            spare: g.bool(),
            prev: Some(g.u64() as u32),
        };
        assert_eq!(reg3.to_bytes()[0], 3, "prev-carrying Register stays v3-labeled");
        for v in [1u8, 2] {
            let mut bytes = reg3.to_bytes();
            bytes[0] = v;
            assert!(Msg::from_bytes(&bytes).is_err(), "prev index needs v3 (label v{v})");
            for hb in [Msg::Ping { nonce: g.u64() }, Msg::Pong { nonce: g.u64() }] {
                let mut bytes = hb.to_bytes();
                bytes[0] = v;
                assert!(Msg::from_bytes(&bytes).is_err(), "{hb:?} needs v3 (label v{v})");
            }
        }
    });
}

#[test]
fn heartbeat_frames_roundtrip_and_truncated_pings_error() {
    Cases::new(256).run(|g| {
        let nonce = g.u64();
        for msg in [Msg::Ping { nonce }, Msg::Pong { nonce }] {
            let mut buf = Vec::new();
            write_msg(&mut buf, &msg).unwrap();
            let mut r: &[u8] = &buf;
            assert_eq!(read_msg(&mut r).unwrap().expect("one frame"), msg);
            assert!(read_msg(&mut r).unwrap().is_none());
            // Every strictly-internal cut — mid-prefix, mid-header, or
            // mid-nonce — must surface as Err, never a panic or a
            // short parse.
            let cut = g.usize_in(1..=buf.len() - 1);
            let mut r: &[u8] = &buf[..cut];
            assert!(read_msg(&mut r).is_err(), "cut at {cut}/{} must error", buf.len());
            let payload = msg.to_bytes();
            let pcut = g.usize_in(0..=payload.len() - 1);
            assert!(Msg::from_bytes(&payload[..pcut]).is_err(), "payload cut at {pcut}");
            // A nonce-less Ping body (header only) is also rejected.
            assert!(Msg::from_bytes(&payload[..2]).is_err());
        }
    });
}

#[test]
fn unknown_event_tags_and_stage_bytes_are_rejected() {
    // A peer speaking a *future* v5 dialect could ship event kinds or
    // stages this decoder has no variant for: the unknown byte must be
    // a clean decode error, never a panic or a silently-dropped entry.
    let reply = Msg::EventsReply {
        latest: 1,
        events: vec![Event { seq: 0, shard: 0, at_ns: 1, kind: EventKind::AuthReject }],
        boot_epoch: 0,
    };
    let mut bytes = reply.to_bytes();
    // [ver][type][latest u64][count u32][seq u64][shard u32][at u64][tag]
    let tag_at = 2 + 8 + 4 + 8 + 4 + 8;
    assert_eq!(bytes[tag_at], 13, "layout check: AuthReject wire tag");
    bytes[tag_at] = 99;
    assert!(Msg::from_bytes(&bytes).is_err(), "unknown event tag must be rejected");
    // The v6 trailing epoch sits *behind* the events, so the event
    // layout — and the unknown-tag rejection — is identical in an
    // epoch-stamped reply.
    let reply6 = Msg::EventsReply {
        latest: 1,
        events: vec![Event { seq: 0, shard: 0, at_ns: 1, kind: EventKind::AuthReject }],
        boot_epoch: 0xB007,
    };
    let mut bytes6 = reply6.to_bytes();
    assert_eq!(bytes6[tag_at], 13, "layout check: same tag offset under v6");
    bytes6[tag_at] = 99;
    assert!(Msg::from_bytes(&bytes6).is_err(), "unknown event tag rejected under v6 too");
    let reply = Msg::SpansReply {
        spans: vec![TraceSpan { trace: 1, stage: Stage::TmrVote, start_ns: 2, dur_ns: 3 }],
    };
    let mut bytes = reply.to_bytes();
    // [ver][type][count u32][trace u64][stage]
    let stage_at = 2 + 4 + 8;
    assert_eq!(bytes[stage_at], Stage::TmrVote as u8, "layout check: stage byte");
    bytes[stage_at] = 77;
    assert!(Msg::from_bytes(&bytes).is_err(), "unknown stage byte must be rejected");
}

#[test]
fn implausible_length_prefixes_are_rejected() {
    // Oversized: a garbage length prefix must not allocate/hang.
    let mut oversized = Vec::new();
    oversized.extend_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
    oversized.extend_from_slice(&[0u8; 32]);
    let mut r: &[u8] = &oversized;
    assert!(read_msg(&mut r).is_err());
    // Undersized: no room for even the version+type header.
    let mut tiny = Vec::new();
    tiny.extend_from_slice(&1u32.to_le_bytes());
    tiny.push(WIRE_VERSION);
    let mut r: &[u8] = &tiny;
    assert!(read_msg(&mut r).is_err());
    // Zero-length frame.
    let zero = 0u32.to_le_bytes().to_vec();
    let mut r: &[u8] = &zero;
    assert!(read_msg(&mut r).is_err());
}

#[test]
fn bit_flips_and_length_lies_never_panic_the_plaintext_codec() {
    // Plaintext has no integrity: a flipped frame may decode to a
    // different valid message (that is exactly the gap the seal
    // closes), but it must never panic, hang, or over-allocate.
    Cases::new(512).run(|g| {
        let msg = gen_msg(g);
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        // Single-bit flip anywhere in the frame, length prefix included.
        let byte = g.usize_in(0..=buf.len() - 1);
        let bit = g.usize_in(0..=7) as u8;
        let mut flipped = buf.clone();
        flipped[byte] ^= 1 << bit;
        let mut r: &[u8] = &flipped;
        let _ = read_msg(&mut r); // Ok or Err — just never a panic
        let _ = Msg::from_bytes(&flipped[4..]);
        // A lying length prefix: any u32, same body bytes behind it.
        let mut lied = buf.clone();
        let lie = (g.u64() as u32).to_le_bytes();
        lied[..4].copy_from_slice(&lie);
        let mut r: &[u8] = &lied;
        let _ = read_msg(&mut r);
    });
}

#[test]
fn sealed_frames_detect_every_flip_truncation_and_replay() {
    // The wire-v4 seal in front of the codec: a sealed frame that was
    // tampered with in *any* single bit, truncated to *any* length, or
    // replayed verbatim must fail to open — so a tampered frame can
    // never decode to a different valid message undetected, because it
    // never reaches the codec at all.
    let psk = Psk::from_material(b"prop fabric wire seal").unwrap();
    // Exhaustive single-bit sweep over one small fixed frame.
    {
        let keys = derive_keys(&psk, &[0xA1; 32], &[0xB2; 32]);
        let (mut tx, rx) = (keys.c2s.clone(), keys.c2s);
        let sealed = tx.seal(&Msg::Ping { nonce: 0xDEAD_BEEF }.to_bytes());
        for byte in 0..sealed.len() {
            for bit in 0..8 {
                let mut t = sealed.clone();
                t[byte] ^= 1 << bit;
                assert!(
                    rx.clone().open(&t).is_err(),
                    "flip at byte {byte} bit {bit} must not open"
                );
            }
        }
        for cut in 0..sealed.len() {
            assert!(rx.clone().open(&sealed[..cut]).is_err(), "truncation to {cut} bytes");
        }
        let mut rx = rx;
        let opened = rx.open(&sealed).unwrap();
        assert_eq!(Msg::from_bytes(&opened).unwrap(), Msg::Ping { nonce: 0xDEAD_BEEF });
        assert!(rx.open(&sealed).is_err(), "verbatim replay must be rejected");
    }
    // Randomized sweep over arbitrary messages (every type, arbitrary
    // sizes): sampled flips and cuts, plus the counter-advance law —
    // failed opens must not desync an honest sender/receiver pair.
    Cases::new(128).run(|g| {
        let keys = derive_keys(&psk, &[g.u64() as u8; 32], &[g.u64() as u8; 32]);
        let (mut tx, mut rx) = (keys.s2c.clone(), keys.s2c);
        let msg = gen_msg(g);
        let payload = msg.to_bytes();
        let sealed = tx.seal(&payload);
        assert_eq!(sealed.len(), payload.len() + SEAL_OVERHEAD);
        for _ in 0..16 {
            let byte = g.usize_in(0..=sealed.len() - 1);
            let bit = g.usize_in(0..=7) as u8;
            let mut t = sealed.clone();
            t[byte] ^= 1 << bit;
            assert!(rx.open(&t).is_err(), "flip at byte {byte} bit {bit}");
            let cut = g.usize_in(0..=sealed.len() - 1);
            assert!(rx.open(&sealed[..cut]).is_err(), "truncation to {cut}");
        }
        // All those failures left the receive counter untouched: the
        // honest frame still opens, exactly once.
        assert_eq!(rx.open(&sealed).unwrap(), payload);
        assert!(rx.open(&sealed).is_err(), "replay after success");
        // And the stream keeps flowing afterwards.
        let msg2 = gen_msg(g);
        let sealed2 = tx.seal(&msg2.to_bytes());
        assert_eq!(Msg::from_bytes(&rx.open(&sealed2).unwrap()).unwrap(), msg2);
    });
}
