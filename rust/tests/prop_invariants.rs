//! Property-based invariants across modules (in-tree prop framework;
//! seeds reproducible via REMUS_PROP_SEED).

use remus::arith::adder::ripple_adder;
use remus::arith::multiplier::multpim_program;
use remus::ecc::DiagonalEcc;
use remus::isa::encode::{decode, encode};
use remus::testutil::prop::Cases;
use remus::tmr::voting::{per_bit_vote_word, per_element_vote};
use remus::util::bitmat::BitMatrix;
use remus::util::stats::one_minus_pow;
use remus::xbar::{Crossbar, Gate, Partitions};
use remus::isa::microop::MicroOp;
use remus::isa::program::Step;

#[test]
fn prop_adder_matches_u64_arithmetic() {
    Cases::new(60).run(|g| {
        let n = g.usize_in(2..=24) as u32;
        let (prog, lay) = ripple_adder(n);
        let a = g.u64() & ((1 << n) - 1);
        let b = g.u64() & ((1 << n) - 1);
        let mut x = Crossbar::new(1, lay.width as usize);
        for k in 0..n {
            x.state_mut().set(0, lay.a.col(k) as usize, (a >> k) & 1 == 1);
            x.state_mut().set(0, lay.b.col(k) as usize, (b >> k) & 1 == 1);
        }
        x.run_program(&prog, None).unwrap();
        let mut s = 0u64;
        for k in 0..n {
            if x.get(0, lay.sum.col(k) as usize) {
                s |= 1 << k;
            }
        }
        let cout = x.get(0, lay.cout as usize) as u64;
        assert_eq!(s | (cout << n), a + b, "{a}+{b} @ n={n}");
    });
}

#[test]
fn prop_multiplier_matches_u128_arithmetic() {
    Cases::new(25).run(|g| {
        let n = *g.pick(&[4u32, 8, 12, 16]);
        let (prog, lay) = multpim_program(n);
        let a = g.u64() & ((1 << n) - 1);
        let b = g.u64() & ((1 << n) - 1);
        let mut x = Crossbar::new(1, lay.width as usize);
        x.set_col_partitions(Partitions::new(lay.width, lay.partition_starts.clone()));
        for k in 0..n as usize {
            x.state_mut().set(0, lay.a_cols[k] as usize, (a >> k) & 1 == 1);
            x.state_mut().set(0, lay.b_cols[k] as usize, (b >> k) & 1 == 1);
        }
        x.run_program(&prog, None).unwrap();
        let mut v = 0u64;
        for i in 0..2 * n {
            if x.get(0, lay.result.col(i) as usize) {
                v |= 1 << i;
            }
        }
        assert_eq!(v, a * b, "{a}*{b} @ n={n}");
    });
}

#[test]
fn prop_encode_decode_roundtrip() {
    Cases::new(60).run(|g| {
        let n = g.usize_in(2..=12) as u32;
        let (prog, _) = ripple_adder(n);
        let flat = prog.flatten();
        let cap = flat.len() + g.usize_in(0..=64);
        let enc = encode(&prog, cap).unwrap();
        assert_eq!(decode(&enc).unwrap(), flat);
    });
}

#[test]
fn prop_ecc_single_error_always_corrected() {
    Cases::new(40).run(|g| {
        let m = *g.pick(&[8usize, 16]);
        let n = m * g.usize_in(1..=3);
        let mut rng = remus::util::rng::Pcg64::new(g.u64(), 0);
        let mut state = BitMatrix::from_fn(n, n, |_, _| rng.bernoulli(0.5));
        let mut ecc = DiagonalEcc::new(n, n, m);
        ecc.encode(&state);
        let r = g.usize_in(0..=n - 1);
        let c = g.usize_in(0..=n - 1);
        state.flip(r, c);
        let out = ecc.correct(&mut state);
        assert_eq!(out.corrected_bits, vec![(r, c)], "n={n} m={m}");
    });
}

#[test]
fn prop_ecc_incremental_equals_reencode() {
    Cases::new(30).run(|g| {
        let n = 32;
        let mut rng = remus::util::rng::Pcg64::new(g.u64(), 1);
        let mut state = BitMatrix::from_fn(n, n, |_, _| rng.bernoulli(0.5));
        let mut inc = DiagonalEcc::new(n, n, 8);
        inc.encode(&state);
        // A random sequence of column/row rewrites, tracked incrementally.
        for _ in 0..g.usize_in(1..=6) {
            if g.bool() {
                let c = g.usize_in(0..=n - 1);
                let old = state.col_bitvec(c);
                for r in 0..n {
                    state.set(r, c, g.bool());
                }
                inc.note_col_write(c, &old, &state.col_bitvec(c));
            } else {
                let r = g.usize_in(0..=n - 1);
                let old = state.row_bitvec(r);
                for c in 0..n {
                    state.set(r, c, g.bool());
                }
                inc.note_row_write(r, &old, &state.row_bitvec(r));
            }
        }
        assert!(inc.verify_all(&state).is_empty());
    });
}

#[test]
fn prop_per_bit_vote_dominates_per_element() {
    Cases::new(300).run(|g| {
        let truth = g.u64();
        // Each copy: truth with random (sparse) bit flips.
        let mut copy = |g: &mut remus::testutil::prop::Gen| {
            let mut v = truth;
            for _ in 0..g.usize_in(0..=2) {
                v ^= 1 << g.usize_in(0..=63);
            }
            v
        };
        let (a, b, c) = (copy(g), copy(g), copy(g));
        let pb = per_bit_vote_word(a, b, c);
        if let Some(pe) = per_element_vote(a, b, c) {
            assert_eq!(pb, pe, "agree when per-element defined");
        }
        // Per-bit errs only on bits where >=2 copies err together.
        let pb_err = pb ^ truth;
        assert_eq!(pb_err, (a ^ truth) & (b ^ truth) | (a ^ truth) & (c ^ truth) | (b ^ truth) & (c ^ truth));
    });
}

#[test]
fn prop_gate_eval_word_bit_consistency() {
    Cases::new(100).run(|g| {
        let (a, b, c, p) = (g.u64(), g.u64(), g.u64(), g.u64());
        for gate in Gate::ALL {
            let w = gate.eval_word(a, b, c, p);
            let i = g.usize_in(0..=63);
            let bit = |x: u64| (x >> i) & 1 == 1;
            assert_eq!(bit(w), gate.eval_bit(bit(a), bit(b), bit(c), bit(p)), "{gate:?}");
        }
    });
}

#[test]
fn prop_one_minus_pow_bounds() {
    Cases::new(200).run(|g| {
        let p = g.f64_log(1e-15, 0.5);
        let n = g.f64_in(1.0, 1e9);
        let v = one_minus_pow(p, n);
        assert!((0.0..=1.0).contains(&v));
        // Union bound: v <= n*p; and v >= p for n >= 1.
        assert!(v <= n * p * (1.0 + 1e-9));
        assert!(v >= p * 0.99 || n < 1.0);
    });
}

#[test]
fn prop_crossbar_state_untouched_outside_written_columns() {
    Cases::new(40).run(|g| {
        let rows = g.usize_in(8..=128);
        let mut rng = remus::util::rng::Pcg64::new(g.u64(), 2);
        let mut x = Crossbar::new(rows, 16);
        for r in 0..rows {
            for c in 0..16 {
                x.state_mut().set(r, c, rng.bernoulli(0.5));
            }
        }
        let snapshot = x.state().clone();
        let out = g.usize_in(4..=15) as u32;
        x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], out)), None).unwrap();
        for c in 0..16u32 {
            if c == out {
                continue;
            }
            for r in 0..rows {
                assert_eq!(x.get(r, c as usize), snapshot.get(r, c as usize), "col {c}");
            }
        }
    });
}
