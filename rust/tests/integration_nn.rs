//! Integration: the MicroNet case study — build-time-trained weights
//! running through the float reference and the full mMPU path.
//! Requires `make artifacts`.

use remus::errs::ErrorModel;
use remus::mmpu::{Mmpu, MmpuConfig, ReliabilityPolicy};
use remus::nn::micronet::{EvalSet, MicroNet};
use remus::nn::quant::{acc_to_f32, Fixed};
use remus::tmr::TmrMode;

#[test]
#[ignore = "requires build-time artifacts (weights.bin/evalset.bin); run `make artifacts` first"]
fn weights_load_and_reference_accuracy() {
    let net = MicroNet::load_default().unwrap();
    let eval = EvalSet::load_default().unwrap();
    assert_eq!(net.indim, eval.indim);
    let logits = net.forward_f32(&eval.x, eval.n);
    let acc = net.accuracy(&logits, &eval.labels);
    assert!(acc > 0.95, "build-time training must generalize: acc={acc}");
}

#[test]
#[ignore = "requires build-time artifacts (weights.bin/evalset.bin); run `make artifacts` first"]
fn mmpu_inference_clean_matches_reference_classes() {
    let net = MicroNet::load_default().unwrap();
    let eval = EvalSet::load_default().unwrap().take(16);
    let mut mmpu = Mmpu::new(MmpuConfig {
        rows: 128,
        cols: 512,
        num_crossbars: 1,
        policy: ReliabilityPolicy::none(),
        errors: ErrorModel::none(),
        seed: 3,
        ..Default::default()
    });
    let mmpu_logits = net.forward_mmpu(&mut mmpu, &eval.x, eval.n).unwrap();
    let ref_logits = net.forward_f32(&eval.x, eval.n);
    // Q8.8 quantization error is small; classifications must agree.
    let a = net.argmax(&mmpu_logits, eval.n);
    let b = net.argmax(&ref_logits, eval.n);
    assert_eq!(a, b, "clean in-memory inference matches float reference");
    // And logits are numerically close.
    for (x, y) in mmpu_logits.iter().zip(&ref_logits) {
        assert!((x - y).abs() < 0.35, "{x} vs {y}");
    }
}

#[test]
#[ignore = "requires build-time artifacts (weights.bin/evalset.bin); run `make artifacts` first"]
fn gate_errors_degrade_then_tmr_recovers() {
    let net = MicroNet::load_default().unwrap();
    let eval = EvalSet::load_default().unwrap().take(12);
    // ~2368 in-memory mults/sample x G~2.6k gates: at p = 1e-5 the
    // unprotected net is mostly wrong while TMR still classifies well
    // (at much higher p, e.g. 2e-4, even TMR collapses — see the
    // nn_inference example sweep).
    let p = 1e-5;
    let run = |tmr: TmrMode, seed: u64| -> f64 {
        let mut mmpu = Mmpu::new(MmpuConfig {
            rows: 128,
            cols: 2048,
            num_crossbars: 1,
            policy: ReliabilityPolicy { ecc_m: None, tmr },
            errors: ErrorModel::direct_only(p),
            seed,
            ..Default::default()
        });
        let logits = net.forward_mmpu(&mut mmpu, &eval.x, eval.n).unwrap();
        net.accuracy(&logits, &eval.labels)
    };
    let noisy = run(TmrMode::Off, 11);
    let tmr = run(TmrMode::Serial, 11);
    assert!(
        tmr > noisy,
        "TMR accuracy {tmr} must beat unprotected {noisy} at p={p}"
    );
    assert!(tmr > 0.5, "TMR keeps the network usable: {tmr}");
    assert!(noisy < 0.6, "unprotected must visibly degrade: {noisy}");
}

#[test]
fn quantization_path_is_sound() {
    // The Q8.8 product path used by forward_mmpu.
    let xs = [-3.5f32, 0.0, 1.25, 7.75];
    for &a in &xs {
        for &b in &xs {
            let p = acc_to_f32(Fixed::from_f32(a).product_i64(Fixed::from_f32(b)));
            assert!((p - a * b).abs() < 0.06, "{a}*{b}={p}");
        }
    }
}
