//! Integration: TMR reliability statistics on the crossbar (Fig. 3 at
//! scale) and the paper's trade-off claims measured end to end.

use remus::arith::multiplier::multpim_program;
use remus::errs::{ErrorModel, Injector};
use remus::tmr::{TmrEngine, TmrMode};
use remus::util::rng::Pcg64;
use remus::xbar::{Crossbar, Partitions};

/// Run n-bit multiply across rows under a TMR mode; count correct rows.
fn run_mult_mode(
    n: u32,
    rows: usize,
    mode: TmrMode,
    p_gate: f64,
    seed: u64,
) -> (usize, usize) {
    let (prog, lay) = multpim_program(n);
    let width = match mode {
        TmrMode::Serial => TmrEngine::serial_layout(&prog).width,
        TmrMode::Parallel => 3 * prog.width + 2 * n + 2,
        _ => prog.width,
    } as usize;
    let mut x = Crossbar::new(rows, width);
    if mode != TmrMode::Parallel && lay.partition_starts.len() > 1 {
        x.set_col_partitions(Partitions::new(width as u32, {
            let mut s = lay.partition_starts.clone();
            s.retain(|&v| (v as usize) < width);
            s
        }));
    }
    let mut rng = Pcg64::new(seed, 3);
    let items = if mode == TmrMode::SemiParallel { (rows - 1) / 3 } else { rows };
    let pairs: Vec<(u64, u64)> = (0..items)
        .map(|_| (rng.next_u64() & ((1 << n) - 1), rng.next_u64() & ((1 << n) - 1)))
        .collect();
    let reps = if mode == TmrMode::SemiParallel { 3 } else { 1 };
    let stride = if reps == 3 { items } else { 0 };
    for (i, &(a, b)) in pairs.iter().enumerate() {
        for rep in 0..reps {
            let r = i + rep * stride;
            for k in 0..n as usize {
                x.state_mut().set(r, lay.a_cols[k] as usize, (a >> k) & 1 == 1);
                x.state_mut().set(r, lay.b_cols[k] as usize, (b >> k) & 1 == 1);
            }
        }
    }
    let mut inj = Injector::new(ErrorModel::direct_only(p_gate), seed, 1);
    let run = TmrEngine::new(mode).execute(&mut x, &prog, Some(&mut inj)).unwrap();
    let correct = pairs
        .iter()
        .enumerate()
        .filter(|&(i, &(a, b))| {
            let mut v = 0u64;
            for (k, &c) in run.output_cols.iter().enumerate() {
                if x.get(i, c as usize) {
                    v |= 1 << k;
                }
            }
            v == a * b
        })
        .count();
    (correct, items)
}

#[test]
fn serial_tmr_statistically_beats_baseline() {
    // p chosen so the baseline fails often but single-copy errors stay
    // mostly isolated — TMR's sweet spot (Fig. 3b).
    let p = 3e-5;
    let mut base_ok = 0;
    let mut tmr_ok = 0;
    let mut total = 0;
    for seed in 0..6 {
        let (c1, t) = run_mult_mode(8, 128, TmrMode::Off, p, seed);
        let (c2, _) = run_mult_mode(8, 128, TmrMode::Serial, p, seed + 100);
        base_ok += c1;
        tmr_ok += c2;
        total += t;
    }
    let base_fail = total - base_ok;
    let tmr_fail = total - tmr_ok;
    assert!(base_fail > 0, "baseline must fail at p={p} over {total} rows");
    assert!(
        (tmr_fail as f64) < (base_fail as f64) * 0.5,
        "TMR {tmr_fail} vs baseline {base_fail} failures"
    );
}

#[test]
fn semi_parallel_tmr_also_corrects() {
    let p = 3e-5;
    let mut base_fail = 0usize;
    let mut semi_fail = 0usize;
    for seed in 0..6 {
        let (c1, t1) = run_mult_mode(8, 127, TmrMode::Off, p, seed);
        let (c2, t2) = run_mult_mode(8, 127, TmrMode::SemiParallel, p, seed + 50);
        base_fail += t1 - c1;
        semi_fail += t2 - c2;
    }
    assert!(base_fail > 0);
    assert!(semi_fail * 3 < base_fail * 2, "semi {semi_fail} vs base/3 {base_fail}");
}

#[test]
fn clean_runs_identical_across_modes() {
    for mode in [TmrMode::Off, TmrMode::Serial, TmrMode::SemiParallel] {
        let (correct, items) = run_mult_mode(8, 64, mode, 0.0, 7);
        assert_eq!(correct, items, "{mode:?} must be exact without errors");
    }
}

#[test]
fn measured_tradeoffs_on_multiplier() {
    // The §V headline, measured on the real multiplier program.
    let (prog, _) = multpim_program(8);
    let base_width = TmrEngine::serial_layout(&prog).width as usize;
    let mut xb = Crossbar::new(16, base_width);
    let base = TmrEngine::new(TmrMode::Off).execute(&mut xb, &prog, None).unwrap();
    let mut xs = Crossbar::new(16, base_width);
    let serial = TmrEngine::new(TmrMode::Serial).execute(&mut xs, &prog, None).unwrap();
    let ratio = serial.cycles as f64 / base.cycles as f64;
    assert!((2.7..3.5).contains(&ratio), "serial latency x{ratio}");
    // Serial area stays ~1x: the extra columns are only 4 output copies.
    assert!((serial.area_cols as f64) < 1.4 * prog.width as f64);
    // Semi-parallel: area identical, items/run = (rows-1)/3.
    let mut xsp = Crossbar::new(31, prog.width as usize);
    for r in 0..31 {
        for k in 0..8 {
            // load zeros — we only check accounting here
            let _ = r;
            let _ = k;
        }
    }
    let semi = TmrEngine::new(TmrMode::SemiParallel).execute(&mut xsp, &prog, None).unwrap();
    assert_eq!(semi.area_cols, prog.width);
    assert_eq!(semi.items, 10);
}
