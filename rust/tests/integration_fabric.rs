//! Integration: the fabric subsystem end to end over real threads and
//! loopback sockets (ISSUE 3 acceptance) — sharded serving bit-identical
//! to the in-process coordinator, health-driven failover with zero lost
//! replies, and merged fleet metrics.

use std::time::Duration;

use remus::coordinator::{Coordinator, CoordinatorConfig, Submitter};
use remus::fabric::{probe_health, shutdown_endpoint, FabricServer, Router};
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::FunctionKind;

fn shard_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 512,
        max_batch: 16,
        max_wait: Duration::from_millis(5),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

/// Candidate kinds that all fit the 32x512 shard shape. The ring is a
/// deterministic function of (kind, shard index), so which shard serves
/// which kind is stable across runs — the tests pick kinds per shard
/// dynamically instead of hard-coding hash outcomes.
fn candidate_kinds() -> Vec<FunctionKind> {
    (4..=16).flat_map(|n| [FunctionKind::Add(n), FunctionKind::Xor(n)]).collect()
}

fn kind_on_shard(router: &Router, shard: usize) -> FunctionKind {
    *candidate_kinds()
        .iter()
        .find(|&&k| router.shard_for(k) == Some(shard))
        .unwrap_or_else(|| panic!("no candidate kind routes to shard {shard}"))
}

/// Submit the whole sequence, then collect every reply (a lost reply
/// fails the `recv_timeout`). Asserts values, returns them.
fn run_checked(sub: &dyn Submitter, reqs: &[(FunctionKind, u64, u64)]) -> Vec<u64> {
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| sub.submit(k, a, b)).collect();
    reqs.iter()
        .zip(rxs)
        .enumerate()
        .map(|(i, (&(kind, a, b), rx))| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} lost its reply: {e}"));
            assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
            assert_eq!(r.value, kind.reference(a, b), "request {i} ({kind:?} {a} {b})");
            r.value
        })
        .collect()
}

#[test]
fn loopback_two_shards_bit_identical_to_in_process() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();

    // Two kinds per shard so the load genuinely exercises both servers.
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);
    assert_ne!(router.shard_for(k0), router.shard_for(k1));

    // >= 1000 requests sharded across the fleet. ErrorModel is none and
    // wear immortal, so the value stream is exact arithmetic — the
    // fabric must reproduce the in-process run bit for bit.
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..1200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();
    let fabric_values = run_checked(&router, &reqs);

    // The same request sequence through one in-process coordinator with
    // the same seed/config as shard 0.
    let coord = Coordinator::start(shard_cfg(0xA)).unwrap();
    let local_values = run_checked(&coord, &reqs);
    coord.shutdown();
    assert_eq!(fabric_values, local_values, "fabric must be bit-identical to in-process");

    // Merged fleet metrics cover both shards' workers and request flow.
    let m = router.metrics();
    assert_eq!(m.worker_health.len(), 4, "2 shards x 2 workers in the merged snapshot");
    assert_eq!(m.completed, 1200);
    assert_eq!(m.retired_workers(), 0);
    assert!(
        m.worker_health.iter().any(|w| w.scrubs > 0),
        "§Health scrubbing must run inside the shards"
    );

    // Health probe over the wire agrees.
    for addr in &addrs {
        let (serving, workers, routable, retired) = probe_health(addr).unwrap();
        assert!(serving);
        assert_eq!(workers, 2);
        assert_eq!(routable, 2);
        assert_eq!(retired, 0);
    }

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}

#[test]
fn shard_retirement_fails_over_with_zero_lost_replies() {
    // Shard 0 healthy; shard 1's single worker gets a lethal endurance
    // budget: after its first batch the march scrub detects the worn
    // crossbar and retires it (same §Health mechanics as
    // integration_coordinator::wear_out_retires_crossbar_and_errors_explicitly).
    // Its queued requests come back as capacity errors, which the router
    // must convert into failover — every request resolves with the
    // correct value, none are lost, and the merged snapshot shows the
    // retirement.
    let healthy = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let dying_cfg = CoordinatorConfig {
        workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        seed: 0xB,
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    };
    let dying = FabricServer::start("127.0.0.1:0", dying_cfg).unwrap();
    let addrs = vec![healthy.local_addr().to_string(), dying.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1); // drives the dying shard

    let reqs: Vec<(FunctionKind, u64, u64)> = (0..600u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 13, (i * 5) % 13))
        .collect();
    // run_checked asserts: every reply arrives (zero lost), none is an
    // error (capacity errors were failed over, not delivered), and all
    // values are correct.
    run_checked(&router, &reqs);

    // The dying shard dropped out of routing: its kind now routes to
    // the survivor.
    assert_eq!(router.live_shards(), 1);
    assert_eq!(router.shard_for(k1), Some(0));

    // Merged fleet health reflects both shards, including the
    // retirement on the (still metrics-reachable) dying shard.
    let m = router.metrics();
    assert_eq!(m.worker_health.len(), 3, "2 + 1 workers in the merged snapshot");
    assert_eq!(m.retired_workers(), 1, "the worn crossbar's retirement is fleet-visible");
    let (serving, _, routable, retired) = probe_health(&addrs[1]).unwrap();
    assert!(!serving, "retire-all must flip the shard's is_serving probe");
    assert_eq!(routable, 0);
    assert_eq!(retired, 1);

    router.shutdown();
    healthy.shutdown();
    dying.shutdown();
}

#[test]
fn shard_disconnect_reroutes_in_flight_requests() {
    // Socket-level failure (no graceful capacity error): shard 1 is
    // shut down while requests are in flight. The router's reader sees
    // the disconnect, drains that shard's pending table, and re-routes
    // everything to the survivor — zero lost replies.
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0x1)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0x2)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();
    let k1 = kind_on_shard(&router, 1);

    // A burst aimed at shard 1, with the kill racing the stream: some
    // requests complete there, some are re-executed on shard 0 after
    // the disconnect (deterministic functions make replays safe).
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..400u64).map(|i| (k1, i % 17, (i * 3) % 17)).collect();
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| router.submit(k, a, b)).collect();
    s2.shutdown();
    for (i, (&(kind, a, b), rx)) in reqs.iter().zip(&rxs).enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("request {i} lost across the disconnect: {e}"));
        assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
        assert_eq!(r.value, kind.reference(a, b), "request {i}");
    }
    // Subsequent traffic keeps flowing on the survivor.
    let more: Vec<(FunctionKind, u64, u64)> =
        (0..50u64).map(|i| (k1, i % 17, (i * 3) % 17)).collect();
    run_checked(&router, &more);
    assert_eq!(router.live_shards(), 1);

    router.shutdown();
    s1.shutdown();
}

#[test]
fn remote_shutdown_frame_stops_a_server() {
    let server = FabricServer::start("127.0.0.1:0", shard_cfg(0x5)).unwrap();
    let addr = server.local_addr().to_string();
    assert!(!server.is_stopped());
    shutdown_endpoint(&addr).unwrap();
    server.wait(); // returns promptly once the frame lands
    assert!(server.is_stopped());
    server.shutdown();
}
