//! Integration: the fabric subsystem end to end over real threads and
//! loopback sockets (ISSUE 3 + ISSUE 4 + ISSUE 5 acceptance) — sharded
//! serving bit-identical to the in-process coordinator, health-driven
//! failover with zero lost replies, merged fleet metrics, and the
//! self-healing membership machinery: shard revival after a
//! kill/restart, registration-based discovery, hot-spare shard pools,
//! the bounded submit retry window during a total outage, data-path
//! heartbeat detection of half-open shards, re-registration across a
//! *router* restart, and the open-loop load generator over the fabric.
//!
//! Every fleet here builds its server and router configs through
//! `..Default::default()`, whose data plane follows the
//! `REMUS_DATA_PLANE` environment variable — so the whole suite
//! re-runs unchanged under the epoll reactor (`REMUS_DATA_PLANE=epoll
//! cargo test`; CI runs the key scenarios both ways).

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use remus::coordinator::{Coordinator, CoordinatorConfig, Submitter};
use remus::fabric::wire::{read_msg, write_msg, Msg};
use remus::fabric::{
    loadgen, probe_health, shutdown_endpoint, DataPlane, FabricServer, Router, RouterConfig,
    ServeOptions,
};
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::FunctionKind;

fn shard_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 512,
        max_batch: 16,
        max_wait: Duration::from_millis(5),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

/// Candidate kinds that all fit the 32x512 shard shape. The ring is a
/// deterministic function of (kind, shard index), so which shard serves
/// which kind is stable across runs — the tests pick kinds per shard
/// dynamically instead of hard-coding hash outcomes.
fn candidate_kinds() -> Vec<FunctionKind> {
    (4..=16).flat_map(|n| [FunctionKind::Add(n), FunctionKind::Xor(n)]).collect()
}

fn kind_on_shard(router: &Router, shard: usize) -> FunctionKind {
    *candidate_kinds()
        .iter()
        .find(|&&k| router.shard_for(k) == Some(shard))
        .unwrap_or_else(|| panic!("no candidate kind routes to shard {shard}"))
}

/// Submit the whole sequence, then collect every reply (a lost reply
/// fails the `recv_timeout`). Asserts values, returns them.
fn run_checked(sub: &dyn Submitter, reqs: &[(FunctionKind, u64, u64)]) -> Vec<u64> {
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| sub.submit(k, a, b)).collect();
    reqs.iter()
        .zip(rxs)
        .enumerate()
        .map(|(i, (&(kind, a, b), rx))| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} lost its reply: {e}"));
            assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
            assert_eq!(r.value, kind.reference(a, b), "request {i} ({kind:?} {a} {b})");
            r.value
        })
        .collect()
}

#[test]
fn loopback_two_shards_bit_identical_to_in_process() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();

    // Two kinds per shard so the load genuinely exercises both servers.
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);
    assert_ne!(router.shard_for(k0), router.shard_for(k1));

    // >= 1000 requests sharded across the fleet. ErrorModel is none and
    // wear immortal, so the value stream is exact arithmetic — the
    // fabric must reproduce the in-process run bit for bit.
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..1200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();
    let fabric_values = run_checked(&router, &reqs);

    // The same request sequence through one in-process coordinator with
    // the same seed/config as shard 0.
    let coord = Coordinator::start(shard_cfg(0xA)).unwrap();
    let local_values = run_checked(&coord, &reqs);
    coord.shutdown();
    assert_eq!(fabric_values, local_values, "fabric must be bit-identical to in-process");

    // Merged fleet metrics cover both shards' workers and request flow.
    let m = router.metrics();
    assert_eq!(m.worker_health.len(), 4, "2 shards x 2 workers in the merged snapshot");
    assert_eq!(m.completed, 1200);
    assert_eq!(m.retired_workers(), 0);
    assert!(
        m.worker_health.iter().any(|w| w.scrubs > 0),
        "§Health scrubbing must run inside the shards"
    );

    // Health probe over the wire agrees.
    for addr in &addrs {
        let (serving, workers, routable, retired) = probe_health(addr).unwrap();
        assert!(serving);
        assert_eq!(workers, 2);
        assert_eq!(routable, 2);
        assert_eq!(retired, 0);
    }

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}

#[test]
fn shard_retirement_fails_over_with_zero_lost_replies() {
    // Shard 0 healthy; shard 1's single worker gets a lethal endurance
    // budget: after its first batch the march scrub detects the worn
    // crossbar and retires it (same §Health mechanics as
    // integration_coordinator::wear_out_retires_crossbar_and_errors_explicitly).
    // Its queued requests come back as capacity errors, which the router
    // must convert into failover — every request resolves with the
    // correct value, none are lost, and the merged snapshot shows the
    // retirement.
    let healthy = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let dying_cfg = CoordinatorConfig {
        workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        seed: 0xB,
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    };
    let dying = FabricServer::start("127.0.0.1:0", dying_cfg).unwrap();
    let addrs = vec![healthy.local_addr().to_string(), dying.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1); // drives the dying shard

    let reqs: Vec<(FunctionKind, u64, u64)> = (0..600u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 13, (i * 5) % 13))
        .collect();
    // run_checked asserts: every reply arrives (zero lost), none is an
    // error (capacity errors were failed over, not delivered), and all
    // values are correct.
    run_checked(&router, &reqs);

    // The dying shard dropped out of routing: its kind now routes to
    // the survivor.
    assert_eq!(router.live_shards(), 1);
    assert_eq!(router.shard_for(k1), Some(0));

    // Merged fleet health reflects both shards, including the
    // retirement on the (still metrics-reachable) dying shard.
    let m = router.metrics();
    assert_eq!(m.worker_health.len(), 3, "2 + 1 workers in the merged snapshot");
    assert_eq!(m.retired_workers(), 1, "the worn crossbar's retirement is fleet-visible");
    let (serving, _, routable, retired) = probe_health(&addrs[1]).unwrap();
    assert!(!serving, "retire-all must flip the shard's is_serving probe");
    assert_eq!(routable, 0);
    assert_eq!(retired, 1);

    router.shutdown();
    healthy.shutdown();
    dying.shutdown();
}

#[test]
fn shard_disconnect_reroutes_in_flight_requests() {
    // Socket-level failure (no graceful capacity error): shard 1 is
    // shut down while requests are in flight. The router's reader sees
    // the disconnect, drains that shard's pending table, and re-routes
    // everything to the survivor — zero lost replies.
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0x1)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0x2)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();
    let k1 = kind_on_shard(&router, 1);

    // A burst aimed at shard 1, with the kill racing the stream: some
    // requests complete there, some are re-executed on shard 0 after
    // the disconnect (deterministic functions make replays safe).
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..400u64).map(|i| (k1, i % 17, (i * 3) % 17)).collect();
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| router.submit(k, a, b)).collect();
    s2.shutdown();
    for (i, (&(kind, a, b), rx)) in reqs.iter().zip(&rxs).enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("request {i} lost across the disconnect: {e}"));
        assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
        assert_eq!(r.value, kind.reference(a, b), "request {i}");
    }
    // Subsequent traffic keeps flowing on the survivor.
    let more: Vec<(FunctionKind, u64, u64)> =
        (0..50u64).map(|i| (k1, i % 17, (i * 3) % 17)).collect();
    run_checked(&router, &more);
    assert_eq!(router.live_shards(), 1);

    router.shutdown();
    s1.shutdown();
}

/// A fast-reviving router config for the self-healing tests.
fn fast_cfg(listen: bool) -> RouterConfig {
    RouterConfig {
        probe_period: Duration::from_millis(50),
        retry_window: Duration::from_millis(2000),
        listen: listen.then(|| "127.0.0.1:0".to_string()),
        ..Default::default()
    }
}

/// Rebind a fabric server on an exact address, retrying briefly (the
/// kernel may hold the port for a moment after the old process/listener
/// goes away).
fn restart_server(addr: &str, cfg: CoordinatorConfig) -> FabricServer {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match FabricServer::start(addr, cfg.clone()) {
            Ok(s) => return s,
            Err(e) => {
                assert!(Instant::now() < deadline, "could not rebind {addr}: {e:#}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

fn wait_until(what: &str, timeout: Duration, mut done: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !done() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// ISSUE 4 acceptance: a 2-shard fleet with one shard killed and
/// restarted mid-run completes 1200 requests with zero lost replies,
/// values bit-identical to an uninterrupted in-process run, and the
/// revived shard returns to its exact ring slot.
#[test]
fn killed_and_restarted_shard_revives_bit_identically() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(false)).unwrap();
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);
    let walk_before: Vec<Vec<usize>> =
        candidate_kinds().iter().map(|&k| router.ring_walk(k)).collect();
    let epoch0 = router.membership_epoch();

    let reqs: Vec<(FunctionKind, u64, u64)> = (0..1200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();

    // Phase 1: healthy fleet.
    let mut values = run_checked(&router, &reqs[..400]);
    // Kill shard 1 (server gone, connections die); the fleet keeps
    // serving through the outage with zero lost replies.
    s2.shutdown();
    wait_until("shard 1 marked down", Duration::from_secs(10), || router.live_shards() == 1);
    let degraded = router.metrics();
    assert_eq!(degraded.shards_total, 2, "down shards still count in the fleet view");
    assert_eq!(degraded.shards_down, 1, "a degraded fleet must not look healthy");
    values.extend(run_checked(&router, &reqs[400..800]));

    // Restart on the same address: the supervisor's probe revives it
    // into its original slot — placement is bit-identical to never
    // having failed.
    let s2b = restart_server(&addrs[1], shard_cfg(0xB));
    wait_until("shard 1 revived", Duration::from_secs(10), || router.live_shards() == 2);
    assert_eq!(router.shard_for(k1), Some(1), "revived shard reclaims its kinds");
    let walk_after: Vec<Vec<usize>> =
        candidate_kinds().iter().map(|&k| router.ring_walk(k)).collect();
    assert_eq!(walk_after, walk_before, "ring placement identical after down/revive");
    assert!(router.membership_epoch() >= epoch0 + 2, "down + revive both bump the epoch");

    // Phase 3: the revived shard serves again.
    values.extend(run_checked(&router, &reqs[800..]));
    let m = router.metrics();
    assert_eq!(m.shards_down, 0);
    // The restart reset shard 1's process-local counters (its 200
    // phase-1 completions died with the old process); the survivor +
    // revived shard still account for everything since.
    assert!(m.completed >= 1000, "fleet view covers the post-restart work: {}", m.completed);

    // Bit-identical to one uninterrupted in-process coordinator run of
    // the same sequence (ErrorModel none + immortal wear: exact
    // arithmetic end to end).
    let coord = Coordinator::start(shard_cfg(0xA)).unwrap();
    let local = run_checked(&coord, &reqs);
    coord.shutdown();
    assert_eq!(values, local, "kill/restart run must be bit-identical to uninterrupted");

    router.shutdown();
    s1.shutdown();
    s2b.shutdown();
}

/// ISSUE 4 acceptance: a router with *no* static shard list serves from
/// registration alone — including a request submitted before any shard
/// exists, held by the retry window until the first registrant lands.
#[test]
fn registration_only_router_serves_without_static_shards() {
    let mut cfg = fast_cfg(true);
    cfg.retry_window = Duration::from_secs(8);
    let router = Router::with_config(&[], cfg).unwrap();
    let reg = router.registration_addr().expect("listener requested").to_string();
    assert_eq!(router.shard_count(), 0);

    // Submitted into the void: parked, not failed.
    let early_kind = FunctionKind::Add(8);
    let early = router.submit(early_kind, 19, 23);

    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    s1.register_with(&reg, "alpha", false);
    assert!(router.wait_for_live(1, Duration::from_secs(10)), "registered shard comes live");
    assert_eq!(router.shard_count(), 1);

    let r = early.recv_timeout(Duration::from_secs(10)).expect("parked request resolves");
    assert!(r.is_ok(), "parked request served after registration: {:?}", r.error);
    assert_eq!(r.value, early_kind.reference(19, 23));

    let k = kind_on_shard(&router, 0);
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..100u64).map(|i| (k, i % 97, (i * 3) % 97)).collect();
    run_checked(&router, &reqs);
    let m = router.metrics();
    assert_eq!((m.shards_total, m.shards_down), (1, 0));
    assert_eq!(m.completed, 101);

    router.shutdown();
    s1.shutdown();
}

/// Satellite: during a total outage `submit` waits out a bounded retry
/// window instead of failing instantly — recovering when a shard
/// revives in time, and resolving to an explicit error (only) once the
/// deadline is exhausted.
#[test]
fn submit_retry_window_recovers_or_expires() {
    let server = FabricServer::start("127.0.0.1:0", shard_cfg(0x7)).unwrap();
    let addr = server.local_addr().to_string();
    let cfg = fast_cfg(false);
    let window = cfg.retry_window;
    let router = Router::with_config(&[addr.clone()], cfg).unwrap();
    let k = kind_on_shard(&router, 0);
    run_checked(&router, &[(k, 3, 4)]);

    // Total outage.
    server.shutdown();
    wait_until("outage detected", Duration::from_secs(10), || router.live_shards() == 0);

    // Recovered path: the request parks, the shard revives inside the
    // window, and the reply carries the correct value.
    let rx = router.submit(k, 5, 6);
    let revived = restart_server(&addr, shard_cfg(0x7));
    let r = rx.recv_timeout(Duration::from_secs(10)).expect("parked request resolves");
    assert!(r.is_ok(), "recovered within the window: {:?}", r.error);
    assert_eq!(r.value, k.reference(5, 6));

    // Exhausted path: no revival this time — the request resolves to an
    // explicit error, and only after the window has genuinely elapsed.
    revived.shutdown();
    wait_until("second outage detected", Duration::from_secs(10), || router.live_shards() == 0);
    let t0 = Instant::now();
    let rx = router.submit(k, 7, 8);
    let r = rx.recv_timeout(Duration::from_secs(10)).expect("expired request resolves");
    assert!(!r.is_ok(), "no shard ever revived");
    let msg = r.error.as_deref().unwrap();
    assert!(msg.contains("retry window"), "error names the window: {msg:?}");
    assert!(
        t0.elapsed() >= window - Duration::from_millis(100),
        "errored only after the window: {:?} < {window:?}",
        t0.elapsed()
    );

    router.shutdown();
}

/// Satellite (hot-spare pools + ring property): a registered spare
/// stays out of the ring until a member fails, covers it while down,
/// and demotes on revival — with the ring walk of every FunctionKind
/// bit-identical before the failure and after the revival.
#[test]
fn spare_shard_promotes_on_failure_and_ring_is_identical_after_revival() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(true)).unwrap();
    let reg = router.registration_addr().unwrap().to_string();
    let spare = FabricServer::start("127.0.0.1:0", shard_cfg(0xC)).unwrap();
    spare.register_with(&reg, "spare0", true);
    assert!(router.wait_for_live(3, Duration::from_secs(10)), "spare connects warm");
    assert_eq!(router.shard_count(), 3);

    // Every FunctionKind the fleet can express: the idle spare (index
    // 2) appears on no walk.
    let all_kinds: Vec<FunctionKind> = (1..=32)
        .flat_map(|b| {
            [
                FunctionKind::Add(b),
                FunctionKind::Mul(b),
                FunctionKind::MulNaive(b),
                FunctionKind::Xor(b),
            ]
        })
        .collect();
    let before: Vec<Vec<usize>> = all_kinds.iter().map(|&k| router.ring_walk(k)).collect();
    for w in &before {
        assert!(!w.contains(&2), "idle spare must stay out of the ring: {w:?}");
    }
    let k1 = kind_on_shard(&router, 1);

    // Member 1 fails: the spare is promoted and traffic keeps flowing
    // with zero lost replies.
    s2.shutdown();
    wait_until("spare promoted", Duration::from_secs(10), || {
        all_kinds.iter().any(|&k| router.ring_walk(k).contains(&2))
    });
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..200u64).map(|i| (k1, i % 89, (i * 5) % 89)).collect();
    run_checked(&router, &reqs);
    assert_eq!(router.metrics().shards_down, 1);

    // Member 1 revives: the spare demotes and the walk of every kind is
    // bit-identical to never having failed.
    let s2b = restart_server(&addrs[1], shard_cfg(0xB));
    wait_until("member revived + spare demoted", Duration::from_secs(10), || {
        router.live_shards() == 3
            && all_kinds.iter().all(|&k| !router.ring_walk(k).contains(&2))
    });
    let after: Vec<Vec<usize>> = all_kinds.iter().map(|&k| router.ring_walk(k)).collect();
    assert_eq!(after, before, "down/revive cycle must not move any kind");
    assert_eq!(router.shard_for(k1), Some(1));

    router.shutdown();
    s1.shutdown();
    s2b.shutdown();
    spare.shutdown();
}

/// Satellite (process-level kill/restart): `fabric-soak --chaos-kill`
/// SIGKILLs one shard *process* mid-run, restarts it, and proves zero
/// lost replies and zero wrong values (every reply is checked against
/// the arithmetic oracle, so with ErrorModel::none the values are
/// bit-identical to an uninterrupted run). Also exercises a registered
/// hot-spare child end to end.
#[test]
fn fabric_soak_chaos_kill_restart_loses_nothing() {
    let exe = env!("CARGO_BIN_EXE_remus");
    let out = std::process::Command::new(exe)
        .args([
            "fabric-soak",
            "--shards",
            "2",
            "--workers",
            "2",
            "--requests",
            "3000",
            "--chaos-kill",
            "--spare-shards",
            "1",
        ])
        .output()
        .expect("spawn remus fabric-soak");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "fabric-soak --chaos-kill failed\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("CHAOS-OK requests=3000 ok=3000 wrong=0 error_results=0"),
        "missing the zero-loss proof line\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("chaos: revived shard 0"),
        "revival not reported\nstdout:\n{stdout}\nstderr:\n{stderr}"
    );
    assert!(
        stdout.contains("spares: 1 hot-spare shard(s) registered and connected"),
        "spare registration not reported\nstdout:\n{stdout}"
    );
}

/// ISSUE 5 acceptance: a half-open shard — registration completed,
/// health probes answered, every submit and ping swallowed, nothing
/// ever written back — produces no reader EOF and no write error, so
/// only the data-path heartbeat can catch it. It must be marked down
/// within 2 heartbeat periods, its in-flight requests replayed on the
/// live shard with zero lost replies (values bit-identical to a
/// healthy fleet), and the merged snapshot must show the down-mark and
/// the heartbeat timeout.
#[test]
fn half_open_shard_detected_by_heartbeats_and_failed_over() {
    let healthy = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let hb_period = Duration::from_millis(600);
    let cfg = RouterConfig {
        probe_period: Duration::from_millis(50),
        retry_window: Duration::from_millis(2000),
        listen: Some("127.0.0.1:0".to_string()),
        heartbeat_period: hb_period,
        heartbeat_timeout: Duration::from_millis(600),
        ..Default::default()
    };
    let router = Router::with_config(&[healthy.local_addr().to_string()], cfg).unwrap();
    let reg = router.registration_addr().unwrap().to_string();

    // The stub: a wedged process. It answers health probes until its
    // data path has seen any traffic (so registration-driven discovery
    // completes and the router opens the data connection), then
    // swallows everything on every connection — submits, pings, and
    // further control probes — while keeping the sockets open.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let stub_addr = listener.local_addr().unwrap().to_string();
    let wedged = Arc::new(AtomicBool::new(false));
    {
        let wedged = wedged.clone();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut c) = conn else { return };
                let wedged = wedged.clone();
                std::thread::spawn(move || loop {
                    match read_msg(&mut c) {
                        Ok(Some(Msg::HealthReq)) if !wedged.load(Ordering::SeqCst) => {
                            let reply = Msg::HealthReply {
                                serving: true,
                                workers: 1,
                                routable: 1,
                                retired: 0,
                            };
                            if write_msg(&mut c, &reply).is_err() {
                                return;
                            }
                        }
                        Ok(Some(Msg::Submit { .. })) | Ok(Some(Msg::Ping { .. })) => {
                            wedged.store(true, Ordering::SeqCst);
                        }
                        Ok(Some(_)) => {}
                        Ok(None) | Err(_) => return,
                    }
                });
            }
        });
    }
    // Complete the stub's registration by hand (a real shard's
    // register_with loop does exactly this).
    {
        let mut s = TcpStream::connect(&reg).unwrap();
        let announce = Msg::Register {
            name: "halfopen".into(),
            addr: stub_addr.clone(),
            spare: false,
            prev: None,
        };
        write_msg(&mut s, &announce).unwrap();
        match read_msg(&mut s).unwrap() {
            Some(Msg::Welcome { shard, active }) => {
                assert_eq!(shard, 1, "registered after the static shard");
                assert!(active);
            }
            other => panic!("unexpected registration reply: {other:?}"),
        }
    }
    assert!(router.wait_for_live(2, Duration::from_secs(10)), "stub's data connection opens");
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1); // routes to the half-open stub

    // Submit while the stub is still nominally up: the k1 half lands in
    // its pending table and must be replayed, not lost.
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..400u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| router.submit(k, a, b)).collect();

    // Detection bound: within 2 heartbeat periods of the connection
    // going silent (it swallowed from the very first ping).
    wait_until("half-open shard marked down within 2 heartbeat periods", 2 * hb_period, || {
        router.live_shards() == 1
    });
    assert_eq!(router.shard_for(k1), Some(0), "stub's kinds fail over to the live shard");

    // Zero lost replies, every value correct.
    let values: Vec<u64> = reqs
        .iter()
        .zip(&rxs)
        .enumerate()
        .map(|(i, (&(kind, a, b), rx))| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} lost across the half-open shard: {e}"));
            assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
            assert_eq!(r.value, kind.reference(a, b), "request {i}");
            r.value
        })
        .collect();

    // Bit-identical to a healthy fleet: one in-process coordinator with
    // the live shard's config serves the same stream.
    let coord = Coordinator::start(shard_cfg(0xA)).unwrap();
    let local = run_checked(&coord, &reqs);
    coord.shutdown();
    assert_eq!(values, local, "half-open failover must not change a single value");

    // The merged snapshot shows the down-mark and names the cause.
    let m = router.metrics();
    assert_eq!(m.shards_total, 2);
    assert_eq!(m.shards_down, 1, "the half-open shard stays down (its probes are swallowed)");
    assert!(m.hb_pings >= 1, "heartbeats were sent");
    assert!(m.hb_timeouts >= 1, "the down-mark came from a heartbeat deadline");
    assert!(m.hb_pongs >= 1, "the healthy shard answered its pings");
    assert_eq!(m.completed, 400, "the live shard absorbed the whole load");

    router.shutdown();
    healthy.shutdown();
}

/// ISSUE 5 acceptance: when the *router* restarts, every shard
/// (members and spares) re-registers through its background refresh
/// loop, each reclaiming the slot index its old `Welcome` assigned —
/// so the new router's ring walk is bit-identical for every
/// `FunctionKind`, and a request submitted before re-registration
/// (parked inside the retry window) completes.
#[test]
fn router_restart_shards_reregister_and_ring_rebuilds_bit_identically() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let spare = FabricServer::start("127.0.0.1:0", shard_cfg(0xC)).unwrap();
    let router_a = Router::with_config(&[], fast_cfg(true)).unwrap();
    let reg = router_a.registration_addr().unwrap().to_string();
    // Sequential registration pins the slot order: alpha=0, beta=1,
    // spare0=2 (first registration wins a fresh slot; the restart below
    // must reproduce these indices in *any* re-registration order).
    s1.register_with(&reg, "alpha", false);
    assert!(router_a.wait_for_live(1, Duration::from_secs(10)));
    s2.register_with(&reg, "beta", false);
    assert!(router_a.wait_for_live(2, Duration::from_secs(10)));
    spare.register_with(&reg, "spare0", true);
    assert!(router_a.wait_for_live(3, Duration::from_secs(10)));

    let all_kinds: Vec<FunctionKind> = (1..=32)
        .flat_map(|b| {
            [
                FunctionKind::Add(b),
                FunctionKind::Mul(b),
                FunctionKind::MulNaive(b),
                FunctionKind::Xor(b),
            ]
        })
        .collect();
    let walks_a: Vec<Vec<usize>> = all_kinds.iter().map(|&k| router_a.ring_walk(k)).collect();
    let addrs_a = router_a.shard_addrs();
    let k0 = kind_on_shard(&router_a, 0);
    let k1 = kind_on_shard(&router_a, 1);
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();
    run_checked(&router_a, &reqs);

    // The router process "dies": connections drop, registration port
    // closes, all membership state is lost.
    router_a.shutdown();

    // Its replacement binds the same registration port with an empty
    // fleet (brief retry: the kernel may hold the just-closed port for
    // a moment, as with restart_server above). A request submitted
    // before any shard re-registers parks inside the retry window
    // instead of failing.
    let mut cfg = fast_cfg(false);
    cfg.listen = Some(reg.clone());
    cfg.retry_window = Duration::from_secs(10);
    let router_b = {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match Router::with_config(&[], cfg.clone()) {
                Ok(r) => break r,
                Err(e) => {
                    assert!(Instant::now() < deadline, "could not rebind {reg}: {e:#}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    };
    assert_eq!(router_b.shard_count(), 0, "a restarted router starts from nothing");
    let early = router_b.submit(k0, 19, 23);

    assert!(
        router_b.wait_for_live(3, Duration::from_secs(10)),
        "every shard re-registers on its own (refresh loop), incl. the spare"
    );
    let r = early.recv_timeout(Duration::from_secs(10)).expect("parked request resolves");
    assert!(r.is_ok(), "parked submit served after re-registration: {:?}", r.error);
    assert_eq!(r.value, k0.reference(19, 23));

    // Identical membership: same slot indices, same endpoints, and a
    // ring walk bit-identical for every kind the fleet can express.
    assert_eq!(router_b.shard_count(), 3);
    assert_eq!(router_b.shard_addrs(), addrs_a, "each shard reclaimed its exact slot");
    let walks_b: Vec<Vec<usize>> = all_kinds.iter().map(|&k| router_b.ring_walk(k)).collect();
    assert_eq!(walks_b, walks_a, "rebuilt ring must be bit-identical to the old router's");
    assert_eq!(router_b.shard_for(k0), Some(0));
    assert_eq!(router_b.shard_for(k1), Some(1));
    for w in &walks_b {
        assert!(!w.contains(&2), "the re-registered spare stays out of the ring");
    }

    // And the rebuilt fleet serves the same stream correctly.
    run_checked(&router_b, &reqs);
    let m = router_b.metrics();
    assert_eq!((m.shards_total, m.shards_down), (3, 0));

    router_b.shutdown();
    s1.shutdown();
    s2.shutdown();
    spare.shutdown();
}

/// ISSUE 5 satellite: the open-loop generator drives a sharded fleet
/// through the router, verifies every reply against the arithmetic
/// oracle, and its per-kind histograms account for every request.
#[test]
fn open_loop_loadgen_over_the_fabric_verifies_all_replies() {
    let s1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let s2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::connect(&addrs).unwrap();
    let cfg = loadgen::LoadgenConfig {
        qps: 5000.0,
        requests: 1000,
        seed: 0x5EED,
        window: 256,
        kinds: vec![kind_on_shard(&router, 0), kind_on_shard(&router, 1)],
    };
    // Determinism holds end to end, not just in the unit tests: the
    // schedule regenerates bit-identically while the fleet is live.
    assert_eq!(loadgen::schedule(&cfg), loadgen::schedule(&cfg));

    let rep = loadgen::run(&router, &cfg);
    assert_eq!(rep.requests, 1000);
    assert_eq!(rep.ok, 1000, "wrong={} errors={}", rep.wrong, rep.errors);
    assert_eq!(rep.wrong + rep.errors, 0);
    let per_kind_total: u64 = rep.kinds.iter().map(|(_, k)| k.hist.count()).sum();
    assert_eq!(per_kind_total, 1000, "every verified reply lands in exactly one histogram");
    for (_, k) in &rep.kinds {
        if k.hist.count() > 0 {
            assert!(k.hist.percentile_us(50.0) <= k.hist.percentile_us(99.0));
            assert!(k.hist.max_us() >= 1);
        }
    }
    // The fleet saw the whole stream (both shards participated).
    let m = router.metrics();
    assert_eq!(m.completed, 1000);
    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}

/// Tentpole acceptance (§Scale data planes): the same request stream
/// through a threads fleet and an epoll fleet — both sides of both
/// fleets explicitly configured, not env-inherited — produces
/// bit-identical values. The reactor changes scheduling, never bytes.
#[test]
fn epoll_and_threads_planes_are_bit_identical() {
    if !remus::fabric::reactor::supported() {
        eprintln!("skipping: the epoll data plane is not supported on this platform");
        return;
    }
    let run_plane = |plane: DataPlane| {
        let opts = || ServeOptions { data_plane: plane, ..ServeOptions::default() };
        let s1 = FabricServer::start_with_options("127.0.0.1:0", shard_cfg(0xA), opts()).unwrap();
        let s2 = FabricServer::start_with_options("127.0.0.1:0", shard_cfg(0xB), opts()).unwrap();
        let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
        let router = Router::with_config(
            &addrs,
            RouterConfig { data_plane: plane, ..Default::default() },
        )
        .unwrap();
        let k0 = kind_on_shard(&router, 0);
        let k1 = kind_on_shard(&router, 1);
        let reqs: Vec<(FunctionKind, u64, u64)> = (0..800u64)
            .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
            .collect();
        let values = run_checked(&router, &reqs);
        let m = router.metrics();
        assert_eq!(m.completed, 800, "both shards served the whole stream");
        router.shutdown();
        s1.shutdown();
        s2.shutdown();
        values
    };
    assert_eq!(
        run_plane(DataPlane::Threads),
        run_plane(DataPlane::Epoll),
        "the data plane must never change a value"
    );
}

/// Regression (bounded reply writes): a peer that floods submits but
/// never drains its replies used to wedge the threads plane's writer
/// forever (`set_write_timeout(None)`). With the bounded timeout the
/// server cuts that connection off, and the shard keeps serving
/// well-behaved clients.
#[test]
fn non_draining_peer_is_disconnected_and_server_keeps_serving() {
    let opts = ServeOptions {
        data_plane: DataPlane::Threads,
        reply_write_timeout: Duration::from_millis(200),
        ..ServeOptions::default()
    };
    let server = FabricServer::start_with_options("127.0.0.1:0", shard_cfg(0x9), opts).unwrap();
    let addr = server.local_addr().to_string();

    // Flood submits without ever reading a reply: the reply backlog
    // fills both socket buffers, the server's writer hits its bounded
    // timeout and shuts the connection down — visible here as a write
    // error once the reset propagates back.
    let mut flood = TcpStream::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut cut_off = false;
    for i in 0..400_000u64 {
        let msg = Msg::Submit {
            id: i,
            kind: FunctionKind::Add(8),
            a: i % 251,
            b: (i * 3) % 251,
            trace: 0,
        };
        if write_msg(&mut flood, &msg).is_err() {
            cut_off = true;
            break;
        }
        assert!(Instant::now() < deadline, "server never cut off the non-draining peer");
    }
    assert!(cut_off, "the undrained reply backlog must get this connection closed");

    // The shard is still healthy for clients that actually read.
    let (serving, workers, routable, _) = probe_health(&addr).unwrap();
    assert!(serving, "shard must survive the misbehaving peer");
    assert_eq!((workers, routable), (2, 2));
    server.shutdown();
}

#[test]
fn remote_shutdown_frame_stops_a_server() {
    let server = FabricServer::start("127.0.0.1:0", shard_cfg(0x5)).unwrap();
    let addr = server.local_addr().to_string();
    assert!(!server.is_stopped());
    shutdown_endpoint(&addr).unwrap();
    server.wait(); // returns promptly once the frame lands
    assert!(server.is_stopped());
    server.shutdown();
}
