//! Property tests for the telemetry subsystem (ISSUE 7): the lock-free
//! slot ring loses nothing below capacity under real thread
//! contention, journal sequence numbers are globally unique and
//! monotonic under concurrent writers, `since` cursors return exactly
//! the gap, [`merge_events`] is associative / commutative / idempotent
//! (the algebra the router's fleet merge relies on), sampling is a
//! deterministic pure function of the trace id with a bounded rate,
//! event kinds roundtrip through their wire words, and the disabled
//! tracer is observably free (mints 0, records nothing).
//!
//! ISSUE 8 adds the flight-recorder WAL properties: writer/reader
//! roundtrip identity for arbitrary event sequences under arbitrary
//! batching, random bit flips and truncations of a segment lose at
//! most the damaged suffix (never an earlier record, never the whole
//! file), and segment rotation keeps the directory under its total
//! footprint bound while always retaining the newest events.

use std::collections::HashSet;
use std::fs;
use std::sync::Arc;

use remus::telemetry::ring::SlotRing;
use remus::telemetry::wal::{read_segment, WAL_HEADER_LEN, WAL_RECORD_LEN};
use remus::telemetry::{
    merge_events, mint_boot_epoch, read_wal_dir, Event, EventJournal, EventKind, Stage, Tracer,
    WalConfig, WalWriter,
};
use remus::testutil::prop::{Cases, Gen};

#[test]
fn ring_below_capacity_loses_nothing_under_contention() {
    // 4 producers race into one ring sized to hold everything: every
    // record must survive, with dense unique sequence numbers — the
    // guarantee that makes "the journal cannot lose events below
    // capacity" true no matter which threads record them.
    let threads = 4u64;
    let per = 512u64;
    let total = threads * per;
    let ring: Arc<SlotRing<2>> = Arc::new(SlotRing::new(total as usize));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 0..per {
                    ring.push([t, i]);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(ring.pushed(), total);
    let snap = ring.snapshot();
    assert_eq!(snap.len(), total as usize, "below capacity no record may be lost");
    let seqs: Vec<u64> = snap.iter().map(|&(s, _)| s).collect();
    assert_eq!(seqs, (0..total).collect::<Vec<_>>(), "sequence numbers are dense and ordered");
    let mut seen = HashSet::new();
    for &(_, [t, i]) in &snap {
        assert!(seen.insert((t, i)), "payload ({t}, {i}) duplicated");
        assert!(t < threads && i < per, "payload ({t}, {i}) corrupted");
    }
}

#[test]
fn journal_seqs_are_unique_and_monotonic_under_concurrent_writers() {
    let journal = Arc::new(EventJournal::new(4096));
    let threads = 4u32;
    let per = 256u64;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let journal = Arc::clone(&journal);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                for _ in 0..per {
                    got.push(journal.record(EventKind::WorkerRetire { worker: t }));
                }
                got
            })
        })
        .collect();
    let mut all: Vec<u64> = Vec::new();
    for h in handles {
        let seqs = h.join().unwrap();
        // Each writer's own seqs strictly increase (fetch_add order).
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "per-writer seqs must increase");
        all.extend(seqs);
    }
    all.sort_unstable();
    let total = threads as u64 * per;
    assert_eq!(all, (0..total).collect::<Vec<_>>(), "seqs globally unique and dense");
    assert_eq!(journal.next_seq(), total);
    let events = journal.events();
    assert_eq!(events.len(), total as usize);
    assert!(events.windows(2).all(|w| w[0].seq < w[1].seq), "events sorted by seq");
}

#[test]
fn journal_cursor_returns_exactly_the_gap() {
    Cases::new(64).run(|g| {
        let n = g.u64_in(1..=200);
        let journal = EventJournal::new(256);
        for i in 0..n {
            journal.record(EventKind::ShardDown { shard: i as u32 });
        }
        let cursor = g.u64_in(0..=n);
        let (gap, latest) = journal.since(cursor);
        assert_eq!(latest, n, "cursor always advances to next_seq");
        assert_eq!(gap.len(), (n - cursor) as usize, "exactly the gap, nothing else");
        for (i, e) in gap.iter().enumerate() {
            assert_eq!(e.seq, cursor + i as u64);
        }
        let (none, latest2) = journal.since(latest);
        assert!(none.is_empty(), "a caught-up cursor gets nothing");
        assert_eq!(latest2, latest);
    });
}

#[test]
fn journal_cursor_skips_overwritten_middle_but_never_stalls() {
    // A reader more than `capacity` behind misses the overwritten
    // entries but still drains to the head — the cursor is based on
    // `next_seq`, not on what happens to be retained.
    let journal = EventJournal::new(16);
    for i in 0..100u32 {
        journal.record(EventKind::SparePromote { unit: i });
    }
    let (events, latest) = journal.since(0);
    assert_eq!(latest, 100);
    assert_eq!(events.len(), 16, "only the retained tail survives");
    assert_eq!(events.first().unwrap().seq, 84);
    assert_eq!(events.last().unwrap().seq, 99);
    let (none, _) = journal.since(latest);
    assert!(none.is_empty());
}

/// Events drawn from deliberately small ranges so duplicates and
/// timestamp ties actually occur — the cases where merge ordering and
/// dedup can go wrong.
fn gen_colliding_event(g: &mut Gen) -> Event {
    let kind = match g.usize_in(0..=3) {
        0 => EventKind::ShardDown { shard: g.u64_in(0..=2) as u32 },
        1 => EventKind::ShardRevive { shard: g.u64_in(0..=2) as u32 },
        2 => EventKind::StuckCell { worker: g.u64_in(0..=1) as u32, cells: g.u64_in(0..=3) },
        _ => EventKind::AuthReject,
    };
    Event { seq: g.u64_in(0..=7), shard: g.u64_in(0..=2) as u32, at_ns: g.u64_in(0..=7), kind }
}

#[test]
fn merge_events_is_associative_commutative_and_idempotent() {
    // The router folds per-shard journals in whatever order the pull
    // threads finish, re-merging cached events every refresh. That is
    // only correct if merge is order-insensitive and re-importing
    // already-delivered events cannot duplicate them.
    Cases::new(128).run(|g| {
        let vec_of = |g: &mut Gen, n: usize| -> Vec<Event> {
            (0..n).map(|_| gen_colliding_event(g)).collect()
        };
        let na = g.usize_in(0..=12);
        let a = vec_of(g, na);
        let nb = g.usize_in(0..=12);
        let b = vec_of(g, nb);
        let nc = g.usize_in(0..=12);
        let c = vec_of(g, nc);
        let left = merge_events(merge_events(a.clone(), b.clone()), c.clone());
        let right = merge_events(a.clone(), merge_events(b.clone(), c.clone()));
        assert_eq!(left, right, "associative");
        assert_eq!(merge_events(a.clone(), b.clone()), merge_events(b.clone(), a.clone()));
        let m = merge_events(a, b);
        assert_eq!(merge_events(m.clone(), m.clone()), m, "idempotent");
        assert!(m.windows(2).all(|w| w[0].at_ns <= w[1].at_ns), "wall-clock ordered");
    });
}

#[test]
fn sampling_is_deterministic_and_rate_bounded() {
    // Every hop keeps/drops the same requests without coordination:
    // the decision is a pure function of (trace id, rate).
    let a = Tracer::new(64, 16);
    let b = Tracer::new(64, 16);
    let mut kept = 0u64;
    for _ in 0..64_000 {
        let id = a.mint();
        assert_ne!(id, 0, "enabled tracers never mint the untraced sentinel");
        assert_eq!(a.sampled(id), b.sampled(id), "same rate => same decision");
        if a.sampled(id) {
            kept += 1;
        }
    }
    // Expect ~1000 of 64k at 1-in-64; allow a generous band.
    assert!((500..2000).contains(&kept), "1-in-64 sampling badly off: {kept}/64000");
    assert!(!a.sampled(0), "trace 0 (untraced) is never sampled");
    let always = Tracer::new(1, 16);
    for _ in 0..256 {
        assert!(always.sampled(always.mint()), "1-in-1 keeps everything");
    }
}

/// One arbitrary event kind, uniform over all 14 variants.
fn gen_event_kind(g: &mut Gen) -> EventKind {
    match g.usize_in(0..=13) {
        0 => EventKind::Scrub {
            worker: g.u64() as u32,
            corrected: g.u64(),
            detected: g.u64() as u32,
            remapped: g.u64() as u32,
        },
        1 => EventKind::StuckCell { worker: g.u64() as u32, cells: g.u64() },
        2 => EventKind::RowRemap { worker: g.u64() as u32, rows: g.u64() },
        3 => EventKind::PolicyEscalate { worker: g.u64() as u32, level: g.u64() as u8 },
        4 => EventKind::PolicyDeescalate { worker: g.u64() as u32, level: g.u64() as u8 },
        5 => EventKind::WorkerRetire { worker: g.u64() as u32 },
        6 => EventKind::SparePromote { unit: g.u64() as u32 },
        7 => EventKind::SpareDemote { unit: g.u64() as u32 },
        8 => EventKind::ShardDown { shard: g.u64() as u32 },
        9 => EventKind::ShardRevive { shard: g.u64() as u32 },
        10 => EventKind::HeartbeatTimeout { shard: g.u64() as u32 },
        11 => EventKind::FailoverReplay { shard: g.u64() as u32, replayed: g.u64() },
        12 => EventKind::AuthReject,
        _ => EventKind::ShardRestarted { shard: g.u64() as u32, epoch: g.u64() },
    }
}

#[test]
fn event_kinds_roundtrip_through_words_and_unknown_tags_rejected() {
    Cases::new(512).run(|g| {
        let kind = gen_event_kind(g);
        let (tag, a, b, c) = kind.to_words();
        assert_eq!(tag, kind.tag());
        assert_eq!(EventKind::from_words(tag, a, b, c), Some(kind), "roundtrip {}", kind.name());
        // Tags outside 1..=14 are unknown: clean None, whatever the
        // payload words claim.
        let bad = match g.u64_in(0..=1) {
            0 => 0u8,
            _ => g.u64_in(15..=255) as u8,
        };
        assert_eq!(EventKind::from_words(bad, a, b, c), None, "unknown tag {bad}");
    });
}

#[test]
fn disabled_tracer_is_free_and_span_ring_is_bounded() {
    let off = Tracer::new(0, 64);
    for _ in 0..256 {
        assert_eq!(off.mint(), 0, "disabled tracers mint the untraced sentinel");
    }
    assert!(!off.sampled(12345));
    off.record(12345, Stage::WorkerExec, 0, 10);
    assert!(off.spans().is_empty(), "disabled tracers record nothing");
    assert_eq!(off.recorded(), 0);
    // An enabled tracer's ring is bounded: overflow keeps the newest.
    let on = Tracer::new(4, 32);
    let traced = (1u64..).find(|&id| on.sampled(id)).unwrap();
    for i in 0..100u64 {
        on.record(traced, Stage::EccVerify, i, 1);
    }
    assert_eq!(on.recorded(), 100);
    let spans = on.spans();
    assert_eq!(spans.len(), on.capacity(), "ring keeps exactly capacity spans");
    assert_eq!(spans.first().unwrap().start_ns, 68, "oldest retained span");
    assert_eq!(spans.last().unwrap().start_ns, 99, "newest span");
}

/// One framed WAL record on disk: u32 len + u32 crc + fixed payload.
const WAL_FRAME: usize = WAL_RECORD_LEN + 8;

/// A fresh temp WAL directory (epoch mints are process-unique, which
/// makes them fine collision-free directory names too).
fn wal_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("remus-wal-prop-{tag}-{}", mint_boot_epoch()))
}

fn gen_events(g: &mut Gen, n: usize) -> Vec<Event> {
    (0..n)
        .map(|i| Event {
            seq: i as u64,
            shard: g.u64_in(0..=4) as u32,
            at_ns: g.u64_in(1..=u64::MAX),
            kind: gen_event_kind(g),
        })
        .collect()
}

#[test]
fn wal_roundtrip_recovers_arbitrary_event_sequences_verbatim() {
    // Batch boundaries are a flusher scheduling detail: however the
    // sequence is split across append_batch calls, the reader must
    // recover it verbatim with a clean (untorn) tail.
    Cases::new(32).run(|g| {
        let n = g.usize_in(1..=48);
        let events = gen_events(g, n);
        let dir = wal_dir("rt");
        let epoch = mint_boot_epoch();
        let mut w = WalWriter::create(&dir, epoch, WalConfig::default()).unwrap();
        let mut at = 0usize;
        while at < n {
            let take = g.usize_in(1..=n - at);
            w.append_batch(&events[at..at + take]).unwrap();
            at += take;
        }
        drop(w);
        let timelines = read_wal_dir(&dir).unwrap();
        assert_eq!(timelines.len(), 1);
        assert_eq!(timelines[0].epoch, epoch);
        assert_eq!(timelines[0].events, events, "roundtrip identity");
        assert!(!timelines[0].torn_tail);
        fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn wal_damage_loses_at_most_the_damaged_suffix() {
    // The crash-forensics contract: whatever a bit flip or a torn
    // write does to the tail of a segment, every record *before* the
    // damage is recovered verbatim — corruption can cost the suffix,
    // never the story so far and never the whole file.
    Cases::new(64).run(|g| {
        let n = g.usize_in(1..=32);
        let events = gen_events(g, n);
        let dir = wal_dir("dmg");
        let epoch = mint_boot_epoch();
        let mut w = WalWriter::create(&dir, epoch, WalConfig::default()).unwrap();
        w.append_batch(&events).unwrap();
        drop(w);
        // Default segment_bytes far exceeds 32 records: one segment.
        let path = dir.join(format!("wal-{epoch:016x}-{:08}.seg", 0));
        let pristine = fs::read(&path).unwrap();
        assert_eq!(pristine.len(), WAL_HEADER_LEN + n * WAL_FRAME, "fixed-format framing");
        if g.bool() {
            // Random bit flip past the header: the damaged record
            // fails its CRC (or its length bound) and cleanly ends
            // the read there.
            let off = g.usize_in(WAL_HEADER_LEN..=pristine.len() - 1);
            let mut data = pristine.clone();
            data[off] ^= 1 << g.usize_in(0..=7);
            fs::write(&path, &data).unwrap();
            let damaged = (off - WAL_HEADER_LEN) / WAL_FRAME;
            let seg = read_segment(&path).unwrap();
            assert_eq!(seg.epoch, epoch);
            assert_eq!(seg.events, events[..damaged], "records before the flip survive");
            assert!(seg.torn_tail, "a flipped record reads as damage");
        } else {
            // Truncation (a SIGKILLed writer's torn tail): whole
            // records before the cut survive; a cut exactly on a
            // record boundary is a clean EOF, not damage.
            let len = g.usize_in(WAL_HEADER_LEN..=pristine.len() - 1);
            fs::write(&path, &pristine[..len]).unwrap();
            let whole = (len - WAL_HEADER_LEN) / WAL_FRAME;
            let seg = read_segment(&path).unwrap();
            assert_eq!(seg.events, events[..whole], "whole records before the cut survive");
            assert_eq!(seg.torn_tail, (len - WAL_HEADER_LEN) % WAL_FRAME != 0);
        }
        fs::remove_dir_all(&dir).unwrap();
    });
}

#[test]
fn wal_rotation_keeps_the_directory_under_its_footprint_bound() {
    // Tiny segments force many rotations; the writer must delete the
    // oldest closed segments to hold the footprint bound, and what
    // survives must be a contiguous suffix ending at the newest event
    // (a flight recorder that dropped its *latest* data would be
    // useless for post-mortems).
    let dir = wal_dir("rot");
    let epoch = mint_boot_epoch();
    let cfg = WalConfig { segment_bytes: 512, max_total_bytes: 2048, ..WalConfig::default() };
    let events: Vec<Event> = (0..400u64)
        .map(|i| Event {
            seq: i,
            shard: 0,
            at_ns: 1 + i,
            kind: EventKind::SparePromote { unit: i as u32 },
        })
        .collect();
    let mut w = WalWriter::create(&dir, epoch, cfg).unwrap();
    for e in &events {
        w.append_batch(std::slice::from_ref(e)).unwrap();
    }
    drop(w);
    // Footprint is enforced at rotation, so the bound has one
    // segment's worth of slack for the active file.
    let on_disk: u64 =
        fs::read_dir(&dir).unwrap().flatten().map(|e| e.metadata().unwrap().len()).sum();
    assert!(
        on_disk <= cfg.max_total_bytes + cfg.segment_bytes + WAL_FRAME as u64,
        "footprint bound violated: {on_disk} bytes on disk"
    );
    let timelines = read_wal_dir(&dir).unwrap();
    assert_eq!(timelines.len(), 1);
    let kept = &timelines[0].events;
    assert!(timelines[0].segments >= 2, "rotation produced multiple segments");
    assert!(kept.len() < events.len(), "old segments were actually deleted");
    assert!(!kept.is_empty());
    assert!(events.ends_with(kept), "survivors are a contiguous suffix");
    assert_eq!(kept.last(), events.last(), "the newest event always survives");
    assert!(!timelines[0].torn_tail);
    fs::remove_dir_all(&dir).unwrap();
}
