//! Integration: full-size crossbar behaviour (Fig. 1 demonstrations at
//! realistic array sizes).

use remus::errs::{ErrorModel, Injector};
use remus::isa::microop::MicroOp;
use remus::isa::program::Step;
use remus::xbar::{Crossbar, Gate, Partitions};

#[test]
fn fig1a_row_parallel_nor_1024_rows() {
    // One cycle computes 1024 NORs (Fig. 1a).
    let mut x = Crossbar::new(1024, 32);
    for r in 0..1024 {
        x.state_mut().set(r, 0, r % 3 == 0);
        x.state_mut().set(r, 1, r % 5 == 0);
    }
    x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2)), None).unwrap();
    assert_eq!(x.stats.cycles, 1);
    assert_eq!(x.stats.gate_instances, 1024);
    for r in 0..1024 {
        assert_eq!(x.get(r, 2), !(r % 3 == 0 || r % 5 == 0));
    }
}

#[test]
fn fig1b_column_parallel_nor_1024_cols() {
    let mut x = Crossbar::new(32, 1024);
    for c in 0..1024 {
        x.state_mut().set(0, c, c % 2 == 0);
        x.state_mut().set(1, c, c % 7 == 0);
    }
    x.apply_step(&Step::one(MicroOp::col(Gate::Nor2, &[0, 1], 2)), None).unwrap();
    assert_eq!(x.stats.gate_instances, 1024);
    for c in 0..1024 {
        assert_eq!(x.get(2, c), !(c % 2 == 0 || c % 7 == 0));
    }
}

#[test]
fn fig1c_64_partitions_concurrent_gates() {
    // 64 independent in-row NORs in a single cycle via partitions.
    let mut x = Crossbar::new(256, 1024);
    x.set_col_partitions(Partitions::uniform(1024, 16));
    for r in 0..256 {
        for p in 0..64 {
            x.state_mut().set(r, p * 16, (r + p) % 2 == 0);
            x.state_mut().set(r, p * 16 + 1, (r + p) % 3 == 0);
        }
    }
    let ops: Vec<MicroOp> = (0..64u32)
        .map(|p| MicroOp::row(Gate::Nor2, &[p * 16, p * 16 + 1], p * 16 + 2))
        .collect();
    let c0 = x.stats.cycles;
    x.apply_step(&Step::many(ops), None).unwrap();
    assert_eq!(x.stats.cycles - c0, 1, "64 gates, one cycle");
    for r in 0..256usize {
        for p in 0..64usize {
            let want = !((r + p) % 2 == 0 || (r + p) % 3 == 0);
            assert_eq!(x.get(r, p * 16 + 2), want, "r={r} p={p}");
        }
    }
}

#[test]
fn error_injection_statistics_at_scale() {
    // 1024-row gate at p_gate = 1e-3, 100 repetitions: flip count within
    // 5 sigma of binomial expectation.
    let mut x = Crossbar::new(1024, 8);
    let mut inj = Injector::new(ErrorModel::direct_only(1e-3), 2024, 0);
    for _ in 0..100 {
        x.apply_step(&Step::one(MicroOp::row(Gate::Nor2, &[0, 1], 2)), Some(&mut inj)).unwrap();
    }
    let n = 1024.0 * 100.0;
    let expect = n * 1e-3;
    let sd = (n * 1e-3f64 * (1.0 - 1e-3)).sqrt();
    let got = inj.counters.gate_flips as f64;
    assert!((got - expect).abs() < 5.0 * sd, "flips {got} vs {expect}±{sd}");
}

#[test]
fn energy_and_cycles_scale_with_work() {
    let mut small = Crossbar::new(64, 64);
    let mut big = Crossbar::new(1024, 64);
    for x in [&mut small, &mut big] {
        for r in 0..x.rows() {
            x.state_mut().set(r, 0, r % 2 == 0);
        }
        x.apply_step(&Step::one(MicroOp::row(Gate::Not, &[0], 1)), None).unwrap();
    }
    assert_eq!(small.stats.cycles, big.stats.cycles, "latency independent of rows");
    assert!(big.stats.energy_pj > small.stats.energy_pj * 8.0, "energy scales with rows");
}
