//! Integration: ECC under realistic error processes (retention aging,
//! scrub loops), and the Fig. 2 asymmetry end to end.

use remus::ecc::{DiagonalEcc, HorizontalEcc};
use remus::errs::{ErrorModel, Injector};
use remus::util::bitmat::BitMatrix;
use remus::util::rng::Pcg64;

fn random_state(n: usize, seed: u64) -> BitMatrix {
    let mut r = Pcg64::new(seed, 0);
    BitMatrix::from_fn(n, n, |_, _| r.bernoulli(0.5))
}

#[test]
fn scrub_loop_under_retention_keeps_data_alive() {
    // 256x256 array aging in epochs; scrubbing after each epoch keeps
    // corruption near zero while the unscrubbed copy accumulates damage.
    let n = 256;
    let golden = random_state(n, 42);
    let mut protected = golden.clone();
    let mut unprotected = golden.clone();
    let mut ecc = DiagonalEcc::new(n, n, 16);
    ecc.encode(&protected);
    let model = ErrorModel { lambda_retention: 4e-9, ..ErrorModel::none() };
    let mut inj = Injector::new(model, 99, 0);
    let epochs = 20;
    let dt = 1000.0; // ~0.26 expected flips/epoch/array... scale up:
    for _ in 0..epochs {
        // age both arrays identically (clone the injector stream).
        let mut flips = vec![];
        inj.retention(n * n, dt, |i| flips.push(i));
        for &i in &flips {
            protected.flip(i / n, i % n);
            unprotected.flip(i / n, i % n);
        }
        ecc.correct(&mut protected);
    }
    let diff = |m: &BitMatrix| {
        (0..n)
            .flat_map(|r| (0..n).map(move |c| (r, c)))
            .filter(|&(r, c)| m.get(r, c) != golden.get(r, c))
            .count()
    };
    let d_prot = diff(&protected);
    let d_unprot = diff(&unprotected);
    assert!(d_unprot > 0, "aging must corrupt the unprotected copy");
    assert!(
        d_prot <= d_unprot / 4,
        "scrubbed {d_prot} vs unscrubbed {d_unprot}"
    );
}

#[test]
fn burst_beyond_single_error_is_detected_not_miscorrected() {
    let n = 64;
    let golden = random_state(n, 5);
    let mut state = golden.clone();
    let mut ecc = DiagonalEcc::new(n, n, 16);
    ecc.encode(&state);
    // 3 errors in one block: must be flagged, and correction must not
    // invent new damage beyond the block.
    state.flip(3, 4);
    state.flip(5, 9);
    state.flip(10, 12);
    let out = ecc.correct(&mut state);
    assert!(!out.uncorrectable_blocks.is_empty());
    let wrong: usize = (0..n)
        .flat_map(|r| (0..n).map(move |c| (r, c)))
        .filter(|&(r, c)| state.get(r, c) != golden.get(r, c))
        .count();
    assert!(wrong <= 4, "correction must not cascade: {wrong}");
}

#[test]
fn fig2_asymmetry_in_practice() {
    // Simulated op sequences: K in-row ops then K in-column ops. The
    // horizontal code's update cycles blow up on the in-column half;
    // the diagonal code stays flat. (Cost-model cycles, tracked by the
    // engines themselves.)
    let n = 512;
    let state = random_state(n, 11);
    let k = 16;

    let mut diag = DiagonalEcc::new(n, n, 16);
    diag.encode(&state);
    let mut horiz = HorizontalEcc::new(n, n, 8);
    horiz.encode(&state);
    let (d0, h0) = (diag.stats.update_cycles, horiz.stats.update_cycles);

    let col = state.col_bitvec(7);
    let row = state.row_bitvec(3);
    for _ in 0..k {
        diag.note_col_write(7, &col, &col);
        horiz.note_col_write(7, &col, &col);
    }
    let d_inrow = diag.stats.update_cycles - d0;
    let h_inrow = horiz.stats.update_cycles - h0;
    for _ in 0..k {
        diag.note_row_write(3, &row, &row);
        horiz.note_row_write(3, &row, &row);
    }
    let d_total = diag.stats.update_cycles - d0;
    let h_total = horiz.stats.update_cycles - h0;
    let d_incol = d_total - d_inrow;
    let h_incol = h_total - h_inrow;
    assert_eq!(d_inrow, d_incol, "diagonal: same O(1) cost both ways");
    assert!(h_incol >= (n as u64) * (k as u64), "horizontal in-column is O(n) per op");
    assert!(h_incol > 50 * h_inrow, "the Fig. 2 gap");
}

#[test]
fn ecc_storage_overheads() {
    let diag = DiagonalEcc::new(1024, 1024, 16);
    assert!((diag.overhead_ratio() - 0.1875).abs() < 1e-12, "3m per m^2");
    let horiz = HorizontalEcc::new(1024, 1024, 8);
    assert!((horiz.overhead_ratio() - 0.125).abs() < 1e-12);
}

#[test]
fn every_single_bit_position_corrects_in_16x16_block() {
    // Exhaustive over one whole block: all 256 positions.
    let n = 16;
    let golden = random_state(n, 17);
    for r in 0..n {
        for c in 0..n {
            let mut state = golden.clone();
            let mut ecc = DiagonalEcc::new(n, n, 16);
            ecc.encode(&state);
            state.flip(r, c);
            let out = ecc.correct(&mut state);
            assert_eq!(out.corrected_bits, vec![(r, c)], "position ({r},{c})");
            assert_eq!(state, golden);
        }
    }
}
