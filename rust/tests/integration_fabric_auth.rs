//! Integration: fabric authentication and per-frame integrity (ISSUE 6
//! acceptance) over real threads and loopback sockets — an
//! authenticated fleet stays bit-identical to the plaintext baseline,
//! and the three chaos scenarios (unauthenticated registrant, replayed
//! handshake/Welcome transcript, bit-flipped sealed data frame) are all
//! rejected with zero ring effect and zero lost replies. A slowloris
//! trickler at either port is cut by the bounded frame deadline without
//! ever stalling the accept loops.
//!
//! Server and router configs here use `..Default::default()`, so the
//! suite re-runs unchanged under the epoll data plane via
//! `REMUS_DATA_PLANE=epoll` (CI runs the auth rejections both ways).

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use remus::coordinator::{Coordinator, CoordinatorConfig, Submitter};
use remus::fabric::auth::{client_handshake, client_split, Psk, FRAME_DEADLINE};
use remus::fabric::wire::{read_msg, write_msg, Msg};
use remus::fabric::{fetch_metrics_auth, FabricServer, Router, RouterConfig};
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::FunctionKind;

fn shard_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 512,
        max_batch: 16,
        max_wait: Duration::from_millis(5),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

/// Router tunables fast enough for test-scale failover/revival.
fn fast_cfg(psk: Option<Psk>, listen: bool) -> RouterConfig {
    RouterConfig {
        probe_period: Duration::from_millis(100),
        retry_window: Duration::from_secs(3),
        listen: listen.then(|| "127.0.0.1:0".to_string()),
        psk,
        ..Default::default()
    }
}

fn test_psk(tag: &str) -> Psk {
    Psk::from_material(format!("integration auth psk {tag}").as_bytes()).unwrap()
}

fn candidate_kinds() -> Vec<FunctionKind> {
    (4..=16).flat_map(|n| [FunctionKind::Add(n), FunctionKind::Xor(n)]).collect()
}

fn kind_on_shard(router: &Router, shard: usize) -> FunctionKind {
    *candidate_kinds()
        .iter()
        .find(|&&k| router.shard_for(k) == Some(shard))
        .unwrap_or_else(|| panic!("no candidate kind routes to shard {shard}"))
}

/// Submit the whole sequence, then collect every reply (a lost reply
/// fails the `recv_timeout`). Asserts values, returns them.
fn run_checked(sub: &dyn Submitter, reqs: &[(FunctionKind, u64, u64)]) -> Vec<u64> {
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| sub.submit(k, a, b)).collect();
    reqs.iter()
        .zip(rxs)
        .enumerate()
        .map(|(i, (&(kind, a, b), rx))| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} lost its reply: {e}"));
            assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
            assert_eq!(r.value, kind.reference(a, b), "request {i} ({kind:?} {a} {b})");
            r.value
        })
        .collect()
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn authenticated_fleet_bit_identical_to_plaintext_baseline() {
    // The PSK comes through the same file-loading path --psk-file uses.
    let psk_path = std::env::temp_dir().join("remus_auth_it_psk.txt");
    std::fs::write(&psk_path, "correct horse battery staple\n").unwrap();
    let psk = Psk::load(&psk_path).unwrap();
    let _ = std::fs::remove_file(&psk_path);

    let a1 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0xA), Some(psk.clone()))
        .unwrap();
    let a2 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0xB), Some(psk.clone()))
        .unwrap();
    let sealed_addrs = vec![a1.local_addr().to_string(), a2.local_addr().to_string()];
    let sealed = Router::with_config(&sealed_addrs, fast_cfg(Some(psk.clone()), false)).unwrap();

    let p1 = FabricServer::start("127.0.0.1:0", shard_cfg(0xA)).unwrap();
    let p2 = FabricServer::start("127.0.0.1:0", shard_cfg(0xB)).unwrap();
    let plain_addrs = vec![p1.local_addr().to_string(), p2.local_addr().to_string()];
    let plain = Router::connect(&plain_addrs).unwrap();

    // The ring is a function of stable shard indices alone, so both
    // fleets place every kind identically.
    let k0 = kind_on_shard(&sealed, 0);
    let k1 = kind_on_shard(&sealed, 1);
    assert_eq!(sealed.ring_walk(k0), plain.ring_walk(k0));
    assert_eq!(sealed.ring_walk(k1), plain.ring_walk(k1));

    let reqs: Vec<(FunctionKind, u64, u64)> = (0..1200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 251, (i * 7 + 3) % 251))
        .collect();
    let sealed_values = run_checked(&sealed, &reqs);
    let plain_values = run_checked(&plain, &reqs);
    assert_eq!(sealed_values, plain_values, "seal must not change a single value");

    let coord = Coordinator::start(shard_cfg(0xA)).unwrap();
    let local_values = run_checked(&coord, &reqs);
    coord.shutdown();
    assert_eq!(sealed_values, local_values, "sealed fabric bit-identical to in-process");

    let m = sealed.metrics();
    assert_eq!(m.completed, 1200);
    assert_eq!(m.auth_rejects, 0, "a well-behaved sealed fleet rejects nobody");
    assert_eq!(m.worker_health.len(), 4);

    // The authenticated control plane works end to end too.
    let ms = fetch_metrics_auth(&sealed_addrs[0], Some(&psk)).unwrap();
    assert!(ms.completed > 0);

    sealed.shutdown();
    plain.shutdown();
    a1.shutdown();
    a2.shutdown();
    p1.shutdown();
    p2.shutdown();
}

#[test]
fn unauthenticated_registrant_is_rejected_without_touching_the_ring() {
    let psk = test_psk("unauth");
    let s1 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x1), Some(psk.clone()))
        .unwrap();
    let s2 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x2), Some(psk.clone()))
        .unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(Some(psk.clone()), true)).unwrap();
    let reg = router.registration_addr().unwrap().to_string();

    let epoch0 = router.membership_epoch();
    let count0 = router.shard_count();
    let walks: Vec<Vec<usize>> = candidate_kinds().iter().map(|&k| router.ring_walk(k)).collect();

    // Attack 1: a plaintext Register frame straight at the sealed
    // registration port. The handshake layer rejects it before the
    // frame's *content* is even parsed.
    {
        let mut s = TcpStream::connect(&reg).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let evil = Msg::Register {
            name: "evil".into(),
            addr: "127.0.0.1:1".into(),
            spare: false,
            prev: None,
        };
        write_msg(&mut s, &evil).unwrap();
        match read_msg(&mut s) {
            Ok(Some(msg)) => panic!("sealed port answered a plaintext registrant: {msg:?}"),
            Ok(None) | Err(_) => {} // cut off, as required
        }
    }

    // Attack 2: a registrant holding the *wrong* key fails the mutual
    // handshake (the ServerHello MAC does not verify on our side, and
    // our ClientConfirm never arrives on theirs).
    {
        let mut s = TcpStream::connect(&reg).unwrap();
        let wrong = test_psk("not the fleet key");
        assert!(client_handshake(&mut s, &wrong).is_err(), "wrong PSK must not handshake");
    }

    // Attack 3: a plaintext Submit at a sealed shard data port.
    {
        let mut s = TcpStream::connect(&addrs[0]).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let probe = Msg::Submit { id: 1, kind: FunctionKind::Add(8), a: 1, b: 2, trace: 0 };
        write_msg(&mut s, &probe).unwrap();
        match read_msg(&mut s) {
            Ok(Some(msg)) => panic!("sealed shard answered a plaintext Submit: {msg:?}"),
            Ok(None) | Err(_) => {}
        }
    }

    // All three rejections become visible in the merged fleet metrics
    // (router-side counts for the registration port, shard-side for the
    // data port) — and none of them moved the ring.
    wait_until("3 auth rejects in the merged metrics", Duration::from_secs(10), || {
        router.metrics().auth_rejects >= 3
    });
    assert_eq!(router.membership_epoch(), epoch0, "rejected registrant must not bump epoch");
    assert_eq!(router.shard_count(), count0, "rejected registrant must not join");
    for (i, k) in candidate_kinds().iter().enumerate() {
        assert_eq!(router.ring_walk(*k), walks[i], "ring placement must be untouched");
    }

    // Legitimate traffic is entirely unaffected: zero lost replies.
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..400u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 97, (i * 3 + 1) % 97))
        .collect();
    run_checked(&router, &reqs);

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}

/// Copy bytes `from -> to`, appending everything seen to `rec`.
fn pump_recording(mut from: TcpStream, mut to: TcpStream, rec: Arc<Mutex<Vec<u8>>>) {
    let mut buf = [0u8; 4096];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                rec.lock().unwrap().extend_from_slice(&buf[..n]);
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
}

#[test]
fn replayed_welcome_and_handshake_transcripts_are_rejected() {
    let psk = test_psk("replay");
    let shard = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x5), Some(psk.clone()))
        .unwrap();
    let shard_addr = shard.local_addr().to_string();
    let router = Router::with_config(&[], fast_cfg(Some(psk.clone()), true)).unwrap();
    let reg = router.registration_addr().unwrap().to_string();
    shard.register_with(&reg, "s0", false);
    assert!(router.wait_for_live(1, Duration::from_secs(10)), "shard never registered");

    // Record one *legitimate* re-announcement of the same shard through
    // a tapping proxy: handshake, sealed Register, sealed Welcome.
    // (Shards re-announce periodically, so this duplicate is exactly
    // the traffic an eavesdropper would capture in steady state.)
    let c2s = Arc::new(Mutex::new(Vec::new()));
    let s2c = Arc::new(Mutex::new(Vec::new()));
    let tap = TcpListener::bind("127.0.0.1:0").unwrap();
    let tap_addr = tap.local_addr().unwrap();
    let upstream = reg.clone();
    let (c2s2, s2c2) = (c2s.clone(), s2c.clone());
    let tap_thread = std::thread::spawn(move || {
        let (client, _) = tap.accept().unwrap();
        let server = TcpStream::connect(&upstream).unwrap();
        let t = std::thread::spawn({
            let (c, s) = (client.try_clone().unwrap(), server.try_clone().unwrap());
            move || pump_recording(c, s, c2s2)
        });
        pump_recording(server, client, s2c2);
        t.join().unwrap();
    });
    {
        let stream = TcpStream::connect(tap_addr).unwrap();
        let (mut reader, mut writer) =
            client_split(stream, Some(&psk), Some(Duration::from_secs(5))).unwrap();
        let announce = Msg::Register {
            name: "s0".into(),
            addr: shard_addr.clone(),
            spare: false,
            prev: Some(0),
        };
        writer.send(&announce).unwrap();
        match reader.recv().unwrap() {
            Some(Msg::Welcome { shard: 0, active: true }) => {}
            other => panic!("expected Welcome for the recorded announcement, got {other:?}"),
        }
    }
    tap_thread.join().unwrap();
    let c2s = c2s.lock().unwrap().clone();
    let s2c = s2c.lock().unwrap().clone();
    assert!(!c2s.is_empty() && !s2c.is_empty(), "tap recorded both directions");

    let epoch0 = router.membership_epoch();
    let rejects0 = router.metrics().auth_rejects;

    // Replay A: the captured client transcript (ClientHello +
    // ClientConfirm + sealed Register) verbatim at the registration
    // port. The router issues a *fresh* server nonce, so the recorded
    // ClientConfirm MAC no longer verifies — the sealed Register behind
    // it is never opened and the ring never hears about it.
    {
        let mut s = TcpStream::connect(&reg).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let _ = s.write_all(&c2s);
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink); // server's fresh hello, then the cut
    }
    wait_until("the replayed transcript to be counted", Duration::from_secs(10), || {
        router.metrics().auth_rejects > rejects0
    });
    assert_eq!(router.membership_epoch(), epoch0, "replay must have zero ring effect");
    assert_eq!(router.shard_count(), 1);

    // Replay B: a fake "router" that answers a fresh client with the
    // captured server transcript (ServerHello + sealed Welcome). The
    // recorded ServerHello MAC covers the *recorded* client nonce, not
    // the fresh one, so the client refuses before the replayed Welcome
    // can possibly be believed.
    let fake = TcpListener::bind("127.0.0.1:0").unwrap();
    let fake_addr = fake.local_addr().unwrap();
    let replayed = s2c.clone();
    let fake_thread = std::thread::spawn(move || {
        let (mut conn, _) = fake.accept().unwrap();
        let _ = conn.write_all(&replayed);
        let mut sink = [0u8; 4096];
        while matches!(conn.read(&mut sink), Ok(n) if n > 0) {}
    });
    {
        let mut s = TcpStream::connect(fake_addr).unwrap();
        assert!(
            client_handshake(&mut s, &psk).is_err(),
            "a replayed Welcome transcript must not authenticate a fake router"
        );
    }
    fake_thread.join().unwrap();

    // The fleet still serves, with zero lost replies.
    let k0 = kind_on_shard(&router, 0);
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..200u64).map(|i| (k0, i % 97, (i * 5 + 2) % 97)).collect();
    run_checked(&router, &reqs);

    router.shutdown();
    shard.shutdown();
}

#[test]
fn tampered_data_frames_are_rejected_and_replayed_with_zero_loss() {
    let psk = test_psk("tamper");
    let s1 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x7), Some(psk.clone()))
        .unwrap();
    let s2 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x8), Some(psk.clone()))
        .unwrap();
    let shard0_addr = s1.local_addr().to_string();

    // A man-in-the-middle in front of shard 0 that flips exactly one
    // bit of one server->client byte on the *first* connection (the
    // router's data connection), past the 70-byte handshake transcript
    // so the flip lands inside a sealed frame. Every later connection
    // (control probes, the revival's fresh data connection) is passed
    // through verbatim.
    let mitm = TcpListener::bind("127.0.0.1:0").unwrap();
    let mitm_addr = mitm.local_addr().unwrap().to_string();
    let upstream = shard0_addr.clone();
    let flipped = Arc::new(AtomicBool::new(false));
    let flipped2 = flipped.clone();
    std::thread::spawn(move || {
        let mut first = true;
        for client in mitm.incoming() {
            let Ok(client) = client else { break };
            let Ok(server) = TcpStream::connect(&upstream) else { break };
            let tamper = first;
            first = false;
            let (c2, sv2) = (client.try_clone().unwrap(), server.try_clone().unwrap());
            std::thread::spawn(move || {
                // client -> server, verbatim.
                let (mut from, mut to) = (c2, sv2);
                let mut buf = [0u8; 4096];
                loop {
                    match from.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if to.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                let _ = to.shutdown(Shutdown::Both);
            });
            let flipped = flipped2.clone();
            std::thread::spawn(move || {
                // server -> client, one bit flipped once on conn 0.
                let (mut from, mut to) = (server, client);
                let mut buf = [0u8; 4096];
                let mut seen = 0usize;
                loop {
                    match from.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if tamper && seen + n > 80 && !flipped.load(Ordering::SeqCst) {
                                let i = 80usize.saturating_sub(seen).min(n - 1);
                                buf[i] ^= 0x01;
                                flipped.store(true, Ordering::SeqCst);
                            }
                            seen += n;
                            if to.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                let _ = to.shutdown(Shutdown::Both);
            });
        }
    });

    let addrs = vec![mitm_addr, s2.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(Some(psk.clone()), false)).unwrap();
    let k0 = kind_on_shard(&router, 0); // served through the MITM

    // Every reply routed through the tampering proxy must still arrive
    // with the right value: the router detects the MAC failure, marks
    // shard 0 down exactly like a disconnect, and failover replays the
    // in-flight requests on shard 1.
    let reqs: Vec<(FunctionKind, u64, u64)> =
        (0..300u64).map(|i| (k0, i % 97, (i * 7 + 1) % 97)).collect();
    run_checked(&router, &reqs);

    assert!(flipped.load(Ordering::SeqCst), "the MITM never saw a frame to tamper with");
    wait_until("the tampered frame to be counted", Duration::from_secs(10), || {
        router.metrics().auth_rejects >= 1
    });

    // The supervisor revives shard 0 through a fresh (untampered)
    // connection; the fleet heals to full strength.
    assert!(
        router.wait_for_live(2, Duration::from_secs(15)),
        "tampered shard never revived over a clean connection"
    );
    run_checked(&router, &reqs[..50]);

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}

#[test]
fn slowloris_trickle_never_stalls_registration_or_data_ports() {
    let psk = test_psk("slowloris");
    let s1 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x3), Some(psk.clone()))
        .unwrap();
    let addrs = vec![s1.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(Some(psk.clone()), true)).unwrap();
    let reg = router.registration_addr().unwrap().to_string();

    // One trickler per port: connect, then dribble one byte every
    // 150ms — the classic slowloris. The bounded frame deadline must
    // cut each of them off; until then they cost one parked thread
    // each, never the accept loop.
    let cut_count = Arc::new(AtomicU64::new(0));
    let (done_tx, done_rx) = channel::<Duration>();
    for target in [reg.clone(), addrs[0].clone()] {
        let done = done_tx.clone();
        let cuts = cut_count.clone();
        std::thread::spawn(move || {
            let mut s = TcpStream::connect(&target).unwrap();
            let t0 = Instant::now();
            loop {
                if s.write_all(&[0x01]).is_err() {
                    break;
                }
                if t0.elapsed() > Duration::from_secs(30) {
                    break; // never cut: report the elapsed and let the assert fail
                }
                std::thread::sleep(Duration::from_millis(150));
            }
            cuts.fetch_add(1, Ordering::SeqCst);
            done.send(t0.elapsed()).unwrap();
        });
    }
    drop(done_tx);

    // While both tricklers are live: a legitimate shard registers (the
    // registration accept loop is free) and legitimate load completes
    // on both shards (the data accept loop is free).
    let s2 = FabricServer::start_with_auth("127.0.0.1:0", shard_cfg(0x4), Some(psk.clone()))
        .unwrap();
    s2.register_with(&reg, "late", false);
    assert!(
        router.wait_for_live(2, Duration::from_secs(10)),
        "registration stalled behind a slowloris trickler"
    );
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..200u64)
        .map(|i| (if i % 2 == 0 { k0 } else { k1 }, i % 97, (i * 11 + 5) % 97))
        .collect();
    run_checked(&router, &reqs);

    // Both tricklers are disconnected within the frame deadline plus
    // generous slack for RST propagation and scheduler noise.
    let bound = FRAME_DEADLINE + Duration::from_secs(10);
    for _ in 0..2 {
        let cut_after = done_rx.recv_timeout(Duration::from_secs(40)).unwrap();
        assert!(cut_after < bound, "trickler survived {cut_after:?} (bound {bound:?})");
    }
    assert_eq!(cut_count.load(Ordering::SeqCst), 2);
    // Both rejections are counted in the merged fleet metrics.
    wait_until("both tricklers counted as auth rejects", Duration::from_secs(10), || {
        router.metrics().auth_rejects >= 2
    });

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}
