//! Integration: the durable flight recorder (ISSUE 8 acceptance) over
//! real threads and loopback sockets.
//!
//! * The epoch-aware cursor-reset regression test kills and revives a
//!   shard and asserts the router's merged journal carries BOTH boot
//!   epochs' events plus a synthesized `ShardRestarted` marker — the
//!   ROADMAP carryover bug was a router cursor pointing past a
//!   restarted shard's fresh (seq-0) journal, silently losing the new
//!   boot's prefix.
//! * The acceptance test drives a 2-shard authenticated fleet with
//!   `--journal-dir` through the full reliability incident
//!   (scrub -> stuck -> remap -> escalate -> retire -> kill ->
//!   revive), then reconstructs the pre-kill event chain in causal
//!   order from the on-disk WAL alone (what `remus postmortem` does),
//!   and scrapes the router's `/metrics` endpoint, whose
//!   submitted/completed counters must match the merged
//!   `MetricsSnapshot` exactly.
//!
//! Server and router configs here default their data plane, so the
//! suite re-runs unchanged under the epoll reactor via
//! `REMUS_DATA_PLANE=epoll`.

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use remus::coordinator::{CoordinatorConfig, Submitter};
use remus::fabric::auth::Psk;
use remus::fabric::{
    shutdown_endpoint_auth, FabricServer, RouteOptions, Router, RouterConfig, ServeOptions,
};
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::FunctionKind;
use remus::telemetry::{mint_boot_epoch, read_wal_dir, unix_now_ns, EventKind, WalConfig};

/// A healthy shard: immortal wear, scrubbing on, nothing to report.
fn healthy_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 512,
        max_batch: 16,
        max_wait: Duration::from_millis(5),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

/// The doomed shard (same §Health recipe as `integration_telemetry`):
/// a lethal endurance budget so the first batches kill the crossbar
/// and the scrub detects, remaps, escalates, and retires — the full
/// reliability causal chain in one deterministic pass.
fn lethal_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

fn test_psk(tag: &str) -> Psk {
    Psk::from_material(format!("integration flight recorder psk {tag}").as_bytes()).unwrap()
}

/// Router tunables fast enough for test-scale failover/revival.
fn fast_cfg(psk: Psk) -> RouterConfig {
    RouterConfig {
        probe_period: Duration::from_millis(100),
        retry_window: Duration::from_secs(3),
        psk: Some(psk),
        ..Default::default()
    }
}

/// A WAL that flushes fast enough for test-scale assertions.
fn fast_wal() -> WalConfig {
    WalConfig { flush_interval: Duration::from_millis(5), ..WalConfig::default() }
}

/// A fresh temp directory (epoch mints double as collision-free names).
fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("remus-flight-{tag}-{}", mint_boot_epoch()))
}

fn candidate_kinds() -> Vec<FunctionKind> {
    (4..=16).flat_map(|n| [FunctionKind::Add(n), FunctionKind::Xor(n)]).collect()
}

fn kind_on_shard(router: &Router, shard: usize) -> FunctionKind {
    *candidate_kinds()
        .iter()
        .find(|&&k| router.shard_for(k) == Some(shard))
        .unwrap_or_else(|| panic!("no candidate kind routes to shard {shard}"))
}

/// Submit the whole sequence, then collect every reply (a lost reply
/// fails the `recv_timeout`). Asserts values.
fn run_checked(sub: &dyn Submitter, reqs: &[(FunctionKind, u64, u64)]) {
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| sub.submit(k, a, b)).collect();
    for (i, (&(kind, a, b), rx)) in reqs.iter().zip(rxs).enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|e| panic!("request {i} lost its reply: {e}"));
        assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
        assert_eq!(r.value, kind.reference(a, b), "request {i} ({kind:?} {a} {b})");
    }
}

/// The standard incident load: half on the doomed shard's kind, half
/// on the healthy one's.
fn incident_load(
    k_wear: FunctionKind,
    k_ok: FunctionKind,
    n: u64,
) -> Vec<(FunctionKind, u64, u64)> {
    (0..n)
        .map(|i| {
            let k = if i % 2 == 0 { k_wear } else { k_ok };
            (k, i % 13, (i * 5) % 13)
        })
        .collect()
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Rebind an authenticated fabric server (flight-recorder options
/// included) on an exact address, retrying briefly — the kernel may
/// hold the port for a moment after the old listener goes away.
fn restart_shard(
    addr: &str,
    cfg: CoordinatorConfig,
    psk: &Psk,
    journal_dir: Option<&PathBuf>,
) -> FabricServer {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let opts = ServeOptions {
            psk: Some(psk.clone()),
            journal_dir: journal_dir.cloned(),
            metrics_addr: None,
            wal: fast_wal(),
            ..ServeOptions::default()
        };
        match FabricServer::start_with_options(addr, cfg.clone(), opts) {
            Ok(s) => return s,
            Err(e) => {
                assert!(Instant::now() < deadline, "could not rebind {addr}: {e:#}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// One plain-HTTP scrape, exactly what `curl http://addr/metrics` does.
fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(format!("GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").as_bytes()).unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    out
}

/// The value of a single-sample metric line (`name value`) in a
/// Prometheus text exposition.
fn metric_value(exposition: &str, name: &str) -> u64 {
    exposition
        .lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .unwrap_or_else(|| panic!("metric {name} missing from exposition:\n{exposition}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("metric {name} is not a u64: {e}"))
}

/// ISSUE 8 regression (the ROADMAP §Telemetry carryover): a restarted
/// shard's journal starts over at seq 0 while the router's cursor
/// points far past it — the old code silently lost the new boot's
/// event prefix. The v6 boot epoch lets the router detect the restart,
/// reset the cursor to 0, and synthesize a `ShardRestarted` marker, so
/// the merged journal carries BOTH epochs' events with no duplicates.
#[test]
fn router_cursor_resets_on_shard_restart_instead_of_losing_events() {
    let psk = test_psk("cursor");
    let wear =
        FabricServer::start_with_auth("127.0.0.1:0", lethal_cfg(0xB), Some(psk.clone())).unwrap();
    let healthy =
        FabricServer::start_with_auth("127.0.0.1:0", healthy_cfg(0xA), Some(psk.clone())).unwrap();
    let first_epoch = wear.boot_epoch();
    assert_ne!(first_epoch, 0, "every server boot mints a non-zero epoch");
    let addrs = vec![wear.local_addr().to_string(), healthy.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(psk.clone())).unwrap();
    let k_wear = kind_on_shard(&router, 0);
    let k_ok = kind_on_shard(&router, 1);

    // First boot: drive the incident so shard 0's journal fills, then
    // pull it — the router's slot-0 cursor now points far past seq 0.
    run_checked(&router, &incident_load(k_wear, k_ok, 600));
    wait_until("first boot's chain in the fleet journal", Duration::from_secs(10), || {
        router
            .fleet_events()
            .iter()
            .any(|e| e.shard == 0 && matches!(e.kind, EventKind::WorkerRetire { .. }))
    });
    assert_eq!(
        router.fleet_epochs().get(&0),
        Some(&first_epoch),
        "the pull learns the shard's boot epoch"
    );
    let pulled = router.fleet_events().iter().filter(|e| e.shard == 0).count();
    assert!(pulled > 2, "cursor must be well past the fresh journal's seqs (got {pulled})");

    // Kill shard 0 and restart it on the same address: a fresh journal
    // (seq 0) under a fresh boot epoch.
    shutdown_endpoint_auth(&addrs[0], Some(&psk)).unwrap();
    wear.shutdown();
    let cut_ns = unix_now_ns();
    let revived = restart_shard(&addrs[0], lethal_cfg(0xD), &psk, None);
    let second_epoch = revived.boot_epoch();
    assert_ne!(second_epoch, first_epoch, "restart mints a different epoch");
    wait_until("wear slot revived", Duration::from_secs(10), || router.live_shards() == 2);
    assert_eq!(router.shard_for(k_wear), Some(0), "revived slot reclaims its kinds");

    // Second boot: generate journal events whose seqs (0, 1, ...) sit
    // *below* the router's stale cursor — exactly the events the old
    // code lost.
    run_checked(&router, &incident_load(k_wear, k_ok, 600));
    wait_until("second boot's events in the merged journal", Duration::from_secs(10), || {
        router
            .fleet_events()
            .iter()
            .any(|e| e.shard == 0 && e.at_ns > cut_ns && matches!(e.kind, EventKind::Scrub { .. }))
    });

    let timeline = router.fleet_events();
    // Both epochs' stories are present...
    let slot0_has = |after_cut: bool, f: fn(&EventKind) -> bool| {
        timeline.iter().any(|e| e.shard == 0 && (e.at_ns > cut_ns) == after_cut && f(&e.kind))
    };
    assert!(
        slot0_has(false, |k| matches!(k, EventKind::WorkerRetire { .. })),
        "first boot's events survive the restart: {timeline:#?}"
    );
    assert!(
        slot0_has(true, |k| matches!(k, EventKind::Scrub { .. })),
        "second boot's sub-cursor events were recovered: {timeline:#?}"
    );
    // ...the router marked the restart explicitly, naming the new epoch...
    let marker = timeline.iter().any(|e| {
        matches!(e.kind, EventKind::ShardRestarted { shard: 0, epoch } if epoch == second_epoch)
    });
    assert!(marker, "a ShardRestarted marker names slot 0 and the new epoch: {timeline:#?}");
    assert_eq!(router.fleet_epochs().get(&0), Some(&second_epoch), "the slot tracks the new epoch");
    // ...and the merge introduced no duplicates: within one boot epoch
    // (same shard + same timestamp) a journal seq appears once.
    let mut seen = HashSet::new();
    for e in &timeline {
        assert!(seen.insert((e.shard, e.seq, e.at_ns)), "duplicate merged event {e:?}");
    }

    router.shutdown();
    revived.shutdown();
    healthy.shutdown();
}

/// ISSUE 8 acceptance: a 2-shard authenticated fleet with
/// `--journal-dir` everywhere and `--metrics-addr` on the router,
/// driven through scrub -> escalate -> remap -> retire -> kill ->
/// revive. The dead shard's pre-kill chain is reconstructed in causal
/// order from its WAL alone; the revived shard's fresh epoch shows up
/// as a second WAL timeline and as a router-detected restart; the
/// `/metrics` exposition matches the merged snapshot exactly.
#[test]
fn wal_postmortem_reconstructs_the_chain_and_metrics_match_the_snapshot() {
    let psk = test_psk("wal");
    let dir_wear = temp_dir("wear");
    let dir_ok = temp_dir("ok");
    let dir_router = temp_dir("router");
    let wear = restart_shard("127.0.0.1:0", lethal_cfg(0xB), &psk, Some(&dir_wear));
    let healthy = restart_shard("127.0.0.1:0", healthy_cfg(0xA), &psk, Some(&dir_ok));
    let first_epoch = wear.boot_epoch();
    let addrs = vec![wear.local_addr().to_string(), healthy.local_addr().to_string()];
    let router = Router::with_options(
        &addrs,
        fast_cfg(psk.clone()),
        RouteOptions {
            journal_dir: Some(dir_router.clone()),
            metrics_addr: Some("127.0.0.1:0".to_string()),
            wal: fast_wal(),
        },
    )
    .unwrap();
    let metrics_addr = router.metrics_addr().expect("metrics endpoint configured");
    let k_wear = kind_on_shard(&router, 0);
    let k_ok = kind_on_shard(&router, 1);

    // Drive the incident, then let the WAL flusher catch up until the
    // retirement (the chain's last in-shard step) is on disk.
    run_checked(&router, &incident_load(k_wear, k_ok, 600));
    wait_until("the chain reaches shard 0's WAL", Duration::from_secs(10), || {
        read_wal_dir(&dir_wear).is_ok_and(|t| {
            t.iter().any(|tl| {
                tl.epoch == first_epoch
                    && tl.events.iter().any(|e| matches!(e.kind, EventKind::WorkerRetire { .. }))
            })
        })
    });

    // Scrape /metrics while the fleet is quiescent: the submitted and
    // completed counters must equal the merged snapshot's exactly.
    let m = router.metrics();
    let scrape = http_get(metrics_addr, "/metrics");
    assert!(scrape.starts_with("HTTP/1.0 200 OK\r\n"), "scrape failed:\n{scrape}");
    assert!(scrape.contains("text/plain; version=0.0.4"), "wrong content type:\n{scrape}");
    let body = scrape.split("\r\n\r\n").nth(1).expect("exposition body");
    assert!(body.contains("# TYPE remus_requests_submitted_total counter"));
    assert_eq!(metric_value(body, "remus_requests_submitted_total"), m.submitted);
    assert_eq!(metric_value(body, "remus_requests_completed_total"), m.completed);
    // Failover retries may re-submit a request to a second shard, so
    // the merged counter is a lower-bounded sum, not an exact 600.
    assert!(m.submitted >= 600, "the incident load was counted (got {})", m.submitted);

    // Kill shard 0. Its story must now be reconstructible from disk
    // alone — this is exactly what `remus postmortem` runs on the
    // directory.
    shutdown_endpoint_auth(&addrs[0], Some(&psk)).unwrap();
    wear.shutdown();
    let timelines = read_wal_dir(&dir_wear).unwrap();
    assert_eq!(timelines.len(), 1, "one boot so far");
    let tl = &timelines[0];
    assert_eq!(tl.epoch, first_epoch, "segments are stamped with the boot epoch");
    assert!(!tl.torn_tail, "a drained shutdown leaves a clean tail");
    assert!(
        tl.events.windows(2).all(|w| w[0].seq < w[1].seq),
        "WAL events are in journal order: {tl:#?}"
    );
    let pos = |pred: fn(&EventKind) -> bool| {
        tl.events
            .iter()
            .position(|e| pred(&e.kind))
            .unwrap_or_else(|| panic!("event missing from the WAL: {tl:#?}"))
    };
    let scrub = pos(|k| matches!(k, EventKind::Scrub { .. }));
    let stuck = pos(|k| matches!(k, EventKind::StuckCell { .. }));
    let remap = pos(|k| matches!(k, EventKind::RowRemap { .. }));
    let escalate = pos(|k| matches!(k, EventKind::PolicyEscalate { .. }));
    let retire = pos(|k| matches!(k, EventKind::WorkerRetire { .. }));
    assert!(scrub < stuck && stuck < remap, "scrub detects, then remaps");
    assert!(remap < escalate && escalate < retire, "escalate precedes retirement");

    // Revive on the same address with the same journal dir: a second
    // epoch appears on disk, and the router flags the restart.
    let revived = restart_shard(&addrs[0], healthy_cfg(0xC), &psk, Some(&dir_wear));
    let second_epoch = revived.boot_epoch();
    wait_until("wear slot revived", Duration::from_secs(10), || router.live_shards() == 2);
    run_checked(&router, &[(k_wear, 20, 22), (k_ok, 7, 8)]);
    wait_until("router detects the new epoch", Duration::from_secs(10), || {
        router.fleet_events();
        router.fleet_epochs().get(&0) == Some(&second_epoch)
    });
    wait_until("second epoch reaches the WAL", Duration::from_secs(10), || {
        read_wal_dir(&dir_wear).is_ok_and(|t| t.len() == 2)
    });
    let timelines = read_wal_dir(&dir_wear).unwrap();
    assert_eq!(timelines[0].epoch, first_epoch, "epochs ordered oldest boot first");
    assert_eq!(timelines[1].epoch, second_epoch);

    // Shut the fleet down; the router's own WAL (final-drained on
    // shutdown) must carry the membership story including the
    // synthesized restart marker.
    router.shutdown();
    revived.shutdown();
    healthy.shutdown();
    let router_tl = read_wal_dir(&dir_router).unwrap();
    assert_eq!(router_tl.len(), 1, "one router boot");
    let has = |pred: fn(&EventKind) -> bool| router_tl[0].events.iter().any(|e| pred(&e.kind));
    assert!(has(|k| matches!(k, EventKind::ShardDown { .. })), "kill reached the router WAL");
    assert!(
        has(|k| matches!(k, EventKind::ShardRestarted { .. })),
        "the synthesized restart marker reached the router WAL: {router_tl:#?}"
    );

    for d in [dir_wear, dir_ok, dir_router] {
        let _ = std::fs::remove_dir_all(d);
    }
}
