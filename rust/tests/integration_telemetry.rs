//! Integration: fleet-wide telemetry (ISSUE 7 acceptance) over real
//! threads and loopback sockets. A 2-shard authenticated fleet is
//! driven through a forced reliability incident — wear-driven scrub
//! detection, stuck cells, spare-row remapping, policy escalation,
//! worker retirement, a shard kill and its revival — and the router's
//! merged journal must tell that story as one causally ordered
//! timeline with fleet-truthful shard attribution. Separately, a
//! sampled request's trace must cover every pipeline stage with
//! non-zero spans whose durations fit inside the router-measured
//! end-to-end latency.
//!
//! Server and router configs here use `..Default::default()`, so the
//! suite re-runs unchanged under the epoll data plane via
//! `REMUS_DATA_PLANE=epoll`.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use remus::coordinator::{CoordinatorConfig, Submitter};
use remus::fabric::auth::Psk;
use remus::fabric::{shutdown_endpoint_auth, FabricServer, Router, RouterConfig};
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::{FunctionKind, ReliabilityPolicy};
use remus::telemetry::{Event, EventKind, Stage, TraceSpan};

/// A healthy shard: immortal wear, scrubbing on, nothing to report.
fn healthy_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 512,
        max_batch: 16,
        max_wait: Duration::from_millis(5),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

/// The doomed shard: a lethal endurance budget (same §Health recipe as
/// `integration_coordinator::wear_out_retires_crossbar_and_errors_explicitly`)
/// so the first batch kills the crossbar and the next march scrub
/// detects it, remaps into (and exhausts) the spare rows, escalates the
/// policy, and retires the worker — the full reliability causal chain
/// in one deterministic pass.
fn lethal_cfg(seed: u64) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        seed,
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    }
}

fn test_psk(tag: &str) -> Psk {
    Psk::from_material(format!("integration telemetry psk {tag}").as_bytes()).unwrap()
}

/// Router tunables fast enough for test-scale failover/revival.
fn fast_cfg(psk: Psk, trace_sample: u64) -> RouterConfig {
    RouterConfig {
        probe_period: Duration::from_millis(100),
        retry_window: Duration::from_secs(3),
        psk: Some(psk),
        trace_sample,
        ..Default::default()
    }
}

fn candidate_kinds() -> Vec<FunctionKind> {
    (4..=16).flat_map(|n| [FunctionKind::Add(n), FunctionKind::Xor(n)]).collect()
}

fn kind_on_shard(router: &Router, shard: usize) -> FunctionKind {
    *candidate_kinds()
        .iter()
        .find(|&&k| router.shard_for(k) == Some(shard))
        .unwrap_or_else(|| panic!("no candidate kind routes to shard {shard}"))
}

/// Submit the whole sequence, then collect every reply (a lost reply
/// fails the `recv_timeout`). Asserts values, returns them.
fn run_checked(sub: &dyn Submitter, reqs: &[(FunctionKind, u64, u64)]) -> Vec<u64> {
    let rxs: Vec<_> = reqs.iter().map(|&(k, a, b)| sub.submit(k, a, b)).collect();
    reqs.iter()
        .zip(rxs)
        .enumerate()
        .map(|(i, (&(kind, a, b), rx))| {
            let r = rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|e| panic!("request {i} lost its reply: {e}"));
            assert!(r.is_ok(), "request {i} errored: {:?}", r.error);
            assert_eq!(r.value, kind.reference(a, b), "request {i} ({kind:?} {a} {b})");
            r.value
        })
        .collect()
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Rebind an authenticated fabric server on an exact address, retrying
/// briefly (the kernel may hold the port for a moment after the old
/// listener goes away).
fn restart_with_auth(addr: &str, cfg: CoordinatorConfig, psk: &Psk) -> FabricServer {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match FabricServer::start_with_auth(addr, cfg.clone(), Some(psk.clone())) {
            Ok(s) => return s,
            Err(e) => {
                assert!(Instant::now() < deadline, "could not rebind {addr}: {e:#}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Index of the first event on `shard` matching `pred` in the merged
/// timeline — merged order IS the causal claim under test.
fn first_idx(timeline: &[Event], shard: u32, pred: impl Fn(&EventKind) -> bool) -> usize {
    timeline
        .iter()
        .position(|e| e.shard == shard && pred(&e.kind))
        .unwrap_or_else(|| panic!("no matching event for shard {shard} in {timeline:#?}"))
}

/// ISSUE 7 acceptance (journal): drive a 2-shard authenticated fleet
/// through scrub -> stuck-cell detection -> remap -> escalation ->
/// retirement -> shard kill -> revival, and assert the merged fleet
/// journal contains the whole causal chain in order, each event
/// attributed to the shard it actually happened on.
#[test]
fn fleet_journal_captures_the_reliability_causal_chain() {
    let psk = test_psk("journal");
    let wear = FabricServer::start_with_auth("127.0.0.1:0", lethal_cfg(0xB), Some(psk.clone()))
        .unwrap();
    let healthy = FabricServer::start_with_auth("127.0.0.1:0", healthy_cfg(0xA), Some(psk.clone()))
        .unwrap();
    let addrs = vec![wear.local_addr().to_string(), healthy.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(psk.clone(), 0)).unwrap();
    let k_wear = kind_on_shard(&router, 0);
    let k_ok = kind_on_shard(&router, 1);

    // Phase 1: mixed load. The wear shard's crossbar dies under it; the
    // march scrub detects the stuck cells, remaps into the spares,
    // escalates the policy, and retires the worker. The router converts
    // the resulting capacity errors into failover — values stay correct
    // throughout (nothing here asserts less than full correctness).
    let reqs: Vec<(FunctionKind, u64, u64)> = (0..600u64)
        .map(|i| {
            let k = if i % 2 == 0 { k_wear } else { k_ok };
            (k, i % 13, (i * 5) % 13)
        })
        .collect();
    run_checked(&router, &reqs);
    assert_eq!(router.live_shards(), 1, "retire-all must drop the wear shard from routing");

    // The merged journal pulls the (down but still listening) wear
    // shard's events over the authenticated control plane, re-stamped
    // with its fleet slot.
    wait_until("reliability chain in the fleet journal", Duration::from_secs(10), || {
        let t = router.fleet_events();
        let has = |f: fn(&EventKind) -> bool| t.iter().any(|e| e.shard == 0 && f(&e.kind));
        has(|k| matches!(k, EventKind::Scrub { .. }))
            && has(|k| matches!(k, EventKind::StuckCell { .. }))
            && has(|k| matches!(k, EventKind::RowRemap { .. }))
            && has(|k| matches!(k, EventKind::PolicyEscalate { .. }))
            && has(|k| matches!(k, EventKind::WorkerRetire { .. }))
            && has(|k| matches!(k, EventKind::ShardDown { .. }))
    });

    // Phase 2: kill the wear shard's process outright, then revive the
    // slot with a healthy replacement on the exact same address.
    shutdown_endpoint_auth(&addrs[0], Some(&psk)).unwrap();
    let revived = restart_with_auth(&addrs[0], healthy_cfg(0xC), &psk);
    wait_until("wear slot revived", Duration::from_secs(10), || router.live_shards() == 2);
    wait_until("ShardRevive in the fleet journal", Duration::from_secs(10), || {
        router
            .fleet_events()
            .iter()
            .any(|e| e.shard == 0 && matches!(e.kind, EventKind::ShardRevive { .. }))
    });
    assert_eq!(router.shard_for(k_wear), Some(0), "revived slot reclaims its kinds");
    run_checked(&router, &[(k_wear, 20, 22), (k_ok, 7, 8)]);

    // The merged timeline tells the whole story, in causal order.
    let timeline = router.fleet_events();
    let scrub = first_idx(&timeline, 0, |k| matches!(k, EventKind::Scrub { .. }));
    let stuck = first_idx(&timeline, 0, |k| matches!(k, EventKind::StuckCell { .. }));
    let remap = first_idx(&timeline, 0, |k| matches!(k, EventKind::RowRemap { .. }));
    let escalate = first_idx(&timeline, 0, |k| matches!(k, EventKind::PolicyEscalate { .. }));
    let retire = first_idx(&timeline, 0, |k| matches!(k, EventKind::WorkerRetire { .. }));
    let down = first_idx(&timeline, 0, |k| matches!(k, EventKind::ShardDown { .. }));
    let revive = first_idx(&timeline, 0, |k| matches!(k, EventKind::ShardRevive { .. }));
    assert!(scrub < stuck && stuck < remap, "scrub detects, then remaps: {timeline:#?}");
    assert!(remap < escalate, "escalation follows the scrub findings: {timeline:#?}");
    assert!(escalate < retire, "retirement is the last in-shard step: {timeline:#?}");
    assert!(retire < down, "the shard goes down after its worker retires: {timeline:#?}");
    assert!(down < revive, "revival concludes the chain: {timeline:#?}");

    // Attribution: the healthy (immortal) shard can never produce the
    // in-shard incident events — every one of them names the wear
    // slot, and only it. (Shard down/revive are asserted on slot 0 via
    // `first_idx` above; a CI scheduler stall can legitimately blip
    // the healthy shard's heartbeat, so membership events are not
    // required to be slot-0-exclusive.)
    for e in &timeline {
        let incident = matches!(
            e.kind,
            EventKind::Scrub { .. }
                | EventKind::StuckCell { .. }
                | EventKind::RowRemap { .. }
                | EventKind::PolicyEscalate { .. }
                | EventKind::WorkerRetire { .. }
        );
        if incident {
            assert_eq!(e.shard, 0, "misattributed event {e:?}");
        }
    }
    // And the chain survives re-pulling: re-importing already-delivered
    // shard events must not duplicate them in the merged view.
    let count = |t: &[Event], f: fn(&EventKind) -> bool| -> usize {
        t.iter().filter(|e| e.shard == 0 && f(&e.kind)).count()
    };
    let again = router.fleet_events();
    assert_eq!(
        count(&again, |k| matches!(k, EventKind::Scrub { .. })),
        count(&timeline, |k| matches!(k, EventKind::Scrub { .. })),
        "a second pull must not duplicate scrub events"
    );
    assert_eq!(
        count(&again, |k| matches!(k, EventKind::WorkerRetire { .. })),
        count(&timeline, |k| matches!(k, EventKind::WorkerRetire { .. })),
        "a second pull must not duplicate retirement events"
    );

    router.shutdown();
    revived.shutdown();
    healthy.shutdown();
}

/// ISSUE 7 acceptance (tracing): with 1-in-1 sampling on an otherwise
/// idle authenticated fleet, a single request's trace must contain all
/// seven pipeline stages — router queue, wire transit, batcher wait,
/// worker exec, ECC verify, TMR vote, readback — each with a non-zero
/// duration, and their sum must fit inside the router-measured
/// end-to-end latency.
#[test]
fn sampled_trace_covers_every_stage_within_e2e() {
    let psk = test_psk("trace");
    let traced = |seed| CoordinatorConfig {
        // The full reliability policy makes every exec-side stage real
        // work: ECC verification, TMR voting and readback all non-zero.
        policy: ReliabilityPolicy::full(),
        trace_sample: 1,
        ..healthy_cfg(seed)
    };
    let s1 = FabricServer::start_with_auth("127.0.0.1:0", traced(0xA), Some(psk.clone())).unwrap();
    let s2 = FabricServer::start_with_auth("127.0.0.1:0", traced(0xB), Some(psk.clone())).unwrap();
    let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
    let router = Router::with_config(&addrs, fast_cfg(psk, 1)).unwrap();
    let k0 = kind_on_shard(&router, 0);
    let k1 = kind_on_shard(&router, 1);

    // Warm both shards (plan caches, connections) so the solo request
    // below measures the steady-state pipeline.
    let warmup: Vec<(FunctionKind, u64, u64)> = (0..64u64)
        .map(|i| {
            let k = if i % 2 == 0 { k0 } else { k1 };
            (k, i % 19, (i * 3 + 1) % 19)
        })
        .collect();
    run_checked(&router, &warmup);

    // Every trace id visible before the solo request; shard-side spans
    // are recorded before the reply is sent, so this set is complete.
    let before: HashSet<u64> = router.fleet_spans().iter().map(|s| s.trace).collect();

    let r = router
        .submit(k0, 41, 1)
        .recv_timeout(Duration::from_secs(30))
        .expect("solo request reply");
    assert!(r.is_ok(), "solo request errored: {:?}", r.error);
    assert_eq!(r.value, k0.reference(41, 1));
    let e2e = r.latency.as_nanos() as u64;

    let spans = router.fleet_spans();
    let fresh: HashSet<u64> =
        spans.iter().map(|s| s.trace).filter(|t| !before.contains(t)).collect();
    assert_eq!(fresh.len(), 1, "exactly one new trace on an idle fleet: {fresh:?}");
    let trace = *fresh.iter().next().unwrap();
    let mine: Vec<&TraceSpan> = spans.iter().filter(|s| s.trace == trace).collect();

    for stage in Stage::ALL {
        let hits: Vec<_> = mine.iter().filter(|s| s.stage == stage).collect();
        assert_eq!(hits.len(), 1, "stage {} recorded exactly once: {mine:#?}", stage.name());
        assert!(hits[0].dur_ns > 0, "stage {} must be non-zero: {mine:#?}", stage.name());
    }
    let sum: u64 = mine.iter().map(|s| s.dur_ns).sum();
    assert!(
        sum <= e2e,
        "stage durations ({sum} ns) must fit inside the end-to-end latency ({e2e} ns)"
    );

    router.shutdown();
    s1.shutdown();
    s2.shutdown();
}
