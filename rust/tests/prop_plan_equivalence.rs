//! §Perf equivalence properties: the plan-compiled execution path and
//! the word-parallel marshalling must be **bit-identical** to the legacy
//! per-bit/per-step paths — same final state, same statistics, same
//! consumed error-injection stream — across gates, directions, lane
//! ranges, TMR modes (including `SemiParallel` row-replica layouts), ECC
//! and injected-error seeds.

use remus::arith::adder::ripple_adder;
use remus::arith::multiplier::{multpim_program, naive_mult_program};
use remus::errs::{ErrorModel, Injector};
use remus::isa::microop::{Dir, LaneRange, MicroOp};
use remus::isa::program::Program;
use remus::isa::ScheduleConfig;
use remus::mmpu::{FunctionKind, FunctionSpec, Mmpu, MmpuConfig, ReliabilityPolicy};
use remus::testutil::prop::{Cases, Gen};
use remus::tmr::{TmrEngine, TmrMode};
use remus::util::rng::Pcg64;
use remus::xbar::{Crossbar, Gate, Partitions};

/// Every error class at rates high enough to exercise the injection
/// plumbing in a few hundred lanes. The time-domain and proximity
/// classes fire on the controller (`exec_vector`) paths; the crossbar
/// paths consume no RNG for them, so one model serves every property.
fn noisy_model() -> ErrorModel {
    ErrorModel {
        p_gate: 2e-2,
        p_write: 2e-2,
        p_input: 1e-2,
        lambda_retention: 2e4, // ~1e-2/bit over a typical microsecond batch
        p_proximity: 1e-2,
        lambda_abrupt: 2e5, // a strike every few batches
    }
}

fn assert_same_execution(
    name: &str,
    prog: &Program,
    rows: usize,
    cols: usize,
    parts: Option<&Partitions>,
    init: &remus::util::bitmat::BitMatrix,
    seed: u64,
) {
    let mut legacy = Crossbar::new(rows, cols);
    *legacy.state_mut() = init.clone();
    if let Some(p) = parts {
        legacy.set_col_partitions(p.clone());
    }
    let mut inj_a = Injector::new(noisy_model(), seed, 0);
    legacy.run_program_uncompiled(prog, Some(&mut inj_a)).unwrap();

    let mut compiled = Crossbar::new(rows, cols);
    *compiled.state_mut() = init.clone();
    if let Some(p) = parts {
        compiled.set_col_partitions(p.clone());
    }
    let plan = compiled.compile_plan(prog).unwrap();
    let mut inj_b = Injector::new(noisy_model(), seed, 0);
    compiled.run_plan(&plan, Some(&mut inj_b)).unwrap();

    assert_eq!(legacy.state(), compiled.state(), "{name}: state diverged");
    assert_eq!(legacy.stats, compiled.stats, "{name}: stats diverged");
    assert_eq!(inj_a.counters, inj_b.counters, "{name}: injector diverged");
}

#[test]
fn prop_plan_matches_uncompiled_adder() {
    Cases::new(25).run(|g| {
        let n = g.usize_in(2..=16) as u32;
        let (prog, _) = ripple_adder(n);
        let rows = g.usize_in(1..=130);
        let cols = prog.width as usize + 4;
        let mut rng = Pcg64::new(g.u64(), 3);
        let init = remus::util::bitmat::BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));
        assert_same_execution("adder", &prog, rows, cols, None, &init, g.u64());
    });
}

#[test]
fn prop_plan_matches_uncompiled_multpim_partitioned() {
    // Partition-parallel steps: the concurrency-heavy workload.
    Cases::new(10).run(|g| {
        let n = *g.pick(&[4u32, 8]);
        let (prog, lay) = multpim_program(n);
        let rows = g.usize_in(1..=96);
        let cols = lay.width as usize;
        let parts = Partitions::new(lay.width, lay.partition_starts.clone());
        let mut rng = Pcg64::new(g.u64(), 4);
        let init = remus::util::bitmat::BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.3));
        assert_same_execution("multpim", &prog, rows, cols, Some(&parts), &init, g.u64());
    });
}

/// Random single-op programs mixing directions, gates and lane ranges —
/// covers the in-column word-tile path and partial-lane masks.
fn random_program(g: &mut Gen, rows: usize, cols: usize, len: usize) -> Program {
    let gates = [
        Gate::Not,
        Gate::Nor2,
        Gate::Nor3,
        Gate::Or2,
        Gate::Nand2,
        Gate::Min3,
        Gate::Set0,
        Gate::Set1,
        Gate::Imply,
        Gate::Nop,
    ];
    let mut prog = Program::new("random");
    for _ in 0..len {
        let gate = *g.pick(&gates);
        let in_col = g.bool();
        let lines = if in_col { rows } else { cols };
        let lanes_max = if in_col { cols } else { rows };
        let out = g.usize_in(0..=lines - 1) as u32;
        let mut operands = Vec::new();
        for _ in 0..gate.arity() {
            // Logic operands must not alias the output line.
            let mut o = g.usize_in(0..=lines - 1) as u32;
            while gate.is_logic() && o == out {
                o = g.usize_in(0..=lines - 1) as u32;
            }
            operands.push(o);
        }
        let lanes = if g.bool() {
            LaneRange::all()
        } else {
            let s = g.usize_in(0..=lanes_max - 1);
            let e = g.usize_in(s + 1..=lanes_max);
            LaneRange::new(s as u32, e as u32)
        };
        let dir = if in_col { Dir::InCol } else { Dir::InRow };
        prog.push(MicroOp::with_dir(dir, gate, &operands, out, lanes));
    }
    prog
}

#[test]
fn prop_plan_matches_uncompiled_random_ops() {
    Cases::new(40).run(|g| {
        let rows = g.usize_in(2..=150);
        let cols = g.usize_in(2..=150);
        let prog = random_program(g, rows, cols, g.usize_in(1..=30));
        let mut rng = Pcg64::new(g.u64(), 5);
        let init = remus::util::bitmat::BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));
        assert_same_execution("random-ops", &prog, rows, cols, None, &init, g.u64());
    });
}

/// mMPU sizing mirroring `quick_exec` (wide enough for every TMR mode).
fn mmpu_config(func: &FunctionSpec, policy: ReliabilityPolicy, items: usize, seed: u64) -> MmpuConfig {
    let need = match policy.tmr {
        TmrMode::Serial => TmrEngine::serial_layout(&func.prog).width,
        TmrMode::Parallel => 3 * func.prog.width + func.out_bits + 2,
        _ => func.prog.width,
    };
    let mut cols = need.next_power_of_two().max(64) as usize;
    if let Some(m) = policy.ecc_m {
        cols = cols.div_ceil(m) * m;
    }
    let mut rows = items.max(4);
    if policy.tmr == TmrMode::SemiParallel {
        rows = 3 * items + 1;
    }
    if let Some(m) = policy.ecc_m {
        rows = rows.div_ceil(m) * m;
    }
    MmpuConfig {
        rows,
        cols,
        num_crossbars: 1,
        policy,
        errors: noisy_model(),
        seed,
        ..Default::default()
    }
}

#[test]
fn prop_exec_vector_word_path_matches_legacy_all_modes() {
    // The full controller path: word-parallel operand scatter, compiled
    // TMR execution, word-parallel readback vs per-bit writes, legacy
    // TMR interpreter, per-bit readback — same seed, identical results,
    // states, stats and injector consumption. Covers the SemiParallel
    // row-replica layout and the Parallel relocated input copies.
    let kinds = [FunctionKind::Add(8), FunctionKind::Mul(8), FunctionKind::Xor(8)];
    let modes =
        [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel];
    Cases::new(12).run(|g| {
        let kind = *g.pick(&kinds);
        let tmr = *g.pick(&modes);
        let ecc_m = if g.bool() { Some(16) } else { None };
        let items = g.usize_in(1..=20);
        let func = FunctionSpec::build(kind);
        let cfg = mmpu_config(&func, ReliabilityPolicy { ecc_m, tmr }, items, g.u64());
        let mask = (1u64 << kind.operand_bits()) - 1;
        let a: Vec<u64> = (0..items).map(|_| g.u64() & mask).collect();
        let b: Vec<u64> = (0..items).map(|_| g.u64() & mask).collect();

        let mut fast = Mmpu::new(cfg.clone());
        let rf = fast.exec_vector(0, &func, &a, &b).unwrap();
        let mut slow = Mmpu::new(cfg);
        let rs = slow.exec_vector_legacy(0, &func, &a, &b).unwrap();

        assert_eq!(rf.values, rs.values, "{kind:?} {tmr:?} ecc={ecc_m:?} values");
        assert_eq!(rf.compute_cycles, rs.compute_cycles, "{kind:?} {tmr:?} cycles");
        assert_eq!(rf.ecc_cycles, rs.ecc_cycles, "{kind:?} {tmr:?} ecc cycles");
        assert_eq!(rf.ecc_corrected, rs.ecc_corrected, "{kind:?} {tmr:?} ecc corrected");
        assert_eq!(
            fast.crossbar(0).state(),
            slow.crossbar(0).state(),
            "{kind:?} {tmr:?} state"
        );
        assert_eq!(fast.stats(0), slow.stats(0), "{kind:?} {tmr:?} stats");
        assert_eq!(
            fast.injector_counters(0),
            slow.injector_counters(0),
            "{kind:?} {tmr:?} injector"
        );
    });
}

#[test]
fn prop_exec_vector_clean_results_correct() {
    // Sanity anchor: with no errors the word-parallel path computes the
    // actual arithmetic across every mode (not merely the same as the
    // reference).
    let modes =
        [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel];
    Cases::new(10).run(|g| {
        let tmr = *g.pick(&modes);
        let items = g.usize_in(1..=24);
        let func = FunctionSpec::build(FunctionKind::Mul(8));
        let mut cfg =
            mmpu_config(&func, ReliabilityPolicy { ecc_m: None, tmr }, items, g.u64());
        cfg.errors = ErrorModel::none();
        let a: Vec<u64> = (0..items).map(|_| g.u64() & 0xFF).collect();
        let b: Vec<u64> = (0..items).map(|_| g.u64() & 0xFF).collect();
        let mut mmpu = Mmpu::new(cfg);
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        for i in 0..items {
            assert_eq!(r.values[i], a[i] * b[i], "{tmr:?} item {i}");
        }
    });
}

/// A random but valid column partition configuration over `cols`.
fn random_col_partitions(g: &mut Gen, cols: usize) -> Partitions {
    let mut starts = vec![0u32];
    let mut at = 0usize;
    loop {
        at += g.usize_in(1..=cols.div_ceil(3));
        if at >= cols {
            break;
        }
        starts.push(at as u32);
    }
    Partitions::new(cols as u32, starts)
}

#[test]
fn prop_scheduled_plan_matches_reference_random_programs() {
    // §Perf list scheduling, the clean-model contract: for any program,
    // any base partition configuration and any schedule, the bundled
    // plan reaches the exact program-order final state with the exact
    // program-order wear accounting — only cycles may shrink — and the
    // scheduler is deterministic.
    Cases::new(40).run(|g| {
        let rows = g.usize_in(2..=150);
        let cols = g.usize_in(2..=150);
        let prog = random_program(g, rows, cols, g.usize_in(1..=30));
        let parts = if g.bool() { Some(random_col_partitions(g, cols)) } else { None };
        let sched = ScheduleConfig::packed(*g.pick(&[0u32, 1, 2, 4, 8, 16]));
        let mut rng = Pcg64::new(g.u64(), 7);
        let init = remus::util::bitmat::BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));

        // Program-order reference: uncompiled, clean.
        let mut reference = Crossbar::new(rows, cols);
        *reference.state_mut() = init.clone();
        if let Some(p) = &parts {
            reference.set_col_partitions(p.clone());
        }
        reference.run_program_uncompiled(&prog, None).unwrap();

        // Compile both plans against the same base configuration.
        let mut base = Crossbar::new(rows, cols);
        if let Some(p) = &parts {
            base.set_col_partitions(p.clone());
        }
        let serial = base.compile_plan(&prog).unwrap();
        let plan = base.compile_plan_scheduled(&prog, sched).unwrap();
        assert!(
            plan.cycles() <= serial.cycles(),
            "scheduling must never add cycles: {} > {}",
            plan.cycles(),
            serial.cycles()
        );
        assert_eq!(plan.num_ops(), serial.num_ops(), "packing drops no ops");

        // Deterministic: an identical compilation is an identical plan.
        let again = base.compile_plan_scheduled(&prog, sched).unwrap();
        assert_eq!(plan.cycles(), again.cycles(), "cycle count must be deterministic");
        assert_eq!(plan.bundle_sizes(), again.bundle_sizes(), "bundles must be deterministic");
        assert_eq!(
            plan.required_col_partitions(),
            again.required_col_partitions(),
            "required grid must be deterministic"
        );

        // Execute the bundled plan and compare bit-for-bit.
        let mut run = Crossbar::new(rows, cols);
        *run.state_mut() = init.clone();
        match plan.required_col_partitions() {
            Some(p) => run.set_col_partitions(p.clone()),
            None => {
                if let Some(p) = &parts {
                    run.set_col_partitions(p.clone());
                }
            }
        }
        run.run_plan(&plan, None).unwrap();
        assert_eq!(reference.state(), run.state(), "scheduled state diverged");
        let (a, b) = (reference.stats, run.stats);
        assert_eq!(a.switched_bits, b.switched_bits, "wear model drifted");
        assert_eq!(a.logic_ops, b.logic_ops);
        assert_eq!(a.init_ops, b.init_ops);
        assert_eq!(a.gate_instances, b.gate_instances);
        assert!((a.energy_pj - b.energy_pj).abs() < 1e-6, "{} vs {}", a.energy_pj, b.energy_pj);
        // Cycle accounting: exactly one cycle per bundle (reconfigs
        // are tracked separately and stay visible).
        assert_eq!(b.cycles - b.reconfigs, plan.cycles() as u64);
    });
}

#[test]
fn prop_mmpu_scheduled_matches_serial_every_kind_and_mode_clean() {
    // The full controller path under a schedule: for every FunctionKind
    // family and TmrMode, scheduled plans return the same values, final
    // state and wear as the serial reference — in no more compute
    // cycles — and the arithmetic stays correct.
    let kinds =
        [FunctionKind::Add(8), FunctionKind::Mul(8), FunctionKind::MulNaive(4), FunctionKind::Xor(8)];
    let modes = [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel];
    Cases::new(16).run(|g| {
        let kind = *g.pick(&kinds);
        let tmr = *g.pick(&modes);
        let items = g.usize_in(1..=16);
        let func = FunctionSpec::build(kind);
        let mut cfg = mmpu_config(&func, ReliabilityPolicy { ecc_m: None, tmr }, items, g.u64());
        cfg.errors = ErrorModel::none();
        let mask = (1u64 << kind.operand_bits()) - 1;
        let a: Vec<u64> = (0..items).map(|_| g.u64() & mask).collect();
        let b: Vec<u64> = (0..items).map(|_| g.u64() & mask).collect();

        let mut serial = Mmpu::new(cfg.clone());
        let rs = serial.exec_vector(0, &func, &a, &b).unwrap();
        let mut sched_cfg = cfg;
        sched_cfg.schedule = ScheduleConfig::packed(*g.pick(&[2u32, 4, 8, 16]));
        let mut sched = Mmpu::new(sched_cfg);
        let rf = sched.exec_vector(0, &func, &a, &b).unwrap();

        assert_eq!(rf.values, rs.values, "{kind:?} {tmr:?} values");
        for i in 0..items {
            assert_eq!(rf.values[i], kind.reference(a[i], b[i]), "{kind:?} {tmr:?} item {i}");
        }
        assert!(
            rf.compute_cycles <= rs.compute_cycles,
            "{kind:?} {tmr:?}: scheduled {} > serial {}",
            rf.compute_cycles,
            rs.compute_cycles
        );
        assert_eq!(
            sched.crossbar(0).state(),
            serial.crossbar(0).state(),
            "{kind:?} {tmr:?} state"
        );
        let (x, y) = (serial.stats(0), sched.stats(0));
        assert_eq!(x.switched_bits, y.switched_bits, "{kind:?} {tmr:?} wear");
        assert_eq!(x.logic_ops, y.logic_ops, "{kind:?} {tmr:?} logic ops");
        assert_eq!(x.gate_instances, y.gate_instances, "{kind:?} {tmr:?} gate instances");
    });
}

#[test]
fn prop_naive_mult_plan_matches_uncompiled() {
    // Long single-partition serial programs (the O(n^2) baseline).
    Cases::new(6).run(|g| {
        let (prog, lay) = naive_mult_program(4);
        let rows = g.usize_in(1..=40);
        let cols = lay.width as usize;
        let mut rng = Pcg64::new(g.u64(), 6);
        let init = remus::util::bitmat::BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));
        assert_same_execution("naive-mult", &prog, rows, cols, None, &init, g.u64());
    });
}
