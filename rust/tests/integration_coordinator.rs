//! Integration: the coordinator under load — correctness, batching
//! efficiency, backpressure, reliability policies on the request path.

use std::time::Duration;

use remus::coordinator::{Coordinator, CoordinatorConfig};
use remus::errs::ErrorModel;
use remus::mmpu::{FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;

#[test]
fn thousand_requests_all_correct() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        rows: 64,
        cols: 512,
        max_batch: 64,
        // Generous window: under `cargo test` CPU contention the submit
        // loop itself can take hundreds of us; batching behaviour with a
        // tight window is covered by the unit tests and perf bench.
        max_wait: Duration::from_millis(20),
        ..Default::default()
    })
    .unwrap();
    let n = 1000u64;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, coord.submit(FunctionKind::Mul(8), i % 251, (i * 3) % 251))).collect();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(r.value, (i % 251) * ((i * 3) % 251), "request {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, n);
    assert!(
        m.mean_batch_size() > 4.0,
        "dynamic batching must aggregate: mean={}",
        m.mean_batch_size()
    );
    coord.shutdown();
}

#[test]
fn reliable_policy_on_request_path() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 1024,
        policy: ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Serial },
        errors: ErrorModel::direct_only(1e-6),
        max_batch: 32,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    })
    .unwrap();
    let n = 256u64;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, coord.submit(FunctionKind::Add(16), i * 17, i * 5))).collect();
    let mut correct = 0;
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        if r.value == i * 22 {
            correct += 1;
        }
    }
    // At p=1e-6 with TMR, essentially everything is correct.
    assert!(correct >= n - 1, "correct {correct}/{n}");
    coord.shutdown();
}

#[test]
fn backpressure_does_not_deadlock_or_drop() {
    // Tiny queues + one worker + a burst far larger than capacity.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        rows: 8,
        cols: 256,
        max_batch: 8,
        max_wait: Duration::from_micros(50),
        worker_queue: 1,
        ..Default::default()
    })
    .unwrap();
    let n = 512u64;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(FunctionKind::Xor(8), i % 256, 0xAA)).collect();
    let mut got = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(60)).expect("no drops under pressure");
        assert_eq!(r.value, (i as u64 % 256) ^ 0xAA);
        got += 1;
    }
    assert_eq!(got, n);
    coord.shutdown();
}

#[test]
fn latency_histogram_populates() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 16,
        cols: 256,
        max_batch: 16,
        max_wait: Duration::from_micros(100),
        ..Default::default()
    })
    .unwrap();
    let rxs: Vec<_> = (0..64u64).map(|i| coord.submit(FunctionKind::Add(8), i, i)).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let m = coord.metrics();
    let p50 = m.latency_percentile_us(50.0);
    let p99 = m.latency_percentile_us(99.0);
    assert!(p50 > 0 && p99 >= p50, "p50={p50} p99={p99}");
    coord.shutdown();
}
