//! Integration: the coordinator under load — correctness, batching
//! efficiency, backpressure, reliability policies on the request path,
//! shutdown draining, and §Health retirement/redistribution.

use std::time::Duration;

use remus::coordinator::{Coordinator, CoordinatorConfig};
use remus::errs::ErrorModel;
use remus::health::{HealthConfig, WearModel};
use remus::mmpu::{FunctionKind, ReliabilityPolicy};
use remus::tmr::TmrMode;

#[test]
fn thousand_requests_all_correct() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 4,
        rows: 64,
        cols: 512,
        max_batch: 64,
        // Generous window: under `cargo test` CPU contention the submit
        // loop itself can take hundreds of us; batching behaviour with a
        // tight window is covered by the unit tests and perf bench.
        max_wait: Duration::from_millis(20),
        ..Default::default()
    })
    .unwrap();
    let n = 1000u64;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, coord.submit(FunctionKind::Mul(8), i % 251, (i * 3) % 251))).collect();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert_eq!(r.value, (i % 251) * ((i * 3) % 251), "request {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, n);
    assert!(
        m.mean_batch_size() > 4.0,
        "dynamic batching must aggregate: mean={}",
        m.mean_batch_size()
    );
    coord.shutdown();
}

#[test]
fn reliable_policy_on_request_path() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 1024,
        policy: ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Serial },
        errors: ErrorModel::direct_only(1e-6),
        max_batch: 32,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    })
    .unwrap();
    let n = 256u64;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, coord.submit(FunctionKind::Add(16), i * 17, i * 5))).collect();
    let mut correct = 0;
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        if r.value == i * 22 {
            correct += 1;
        }
    }
    // At p=1e-6 with TMR, essentially everything is correct.
    assert!(correct >= n - 1, "correct {correct}/{n}");
    coord.shutdown();
}

#[test]
fn backpressure_does_not_deadlock_or_drop() {
    // Tiny queues + one worker + a burst far larger than capacity.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        rows: 8,
        cols: 256,
        max_batch: 8,
        max_wait: Duration::from_micros(50),
        worker_queue: 1,
        ..Default::default()
    })
    .unwrap();
    let n = 512u64;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(FunctionKind::Xor(8), i % 256, 0xAA)).collect();
    let mut got = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(60)).expect("no drops under pressure");
        assert_eq!(r.value, (i as u64 % 256) ^ 0xAA);
        got += 1;
    }
    assert_eq!(got, n);
    coord.shutdown();
}

#[test]
fn shutdown_drains_inflight_batches_to_completion() {
    // Requests still pending in the batcher at shutdown must drain to the
    // workers and produce real values — not hangs, not dropped channels.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 64,
        cols: 256,
        max_batch: 64,                     // never fills
        max_wait: Duration::from_secs(60), // never expires
        ..Default::default()
    })
    .unwrap();
    let rxs: Vec<_> = (0..24u64).map(|i| (i, coord.submit(FunctionKind::Add(8), i, i))).collect();
    coord.shutdown();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(5)).expect("drained result");
        assert!(r.is_ok(), "request {i}: {:?}", r.error);
        assert_eq!(r.value, 2 * i, "request {i}");
    }
}

#[test]
fn no_workers_yields_explicit_errors_not_hangs() {
    // Degenerate fleet (everything retired / zero workers): every request
    // must come back with RequestResult::error, never a dropped channel.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 0,
        rows: 16,
        cols: 256,
        max_batch: 4,
        max_wait: Duration::from_micros(50),
        ..Default::default()
    })
    .unwrap();
    let rxs: Vec<_> = (0..12u64).map(|i| coord.submit(FunctionKind::Add(8), i, 1)).collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(5)).expect("explicit error result");
        assert!(!r.is_ok());
        assert!(r.error.as_deref().unwrap().contains("no healthy workers"), "{:?}", r.error);
    }
    let m = coord.metrics();
    assert_eq!(m.failed, 12);
    assert_eq!(m.completed, 0);
    coord.shutdown();
}

#[test]
fn wear_out_retires_crossbar_and_errors_explicitly() {
    // §Health end-to-end: an absurdly low endurance budget kills the
    // (single) worker's crossbar after the first batch; the march scrub
    // detects the carnage, the worker retires, and later requests get
    // explicit "no healthy workers" errors instead of wrong values.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    })
    .unwrap();
    // First request executes before any wear is applied.
    let r = coord
        .submit(FunctionKind::Add(8), 20, 22)
        .recv_timeout(Duration::from_secs(10))
        .expect("first result");
    assert!(r.is_ok());
    assert_eq!(r.value, 42);
    // Subsequent requests hit the retired fleet; all must resolve, and
    // at least one must carry the explicit retirement error.
    let mut errors = 0;
    for i in 0..50u64 {
        let r = coord
            .submit(FunctionKind::Add(8), i, 1)
            .recv_timeout(Duration::from_secs(10))
            .expect("resolved result (value or error), never a hang");
        if !r.is_ok() {
            errors += 1;
        }
    }
    assert!(errors > 0, "retirement must surface as explicit errors");
    let m = coord.metrics();
    assert_eq!(m.retired_workers(), 1, "worker health must report retirement");
    let wh = &m.worker_health[0];
    assert!(wh.stuck_detected >= 8, "march scrub must detect the dead cells");
    coord.shutdown();
}

#[test]
fn hot_spare_restores_routing_capacity_after_retirement() {
    // Same lethal-wear setup as above, but with one cold spare: when
    // worker 0's crossbar retires, the spare must be activated so
    // routing capacity is restored (requests keep succeeding) instead
    // of the fleet shrinking to zero.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        spare_workers: 1,
        rows: 16,
        cols: 256,
        max_batch: 1,
        max_wait: Duration::from_micros(10),
        health: Some(HealthConfig {
            wear: WearModel::accelerated(1e-6), // dead after any switching
            spare_rows: 2,
            scrub_interval: 1,
            scrub_rows_per_pass: 16,
            retire_stuck_cells: 8,
            ..Default::default()
        }),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(coord.healthy_workers(), 1, "the spare is cold, not routable");
    // Request 1 executes on worker 0 before wear lands; the post-batch
    // scrub then detects the worn crossbar and retires it, activating
    // the spare.
    let r = coord
        .submit(FunctionKind::Add(8), 20, 22)
        .recv_timeout(Duration::from_secs(10))
        .expect("first result");
    assert!(r.is_ok());
    assert_eq!(r.value, 42);
    // Capacity must be restored: the next request lands on the spare's
    // fresh crossbar (worker 0's queued leftovers requeue onto it too)
    // and succeeds. The spare then wears out and retires in turn.
    let r = coord
        .submit(FunctionKind::Add(8), 7, 8)
        .recv_timeout(Duration::from_secs(10))
        .expect("second result");
    assert!(r.is_ok(), "spare must restore capacity: {:?}", r.error);
    assert_eq!(r.value, 15);
    // Drive the spare through its own wear-out/retirement: eventually
    // the fleet is empty and requests error explicitly.
    let mut errors = 0;
    for i in 0..50u64 {
        let r = coord
            .submit(FunctionKind::Add(8), i, 1)
            .recv_timeout(Duration::from_secs(10))
            .expect("resolved result, never a hang");
        if !r.is_ok() {
            errors += 1;
        }
    }
    assert!(errors > 0, "with the spare also retired, errors surface explicitly");
    assert!(!coord.is_serving(), "retire-all flips the capacity probe");
    let m = coord.metrics();
    assert_eq!(m.worker_health.len(), 2, "active + spare in the health table");
    assert_eq!(m.retired_workers(), 2, "both crossbars retired in the end");
    coord.shutdown();
}

#[test]
fn health_on_clean_hardware_is_transparent() {
    // A healthy fleet with the manager enabled must behave exactly like
    // the plain fleet: correct results, no retirement, no escalation.
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 32,
        cols: 256,
        max_batch: 16,
        max_wait: Duration::from_micros(100),
        health: Some(HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 4,
            ..Default::default()
        }),
        ..Default::default()
    })
    .unwrap();
    let n = 256u64;
    let rxs: Vec<_> =
        (0..n).map(|i| (i, coord.submit(FunctionKind::Mul(8), i % 251, (i * 3) % 251))).collect();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(r.is_ok(), "request {i}: {:?}", r.error);
        assert_eq!(r.value, (i % 251) * ((i * 3) % 251), "request {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.completed, n);
    assert_eq!(m.retired_workers(), 0);
    for wh in &m.worker_health {
        assert_eq!(wh.stuck_detected, 0);
        assert_eq!(wh.remapped_rows, 0);
        assert_eq!(wh.policy_level, 0);
    }
    assert!(
        m.worker_health.iter().any(|wh| wh.scrubs > 0),
        "scrubbing must have run in the background"
    );
    coord.shutdown();
}

#[test]
fn latency_histogram_populates() {
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 2,
        rows: 16,
        cols: 256,
        max_batch: 16,
        max_wait: Duration::from_micros(100),
        ..Default::default()
    })
    .unwrap();
    let rxs: Vec<_> = (0..64u64).map(|i| coord.submit(FunctionKind::Add(8), i, i)).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let m = coord.metrics();
    let p50 = m.latency_percentile_us(50.0);
    let p99 = m.latency_percentile_us(99.0);
    assert!(p50 > 0 && p99 >= p50, "p50={p50} p99={p99}");
    coord.shutdown();
}
