//! Integration: arithmetic synthesis at full width, cross-checked
//! between the crossbar simulator and the single-lane interpreter.

use remus::analysis::lane::{FaultPlan, LaneSim};
use remus::arith::adder::ripple_adder;
use remus::arith::multiplier::{multpim_program, naive_mult_program};
use remus::util::rng::Pcg64;
use remus::xbar::{Crossbar, Partitions};

#[test]
fn multpim32_full_crossbar_128_rows() {
    // 128 32-bit multiplications in one program run.
    let (prog, lay) = multpim_program(32);
    let mut x = Crossbar::new(128, lay.width as usize);
    x.set_col_partitions(Partitions::new(lay.width, lay.partition_starts.clone()));
    let mut rng = Pcg64::new(7, 7);
    let pairs: Vec<(u64, u64)> =
        (0..128).map(|_| (rng.next_u64() & 0xFFFF_FFFF, rng.next_u64() & 0xFFFF_FFFF)).collect();
    for (r, &(a, b)) in pairs.iter().enumerate() {
        for k in 0..32 {
            x.state_mut().set(r, lay.a_cols[k] as usize, (a >> k) & 1 == 1);
            x.state_mut().set(r, lay.b_cols[k] as usize, (b >> k) & 1 == 1);
        }
    }
    x.run_program(&prog, None).unwrap();
    for (r, &(a, b)) in pairs.iter().enumerate() {
        let mut v = 0u64;
        for i in 0..64 {
            if x.get(r, lay.result.col(i) as usize) {
                v |= 1 << i;
            }
        }
        assert_eq!(v, a * b, "row {r}: {a}*{b}");
    }
}

#[test]
fn lane_sim_equals_crossbar_for_all_functions() {
    // The MC engine (lane sim) and the array simulator must agree.
    let mut rng = Pcg64::new(9, 0);
    for n in [4u32, 8, 16] {
        for (prog, a_cols, b_cols, out_cols) in [
            {
                let (p, l) = multpim_program(n);
                (p, l.a_cols.clone(), l.b_cols.clone(), l.result.cols())
            },
            {
                let (p, l) = naive_mult_program(n);
                (p, l.a_cols.clone(), l.b_cols.clone(), l.result.cols())
            },
            {
                let (p, l) = ripple_adder(n);
                let mut outs = l.sum.cols();
                outs.push(l.cout);
                (p, l.a.cols(), l.b.cols(), outs)
            },
        ] {
            let a = rng.next_u64() & ((1 << n) - 1);
            let b = rng.next_u64() & ((1 << n) - 1);
            let mut lane = LaneSim::new(prog.width as usize);
            lane.load(&a_cols, a);
            lane.load(&b_cols, b);
            lane.run(&prog, FaultPlan::None);
            let lane_out = lane.read(&out_cols);

            let mut x = Crossbar::new(4, prog.width as usize);
            if prog.partition_starts.len() > 1 {
                x.set_col_partitions(Partitions::new(prog.width, prog.partition_starts.clone()));
            }
            for k in 0..n as usize {
                x.state_mut().set(0, a_cols[k] as usize, (a >> k) & 1 == 1);
                x.state_mut().set(0, b_cols[k] as usize, (b >> k) & 1 == 1);
            }
            x.run_program(&prog, None).unwrap();
            let mut xbar_out = 0u64;
            for (i, &c) in out_cols.iter().enumerate() {
                if x.get(0, c as usize) {
                    xbar_out |= 1 << i;
                }
            }
            assert_eq!(lane_out, xbar_out, "{} n={n}", prog.name);
        }
    }
}

#[test]
fn multiplier_latency_hierarchy() {
    // Partition-parallel MultPIM must scale ~linearly in N (cycles),
    // the naive baseline ~quadratically.
    let (m8, _) = multpim_program(8);
    let (m32, _) = multpim_program(32);
    let ratio_mp = m32.cycles() as f64 / m8.cycles() as f64;
    assert!((3.0..6.5).contains(&ratio_mp), "multpim 8->32 cycle ratio {ratio_mp}");
    let (n8, _) = naive_mult_program(8);
    let (n32, _) = naive_mult_program(32);
    let ratio_nv = n32.cycles() as f64 / n8.cycles() as f64;
    assert!(ratio_nv > 10.0, "naive 8->32 cycle ratio {ratio_nv}");
}

#[test]
fn gate_count_drives_fig4_regime() {
    // The 32-bit multiplier's soft-error site count G, with measured
    // masking alpha~0.5..0.8, must put the baseline curve in the paper's
    // regime: p_mult(1e-9) in [2e-6, 2e-5].
    let (prog, _) = multpim_program(32);
    let g = prog.logic_gates_per_lane() as f64;
    let p_low = 1.0 - (1.0 - 0.4 * 1e-9f64).powf(g);
    let p_high = 1.0 - (1.0 - 0.9 * 1e-9f64).powf(g);
    assert!(p_low > 1e-6 && p_high < 2e-5, "G={g}: [{p_low}, {p_high}]");
}
