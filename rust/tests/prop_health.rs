//! §Health properties:
//!
//! 1. A scrub pass restores an ECC-clean crossbar state: for any random
//!    state and any drift placement with at most one flip per ECC block,
//!    `CrossbarHealth::scrub` returns the array to its exact pre-drift
//!    contents (and the march test itself is transparent).
//! 2. Spare-row remapping is data-preserving under random fault
//!    placement: after detection + remapping, vectored executions return
//!    exact results even though ground-truth stuck cells litter the data
//!    rows the batch would otherwise use.

use remus::ecc::DiagonalEcc;
use remus::errs::ErrorModel;
use remus::health::{CrossbarHealth, HealthConfig, WearModel};
use remus::mmpu::{FunctionKind, FunctionSpec, Mmpu, MmpuConfig, ReliabilityPolicy};
use remus::testutil::prop::Cases;
use remus::util::bitmat::BitMatrix;
use remus::util::rng::Pcg64;

fn immortal_cfg(spares: usize, rows_per_pass: usize) -> HealthConfig {
    HealthConfig {
        wear: WearModel::immortal(),
        spare_rows: spares,
        scrub_interval: 1,
        scrub_rows_per_pass: rows_per_pass,
        ..Default::default()
    }
}

#[test]
fn prop_scrub_restores_ecc_clean_state() {
    Cases::new(32).run(|g| {
        let (rows, cols, m) = (32usize, 64usize, 8usize);
        let mut rng = Pcg64::new(g.u64(), 0);
        let golden = BitMatrix::from_fn(rows, cols, |_, _| rng.bernoulli(0.5));
        let mut state = golden.clone();
        let mut ecc = DiagonalEcc::new(rows, cols, m);
        ecc.encode(&state);
        // Drift: at most one flip per ECC block, in a random subset of
        // blocks — the single-error regime the code corrects exactly.
        let mut flips = 0;
        for bi in 0..rows / m {
            for bj in 0..cols / m {
                if g.bool() {
                    let r = bi * m + g.usize_in(0..=m - 1);
                    let c = bj * m + g.usize_in(0..=m - 1);
                    state.flip(r, c);
                    flips += 1;
                }
            }
        }
        let mut h = CrossbarHealth::new(rows, cols, immortal_cfg(4, rows), g.u64());
        let rep = h.scrub(&mut state, Some(&mut ecc));
        assert_eq!(rep.corrected, flips, "every single-error block repaired");
        assert_eq!(rep.uncorrectable, 0);
        assert_eq!(rep.detected, 0, "no stuck cells -> no detections");
        assert_eq!(state, golden, "scrub (ECC + march) must be transparent");
        assert!(ecc.verify_all(&state).is_empty(), "ECC-clean after scrub");
    });
}

#[test]
fn prop_spare_row_remap_is_data_preserving() {
    Cases::new(24).run(|g| {
        let rows = 32usize;
        let cols = 256usize;
        let spares = 6usize;
        let cfg = MmpuConfig {
            rows,
            cols,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: g.u64(),
            ..Default::default()
        };
        let mut mmpu = Mmpu::new(cfg);
        mmpu.enable_health(immortal_cfg(spares, rows));
        // Random persistent faults: up to `spares` distinct data rows,
        // 1..3 stuck cells each, anywhere in the function's column span.
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let width = func.prog.width as usize;
        let n_rows = g.usize_in(1..=spares);
        let mut bad_rows = Vec::new();
        {
            let h = mmpu.health_mut(0).unwrap();
            for _ in 0..n_rows {
                let r = g.usize_in(0..=rows - spares - 1) as u32;
                for _ in 0..g.usize_in(1..=3) {
                    let c = g.usize_in(0..=width - 1) as u32;
                    h.inject_stuck(r, c, g.bool());
                }
                bad_rows.push(r);
            }
        }
        // One full-array scrub detects every fault and remaps the rows.
        let rep = mmpu.health_scrub(0).unwrap();
        bad_rows.sort_unstable();
        bad_rows.dedup();
        assert!(rep.detected >= bad_rows.len() as u64, "{rep:?}");
        assert_eq!(rep.remapped, bad_rows.len() as u64, "{rep:?}");
        assert!(!rep.exhausted);
        // Data-preservation: a full-capacity batch executes exactly.
        let items = rows - spares;
        let a: Vec<u64> = (0..items as u64).map(|i| (i * 37) % 256).collect();
        let b: Vec<u64> = (0..items as u64).map(|i| (i * 91 + 5) % 256).collect();
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        for i in 0..items {
            assert_eq!(r.values[i], a[i] + b[i], "item {i} after remap");
        }
        // And again (remap must be stable across batches).
        let r = mmpu.exec_vector(0, &func, &b, &a).unwrap();
        for i in 0..items {
            assert_eq!(r.values[i], a[i] + b[i], "item {i} second batch");
        }
    });
}

#[test]
fn prop_wear_population_is_monotone_and_calibrated() {
    // The statistical wear process: dead-cell population follows the
    // lognormal CDF of the mean per-cell switch count, never shrinks,
    // and lands near the expectation for a large array.
    Cases::new(8).run(|g| {
        let (rows, cols) = (64usize, 64usize);
        let cells = (rows * cols) as f64;
        let wear = WearModel::accelerated(1000.0);
        let hcfg = HealthConfig { wear, ..Default::default() };
        let mut h = CrossbarHealth::new(rows, cols, hcfg, g.u64());
        let mut last = 0;
        for step in 1..=8u64 {
            // on_batch consumes cumulative switched_bits.
            h.on_batch(step * 500 * (rows * cols) as u64 / 8, 0);
            let now = h.stats().stuck_cells_true;
            assert!(now >= last, "wear population must be monotone");
            last = now;
        }
        // After 500 mean switches vs a 1000-switch median budget:
        let expect = cells * wear.dead_fraction(500.0);
        let got = last as f64;
        assert!(
            (got - expect).abs() <= expect * 0.05 + 2.0,
            "wear calibration: got {got}, expect {expect}"
        );
    });
}
