//! ASCII table / CSV rendering shared by benches and examples, so every
//! figure/table reproduction prints in a consistent, diff-able format.

/// A simple column-aligned table with a title, printed to stdout and
/// optionally mirrored as CSV.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write the CSV next to the binary run (for plotting).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// Format a probability in compact scientific notation.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("333"));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert_eq!(csv.lines().next().unwrap(), "a,bbbb");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn sci_format() {
        assert_eq!(sci(0.0), "0");
        assert!(sci(1.5e-9).starts_with("1.5"));
    }
}
