//! Deterministic pseudo-random number generation for simulation.
//!
//! The offline vendor set has no `rand` crate, so REMUS ships its own
//! PCG64 (O'Neill's PCG XSL RR 128/64) plus SplitMix64 for seeding and
//! stream derivation. Every stochastic component (error injectors,
//! workload generators, Monte-Carlo campaigns) takes an explicit seed so
//! every experiment in EXPERIMENTS.md is bit-reproducible.

/// SplitMix64: tiny, high-quality seed expander.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL RR 128/64 — the simulation workhorse RNG.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Seed a generator; `stream` selects an independent sequence, so
    /// parallel workers can share a seed without sharing a stream.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ 0xA02B_DBF7_BB3C_0A7C);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut smi = SplitMix64::new(stream ^ 0x6A09_E667_F3BC_C909);
        let i0 = smi.next_u64() as u128;
        let i1 = smi.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1,
        };
        rng.next_u64();
        rng
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Geometric skip sampling: number of Bernoulli(p) failures before the
    /// next success, i.e. the gap to the next "hit" when scanning a long
    /// sequence of independent trials. Returns `u64::MAX` when p <= 0.
    ///
    /// This is the hot-path trick that turns O(R) per-row error sampling
    /// into O(R * p): sample the index of the next flipped bit directly.
    #[inline]
    pub fn geometric(&mut self, p: f64) -> u64 {
        if p <= 0.0 {
            return u64::MAX;
        }
        if p >= 1.0 {
            return 0;
        }
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        (u.ln() / (1.0 - p).ln()) as u64
    }

    /// Binomial(n, p) sample. Uses direct geometric skipping for small
    /// n*p, normal approximation for large n*p — accurate enough for the
    /// Monte-Carlo campaign sizes used here.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if p <= 0.0 || n == 0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        let np = n as f64 * p;
        if np < 64.0 || n < 256 {
            // Geometric skipping: expected O(np) iterations.
            let mut count = 0u64;
            let mut i = self.geometric(p);
            while i < n {
                count += 1;
                i = i.saturating_add(1 + self.geometric(p));
            }
            count
        } else {
            // Normal approximation with continuity correction, clamped.
            let sd = (np * (1.0 - p)).sqrt();
            let z = self.gaussian();
            let x = (np + sd * z + 0.5).floor();
            x.clamp(0.0, n as f64) as u64
        }
    }

    /// Standard normal via Box-Muller (one value; no caching for simplicity).
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// A random 64-bit word with each bit set independently with prob p.
    /// Fast paths: p == 0 -> 0, p == 0.5 -> raw word.
    pub fn bit_mask(&mut self, p: f64) -> u64 {
        if p <= 0.0 {
            return 0;
        }
        if (p - 0.5).abs() < 1e-12 {
            return self.next_u64();
        }
        let mut w = 0u64;
        let mut i = self.geometric(p);
        while i < 64 {
            w |= 1 << i;
            i = i.saturating_add(1 + self.geometric(p));
        }
        w
    }

    /// Derive a child RNG (independent stream) — used to give each worker
    /// thread / crossbar its own sequence.
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64(), self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg64::new(7, 7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg64::new(3, 0);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate_matches_p() {
        let mut r = Pcg64::new(9, 1);
        let n = 200_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.125)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.125).abs() < 0.005, "rate={rate}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Pcg64::new(11, 0);
        let p = 0.02;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.geometric(p) as f64).sum::<f64>() / n as f64;
        let expect = (1.0 - p) / p;
        assert!((mean - expect).abs() < expect * 0.05, "mean={mean} expect={expect}");
    }

    #[test]
    fn geometric_degenerate() {
        let mut r = Pcg64::new(1, 0);
        assert_eq!(r.geometric(0.0), u64::MAX);
        assert_eq!(r.geometric(1.0), 0);
    }

    #[test]
    fn binomial_small_and_large_consistent() {
        let mut r = Pcg64::new(5, 5);
        let trials = 20_000;
        let mean_small: f64 =
            (0..trials).map(|_| r.binomial(1000, 1e-3) as f64).sum::<f64>() / trials as f64;
        assert!((mean_small - 1.0).abs() < 0.05, "small {mean_small}");
        let mean_large: f64 =
            (0..trials).map(|_| r.binomial(10_000, 0.25) as f64).sum::<f64>() / trials as f64;
        assert!((mean_large - 2500.0).abs() < 10.0, "large {mean_large}");
    }

    #[test]
    fn bit_mask_density() {
        let mut r = Pcg64::new(13, 2);
        let p = 0.1;
        let total: u32 = (0..10_000).map(|_| r.bit_mask(p).count_ones()).sum();
        let rate = total as f64 / (10_000.0 * 64.0);
        assert!((rate - p).abs() < 0.01, "rate={rate}");
        assert_eq!(r.bit_mask(0.0), 0);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(17, 0);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
