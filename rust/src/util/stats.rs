//! Small statistics toolkit for the reliability analysis
//! (Monte-Carlo estimates, confidence intervals, extrapolation helpers).

/// Wilson score interval for a binomial proportion (95 % by default z).
pub fn wilson_interval(successes: u64, trials: u64, z: f64) -> (f64, f64) {
    if trials == 0 {
        return (0.0, 1.0);
    }
    let n = trials as f64;
    let p = successes as f64 / n;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let center = (p + z2 / (2.0 * n)) / denom;
    let half = (z / denom) * (p * (1.0 - p) / n + z2 / (4.0 * n * n)).sqrt();
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// `1 - (1 - p)^n` computed without catastrophic cancellation for tiny p
/// (the paper's extrapolation formula, e.g. `1-(1-p_mask*p_mult)^M`).
pub fn one_minus_pow(p: f64, n: f64) -> f64 {
    if p <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return 1.0;
    }
    // 1 - exp(n * ln(1-p)); ln_1p for accuracy.
    let x = n * (-p).ln_1p();
    -x.exp_m1()
}

/// Complementary error function (A&S 7.1.26, |eps| < 1.5e-7).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    poly * (-x * x).exp()
}

/// Standard normal CDF Phi(x) (used by the lognormal endurance model).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Binomial tail P[X >= 2] for X ~ Bin(n, p), numerically stable for tiny p.
pub fn prob_at_least_two(n: f64, p: f64) -> f64 {
    if p <= 0.0 {
        return 0.0;
    }
    let p0_ln = n * (-p).ln_1p();
    let p0 = p0_ln.exp();
    let p1 = if p < 1.0 { n * p * ((n - 1.0) * (-p).ln_1p()).exp() } else { 0.0 };
    (1.0 - p0 - p1).clamp(0.0, 1.0)
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Running {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Log-spaced sweep points (inclusive of both ends), e.g. for p_gate axes.
pub fn logspace(lo: f64, hi: f64, n: usize) -> Vec<f64> {
    assert!(lo > 0.0 && hi > lo && n >= 2);
    let (l0, l1) = (lo.log10(), hi.log10());
    (0..n).map(|i| 10f64.powf(l0 + (l1 - l0) * i as f64 / (n - 1) as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wilson_contains_truth() {
        let (lo, hi) = wilson_interval(50, 100, 1.96);
        assert!(lo < 0.5 && 0.5 < hi);
        let (lo, hi) = wilson_interval(0, 100, 1.96);
        assert_eq!(lo, 0.0);
        assert!(hi < 0.05);
    }

    #[test]
    fn one_minus_pow_matches_naive_in_moderate_range() {
        for &(p, n) in &[(0.01, 10.0), (0.1, 3.0), (0.5, 2.0)] {
            let naive = 1.0 - (1.0f64 - p).powf(n);
            assert!((one_minus_pow(p, n) - naive).abs() < 1e-12);
        }
    }

    #[test]
    fn one_minus_pow_tiny_p() {
        // 1-(1-1e-15)^1e6 ~= 1e-9; the naive form loses all precision.
        let v = one_minus_pow(1e-15, 1e6);
        assert!((v - 1e-9).abs() / 1e-9 < 1e-6, "v={v}");
        // Paper Fig 4-bottom operating point: p_mask*p_mult with M=612e6.
        let v = one_minus_pow(3e-4 * 7.3e-6, 612e6);
        assert!(v > 0.5 && v < 1.0, "v={v}");
    }

    #[test]
    fn erfc_and_normal_cdf_reference_points() {
        assert!((erfc(0.0) - 1.0).abs() < 1e-6);
        assert!((erfc(1.0) - 0.157299).abs() < 1e-5);
        assert!((erfc(-1.0) - 1.842701).abs() < 1e-5);
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!(normal_cdf(-8.0) < 1e-10);
        assert!(normal_cdf(8.0) > 1.0 - 1e-10);
    }

    #[test]
    fn prob_at_least_two_small_p_is_quadratic() {
        let n = 1000.0;
        let p = 1e-8;
        let v = prob_at_least_two(n, p);
        let approx = 0.5 * n * (n - 1.0) * p * p;
        assert!((v - approx).abs() / approx < 1e-3, "v={v} approx={approx}");
    }

    #[test]
    fn running_moments() {
        let mut r = Running::default();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 3.0).abs() < 1e-12);
        assert!((r.variance() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn logspace_endpoints() {
        let v = logspace(1e-10, 1e-4, 7);
        assert_eq!(v.len(), 7);
        assert!((v[0] - 1e-10).abs() / 1e-10 < 1e-9);
        assert!((v[6] - 1e-4).abs() / 1e-4 < 1e-9);
        assert!((v[1] / v[0] - 10.0).abs() < 1e-6);
    }
}
