//! Bit-packed matrices — the storage substrate of the crossbar simulator.
//!
//! `BitMatrix` stores the crossbar state **column-major**: each column is a
//! contiguous run of `u64` words over the rows. This layout makes the
//! dominant operation — an in-row stateful gate repeated across *all* rows
//! (Fig. 1a of the paper) — a handful of word-wide bitwise ops:
//! a 1024-row NOR touches 3 columns x 16 words. In-column gates (Fig. 1b)
//! operate on rows; they go through `row_word`-style gather or a cached
//! transpose (see `xbar::Crossbar`), which the perf pass (§Perf) covers.

/// A fixed-length packed bit vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(64)
}

/// Mask selecting the valid bits of the last word of a `len`-bit vector.
#[inline]
pub fn tail_mask(len: usize) -> u64 {
    let r = len % 64;
    if r == 0 {
        u64::MAX
    } else {
        (1u64 << r) - 1
    }
}

impl BitVec {
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0; words_for(len)], len }
    }

    pub fn ones(len: usize) -> Self {
        let mut v = Self { words: vec![u64::MAX; words_for(len)], len };
        v.mask_tail();
        v
    }

    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> bool) -> Self {
        let mut v = Self::zeros(len);
        for i in 0..len {
            if f(i) {
                v.set(i, true);
            }
        }
        v
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        if v {
            *w |= 1 << (i % 64);
        } else {
            *w &= !(1 << (i % 64));
        }
    }

    #[inline]
    pub fn flip(&mut self, i: usize) {
        self.words[i / 64] ^= 1 << (i % 64);
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    fn mask_tail(&mut self) {
        if let Some(last) = self.words.last_mut() {
            *last &= tail_mask(self.len);
        }
    }

    pub fn xor_with(&mut self, other: &BitVec) {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a ^= b;
        }
    }

    /// Parity (XOR-reduce) of all bits.
    pub fn parity(&self) -> bool {
        self.words.iter().fold(0u64, |acc, w| acc ^ w).count_ones() % 2 == 1
    }

    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

/// Column-major packed bit matrix (rows x cols).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    /// words per column
    wpc: usize,
    /// cols * wpc words, column-major
    words: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let wpc = words_for(rows);
        Self { rows, cols, wpc, words: vec![0; wpc * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> bool) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                if f(r, c) {
                    m.set(r, c, true);
                }
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn words_per_col(&self) -> usize {
        self.wpc
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        debug_assert!(r < self.rows && c < self.cols, "({r},{c}) in {}x{}", self.rows, self.cols);
        (self.words[c * self.wpc + r / 64] >> (r % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        debug_assert!(r < self.rows && c < self.cols);
        let w = &mut self.words[c * self.wpc + r / 64];
        if v {
            *w |= 1 << (r % 64);
        } else {
            *w &= !(1 << (r % 64));
        }
    }

    #[inline]
    pub fn flip(&mut self, r: usize, c: usize) {
        self.words[c * self.wpc + r / 64] ^= 1 << (r % 64);
    }

    /// The packed words of column `c` (length = words_per_col).
    #[inline]
    pub fn col(&self, c: usize) -> &[u64] {
        &self.words[c * self.wpc..(c + 1) * self.wpc]
    }

    #[inline]
    pub fn col_mut(&mut self, c: usize) -> &mut [u64] {
        &mut self.words[c * self.wpc..(c + 1) * self.wpc]
    }

    /// Three disjoint column views (a, b, out) for gate application.
    /// Panics if any two indices alias.
    #[inline]
    pub fn cols3_mut(&mut self, a: usize, b: usize, out: usize) -> (&[u64], &[u64], &mut [u64]) {
        assert!(a != out && b != out, "output column aliases an input");
        let wpc = self.wpc;
        let ptr = self.words.as_mut_ptr();
        // SAFETY: a, b != out, so the mutable slice is disjoint from both
        // shared slices; all ranges are in-bounds (checked below).
        assert!(a < self.cols && b < self.cols && out < self.cols);
        unsafe {
            let sa = std::slice::from_raw_parts(ptr.add(a * wpc), wpc);
            let sb = std::slice::from_raw_parts(ptr.add(b * wpc), wpc);
            let so = std::slice::from_raw_parts_mut(ptr.add(out * wpc), wpc);
            (sa, sb, so)
        }
    }

    /// Three shared column views plus one mutable (gate application hot
    /// path: out = gate(a, b, c) without copies). Inputs may alias each
    /// other; the output must not alias any input (panics otherwise).
    #[inline]
    pub fn cols_gate(
        &mut self,
        a: usize,
        b: usize,
        c: usize,
        out: usize,
    ) -> (&[u64], &[u64], &[u64], &mut [u64]) {
        assert!(a != out && b != out && c != out, "output column aliases an input");
        assert!(a < self.cols && b < self.cols && c < self.cols && out < self.cols);
        let wpc = self.wpc;
        let ptr = self.words.as_mut_ptr();
        // SAFETY: out differs from a, b and c, so the mutable slice is
        // disjoint from every shared slice; all ranges are in-bounds.
        unsafe {
            (
                std::slice::from_raw_parts(ptr.add(a * wpc), wpc),
                std::slice::from_raw_parts(ptr.add(b * wpc), wpc),
                std::slice::from_raw_parts(ptr.add(c * wpc), wpc),
                std::slice::from_raw_parts_mut(ptr.add(out * wpc), wpc),
            )
        }
    }

    /// Extract column `c` as a BitVec.
    pub fn col_bitvec(&self, c: usize) -> BitVec {
        BitVec { words: self.col(c).to_vec(), len: self.rows }
    }

    /// Store a BitVec into column `c`.
    pub fn set_col(&mut self, c: usize, v: &BitVec) {
        assert_eq!(v.len, self.rows);
        self.col_mut(c).copy_from_slice(&v.words);
    }

    /// Extract row `r` as a BitVec (bit-gather across columns; slow path —
    /// used by in-column operations and tests).
    pub fn row_bitvec(&self, r: usize) -> BitVec {
        BitVec::from_fn(self.cols, |c| self.get(r, c))
    }

    pub fn set_row(&mut self, r: usize, v: &BitVec) {
        assert_eq!(v.len, self.cols);
        for c in 0..self.cols {
            self.set(r, c, v.get(c));
        }
    }

    /// Full transpose (used by in-column execution).
    pub fn transpose(&self) -> BitMatrix {
        let mut t = BitMatrix::zeros(self.cols, self.rows);
        for c in 0..self.cols {
            for (wi, &w) in self.col(c).iter().enumerate() {
                let mut bits = w;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    t.set(c, wi * 64 + b, true);
                }
            }
        }
        t
    }

    pub fn count_ones(&self) -> usize {
        // Tail bits beyond `rows` are maintained as zero.
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// XOR a packed row-mask into column `c` (error injection hot path).
    pub fn xor_col_words(&mut self, c: usize, mask: &[u64]) {
        let tm = tail_mask(self.rows);
        let col = self.col_mut(c);
        for (w, m) in col.iter_mut().zip(mask) {
            *w ^= m;
        }
        // Keep tail invariant.
        if let Some(last) = col.last_mut() {
            *last &= tm;
        }
    }

    /// Overwrite `len` (<= 64) bits of column `c` starting at `row_start`
    /// with the low `len` bits of `bits`. Returns how many stored bits
    /// changed (switch-energy accounting). §Perf: this is the word-wide
    /// scatter primitive behind the mMPU's operand marshalling — one or
    /// two word ops instead of `len` `set` calls.
    pub fn splice_col_word(&mut self, c: usize, row_start: usize, len: usize, bits: u64) -> u32 {
        debug_assert!(len >= 1 && len <= 64);
        debug_assert!(row_start + len <= self.rows && c < self.cols);
        let mask = if len == 64 { u64::MAX } else { (1u64 << len) - 1 };
        let bits = bits & mask;
        let col = self.col_mut(c);
        let w = row_start / 64;
        let off = row_start % 64;
        let mut changed = 0u32;
        let lo_mask = mask << off;
        let prev = col[w];
        let next = (prev & !lo_mask) | ((bits << off) & lo_mask);
        changed += (prev ^ next).count_ones();
        col[w] = next;
        if off != 0 && off + len > 64 {
            let hi_mask = mask >> (64 - off);
            let prev = col[w + 1];
            let next = (prev & !hi_mask) | ((bits >> (64 - off)) & hi_mask);
            changed += (prev ^ next).count_ones();
            col[w + 1] = next;
        }
        changed
    }

    /// Read `len` (<= 64) bits of column `c` starting at `row_start` into
    /// the low bits of a word — the gather mirror of `splice_col_word`,
    /// used by word-parallel result readback.
    pub fn gather_col_word(&self, c: usize, row_start: usize, len: usize) -> u64 {
        debug_assert!(len >= 1 && len <= 64);
        debug_assert!(row_start + len <= self.rows && c < self.cols);
        let col = self.col(c);
        let w = row_start / 64;
        let off = row_start % 64;
        let mut bits = col[w] >> off;
        if off != 0 && w + 1 < col.len() {
            bits |= col[w + 1] << (64 - off);
        }
        if len < 64 {
            bits &= (1u64 << len) - 1;
        }
        bits
    }

    /// Dense f32 {0,1} export in row-major order (PJRT literal interchange).
    pub fn to_f32_row_major(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for c in 0..self.cols {
            for (wi, &w) in self.col(c).iter().enumerate() {
                let mut bits = w;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    out[(wi * 64 + b) * self.cols + c] = 1.0;
                }
            }
        }
        out
    }

    /// Import from dense f32 {0,1} row-major (PJRT literal interchange).
    pub fn from_f32_row_major(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        BitMatrix::from_fn(rows, cols, |r, c| data[r * cols + c] > 0.5)
    }
}

/// In-place 64x64 bit-matrix transpose (Hacker's Delight 7-3, adapted to
/// LSB-first column numbering: after the call, bit `i` of word `k` holds
/// what bit `k` of word `i` held). §Perf: the workhorse of word-parallel
/// operand marshalling — it converts 64 item values (item-major) into 64
/// bit-plane words (bit-major) in 6 x 64 word ops, so a batch of operands
/// scatters into crossbar columns with O(bits) word writes instead of
/// O(items x bits) bit writes.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            for i in k..k + j {
                let t = ((a[i] >> j) ^ a[i + j]) & m;
                a[i] ^= t << j;
                a[i + j] ^= t;
            }
            k += 2 * j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn bitvec_set_get_flip() {
        let mut v = BitVec::zeros(130);
        assert_eq!(v.count_ones(), 0);
        v.set(0, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1));
        assert_eq!(v.count_ones(), 3);
        v.flip(129);
        assert_eq!(v.count_ones(), 2);
        assert_eq!(v.iter_ones().collect::<Vec<_>>(), vec![0, 64]);
    }

    #[test]
    fn bitvec_ones_tail_masked() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert!(!v.parity()); // 70 ones -> even parity
        assert!(BitVec::ones(71).parity());
    }

    #[test]
    fn bitvec_parity() {
        let mut v = BitVec::zeros(100);
        assert!(!v.parity());
        v.set(3, true);
        assert!(v.parity());
        v.set(99, true);
        assert!(!v.parity());
    }

    #[test]
    fn matrix_roundtrip_row_col() {
        let mut r = Pcg64::new(1, 0);
        let m = BitMatrix::from_fn(67, 33, |_, _| r.bernoulli(0.5));
        for row in 0..67 {
            let rv = m.row_bitvec(row);
            for col in 0..33 {
                assert_eq!(rv.get(col), m.get(row, col));
            }
        }
        let t = m.transpose();
        for row in 0..67 {
            for col in 0..33 {
                assert_eq!(m.get(row, col), t.get(col, row));
            }
        }
        assert_eq!(m.count_ones(), t.count_ones());
    }

    #[test]
    fn f32_roundtrip() {
        let mut r = Pcg64::new(2, 0);
        let m = BitMatrix::from_fn(40, 24, |_, _| r.bernoulli(0.3));
        let dense = m.to_f32_row_major();
        let back = BitMatrix::from_f32_row_major(40, 24, &dense);
        assert_eq!(m, back);
    }

    #[test]
    fn cols3_mut_disjoint() {
        let mut m = BitMatrix::zeros(128, 8);
        for r in 0..128 {
            m.set(r, 1, r % 2 == 0);
            m.set(r, 2, r % 3 == 0);
        }
        let (a, b, out) = m.cols3_mut(1, 2, 5);
        let nor: Vec<u64> = a.iter().zip(b).map(|(x, y)| !(x | y)).collect();
        out.copy_from_slice(&nor);
        // col 5 now holds NOR(col1, col2) (up to tail bits)
        for r in 0..128 {
            let want = !(r % 2 == 0 || r % 3 == 0);
            assert_eq!(m.get(r, 5), want, "row {r}");
        }
    }

    #[test]
    #[should_panic]
    fn cols3_mut_alias_panics() {
        let mut m = BitMatrix::zeros(8, 4);
        let _ = m.cols3_mut(1, 2, 1);
    }

    #[test]
    fn transpose64_matches_naive() {
        let mut rng = Pcg64::new(7, 0);
        let mut a: [u64; 64] = [0; 64];
        for w in a.iter_mut() {
            *w = rng.next_u64();
        }
        let orig = a;
        transpose64(&mut a);
        for i in 0..64 {
            for k in 0..64 {
                assert_eq!(
                    (a[k] >> i) & 1,
                    (orig[i] >> k) & 1,
                    "bit ({i},{k}) must transpose"
                );
            }
        }
        // Involution: transposing twice restores the original.
        transpose64(&mut a);
        assert_eq!(a, orig);
    }

    #[test]
    fn splice_gather_roundtrip_arbitrary_offsets() {
        let mut rng = Pcg64::new(11, 0);
        let rows = 200;
        for case in 0..200 {
            let mut m = BitMatrix::from_fn(rows, 3, |_, _| rng.bernoulli(0.5));
            let reference = m.clone();
            let len = 1 + (rng.below(64)) as usize;
            let row_start = rng.below((rows - len + 1) as u64) as usize;
            let bits = rng.next_u64();
            let changed = m.splice_col_word(1, row_start, len, bits);
            // Matches a per-bit reference write, including change count.
            let mut expect_changed = 0;
            for k in 0..len {
                let v = (bits >> k) & 1 == 1;
                if reference.get(row_start + k, 1) != v {
                    expect_changed += 1;
                }
            }
            assert_eq!(changed, expect_changed, "case {case}");
            for r in 0..rows {
                let want = if (row_start..row_start + len).contains(&r) {
                    (bits >> (r - row_start)) & 1 == 1
                } else {
                    reference.get(r, 1)
                };
                assert_eq!(m.get(r, 1), want, "case {case} row {r}");
            }
            // Untouched columns stay untouched.
            for c in [0usize, 2] {
                for r in 0..rows {
                    assert_eq!(m.get(r, c), reference.get(r, c));
                }
            }
            assert_eq!(m.gather_col_word(1, row_start, len), bits & tail(len), "case {case}");
        }
    }

    fn tail(len: usize) -> u64 {
        if len == 64 {
            u64::MAX
        } else {
            (1u64 << len) - 1
        }
    }

    #[test]
    fn xor_col_words_keeps_tail_zero() {
        let mut m = BitMatrix::zeros(70, 3);
        m.xor_col_words(1, &[u64::MAX, u64::MAX]);
        assert_eq!(m.count_ones(), 70);
        let col = m.col(1);
        assert_eq!(col[1] >> 6, 0, "tail bits must stay zero");
    }
}
