//! Foundation utilities: deterministic RNG, bit-packed matrices,
//! statistics, CLI parsing and table rendering.

pub mod bitmat;
pub mod cli;
pub mod rng;
pub mod stats;
pub mod table;
