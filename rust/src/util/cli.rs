//! Minimal CLI argument parsing (clap is not in the offline vendor set).
//!
//! Supports the subcommand + `--key value` / `--flag` style used by the
//! `remus` binary and the examples:
//!
//! ```text
//! remus fig4 --pgate-lo 1e-10 --pgate-hi 1e-4 --points 13 --trials 2000
//! ```

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` options and `--flag` booleans (value "true").
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.options.get(name).map(|v| v == "true" || v == "1").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.options.get(name) {
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("warning: could not parse --{name} {v:?}; using default");
                default
            }),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig4 --trials 500 --pgate-lo 1e-10 --verbose");
        assert_eq!(a.subcommand(), Some("fig4"));
        assert_eq!(a.get_or("trials", 0u64), 500);
        assert_eq!(a.get_or("pgate-lo", 0.0f64), 1e-10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --n=128 --mode=ecc");
        assert_eq!(a.get_or("n", 0usize), 128);
        assert_eq!(a.get("mode"), Some("ecc"));
    }

    #[test]
    fn bad_value_falls_back() {
        let a = parse("x --n abc");
        assert_eq!(a.get_or("n", 7usize), 7);
    }

    #[test]
    fn empty() {
        let a = parse("");
        assert_eq!(a.subcommand(), None);
    }
}
