//! Test utilities: a minimal property-testing framework (proptest is not
//! in the offline vendor set) used by unit tests and `rust/tests/`.

pub mod prop;
