//! Mini property-testing framework.
//!
//! `proptest` is not available offline, so this module provides the two
//! things the test-suite actually needs: (1) run a predicate over many
//! random cases from explicit generators, (2) on failure, report the seed
//! and the smallest failing case found by a bounded greedy shrink.
//!
//! ```no_run
//! use remus::testutil::prop::Cases;
//! Cases::new(256).run(|g| {
//!     let n = g.usize_in(1..=64);
//!     let v = g.vec_bool(n);
//!     assert_eq!(v.len(), n);
//! });
//! ```

use crate::util::rng::Pcg64;

/// Per-case random value source handed to the property closure.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn new(seed: u64, case: u64) -> Self {
        Self { rng: Pcg64::new(seed, case) }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn u64_in(&mut self, range: std::ops::RangeInclusive<u64>) -> u64 {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Log-uniform draw (for probability axes).
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        10f64.powf(self.f64_in(lo.log10(), hi.log10()))
    }

    pub fn vec_bool(&mut self, n: usize) -> Vec<bool> {
        (0..n).map(|_| self.bool()).collect()
    }

    pub fn vec_u64(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0..=items.len() - 1)]
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Property runner: executes `n` random cases; panics (with the failing
/// case id + seed) if the property panics for any case.
pub struct Cases {
    n: u64,
    seed: u64,
}

impl Cases {
    pub fn new(n: u64) -> Self {
        // Honor REMUS_PROP_SEED for reproduction of CI failures.
        let seed = std::env::var("REMUS_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { n, seed }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn run(&self, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
        for case in 0..self.n {
            let result = std::panic::catch_unwind(|| {
                let mut g = Gen::new(self.seed, case);
                prop(&mut g);
            });
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property failed at case {case}/{} (seed {:#x}; rerun with \
                     REMUS_PROP_SEED={}): {msg}",
                    self.n, self.seed, self.seed
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNT: AtomicU64 = AtomicU64::new(0);
        Cases::new(50).run(|g| {
            let _ = g.u64();
            COUNT.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(COUNT.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn ranges_respected() {
        Cases::new(200).run(|g| {
            let x = g.usize_in(3..=9);
            assert!((3..=9).contains(&x));
            let y = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&y));
            let z = g.f64_log(1e-10, 1e-2);
            assert!((1e-10..=1e-2).contains(&z));
        });
    }

    #[test]
    fn failure_reports_case() {
        let res = std::panic::catch_unwind(|| {
            Cases::new(100).run(|g| {
                let x = g.usize_in(0..=99);
                assert!(x < 95, "x too large: {x}");
            });
        });
        let err = res.expect_err("property should fail");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("property failed at case"), "{msg}");
    }
}
