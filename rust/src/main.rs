//! `remus` — the mMPU reliability launcher.
//!
//! Subcommands map to the paper's experiments (see DESIGN.md §3):
//!
//! ```text
//! remus info                          # device / throughput model summary
//! remus demo                          # quick reliable vector-multiply demo
//! remus fig4  [--points 13 --trials 4000 --bits 32]
//! remus fig5  [--tmax 1e8]
//! remus overhead                      # ECC latency overhead table (E8)
//! remus tradeoff                      # TMR trade-off table (E9)
//! remus serve [--requests 4096 --workers 4 --shards a:p,b:p]
//!                                     # coordinator load demo (with
//!                                     # --shards: same load through a
//!                                     # fabric router instead)
//! remus soak  [--requests 1000000 --workers 4 --endurance 3e4]
//!                                     # §Health long-running soak:
//!                                     # nominal errors + wear-out, with
//!                                     # vs without the health manager
//! remus lifetime [--batches 512 --p-input 1e-4]
//!                                     # degradation vs closed form
//! remus fabric-serve [--addr 127.0.0.1:4870 --workers 4 --spares 0
//!                     --health --endurance 3e4]
//!                    [--register host:port --name id --spare]
//!                                     # one fabric shard: TCP front end
//!                                     # over one coordinator; prints
//!                                     # "LISTENING <addr>" then serves
//!                                     # until a Shutdown frame. With
//!                                     # --register it announces itself
//!                                     # to a router's registration
//!                                     # port (--spare: hot-spare pool)
//! remus fabric-route [--shards a:p,b:p] [--listen-reg host:port]
//!                    [--requests 8192 --min-shards 1
//!                     --probe-ms 250 --retry-ms 1000]
//!                                     # client-side consistent-hash
//!                                     # router; shards come from the
//!                                     # static list, registration, or
//!                                     # both. Downed shards are
//!                                     # re-probed and revived
//! remus fabric-soak [--shards 2 --requests 100000 --workers 2]
//!                   [--spare-shards 0 --chaos-kill]
//!                                     # §Scale loopback soak: spawns
//!                                     # one fabric-serve *process* per
//!                                     # shard, shards load across them,
//!                                     # merges fleet health.
//!                                     # --spare-shards: extra children
//!                                     # registered as hot spares;
//!                                     # --chaos-kill: SIGKILL one shard
//!                                     # mid-run, restart it, and prove
//!                                     # zero lost/wrong replies
//! remus loadgen [--qps 1000,2000,4000 --requests 8192 --seed 4269
//!                --window 1024] [--shards a:p,b:p | --listen-reg addr]
//!                                     # open-loop generator: seeded
//!                                     # Poisson arrivals at each
//!                                     # offered rate, bounded in-flight
//!                                     # window, every reply verified
//!                                     # against the arithmetic oracle,
//!                                     # per-kind p50/p90/p99/max, knee
//!                                     # detection across the sweep;
//!                                     # writes BENCH_loadgen.json.
//!                                     # Default target: an in-process
//!                                     # coordinator (fabric flags swap
//!                                     # in a router)
//! remus loadgen --connections 1,8,64,256
//!                                     # knee-vs-connection-count mode:
//!                                     # fresh 2-shard loopback fleets
//!                                     # swept under each data plane,
//!                                     # C client routers per point;
//!                                     # writes BENCH_loadgen_epoll.json
//! remus top [--shards a:p,b:p | --listen-reg addr] [--watch
//!            --interval-ms 1000 --rounds N]
//!                                     # §Telemetry live fleet
//!                                     # inspection: merged metrics,
//!                                     # per-kind counters, worker
//!                                     # health, and the fleet-merged
//!                                     # reliability event journal.
//!                                     # One-shot by default (--once is
//!                                     # accepted as an explicit
//!                                     # synonym); --watch refreshes
//!                                     # every --interval-ms
//! remus trace [--requests 2048 --trace-sample 16]
//!             [--shards a:p,b:p | --listen-reg addr]
//!             [--json --out BENCH_telemetry.json]
//!                                     # §Telemetry stage tracing:
//!                                     # drive sampled load, collect
//!                                     # the per-stage spans (router
//!                                     # queue, wire transit, batcher
//!                                     # wait, worker exec, ECC, TMR
//!                                     # vote, readback), and print
//!                                     # per-stage percentiles.
//!                                     # Fabric shards must run the
//!                                     # same --trace-sample rate
//! remus postmortem --journal-dir d [--json --out BENCH_postmortem.json]
//!                                     # §Observability crash
//!                                     # forensics: reconstruct a dead
//!                                     # process's reliability
//!                                     # timeline from its on-disk
//!                                     # journal WAL — per-boot-epoch
//!                                     # event tables in causal order
//!                                     # plus a scrub / escalation /
//!                                     # remap / retirement summary.
//!                                     # Needs no running fleet
//! ```
//!
//! Every fabric role additionally accepts `--psk-file <path>`
//! (§Security, wire v4): the file's contents become the fleet's
//! pre-shared key, every connection runs a mutual-authentication
//! handshake, and all frames are sealed (encrypted + integrity-tagged,
//! replay-protected). Without the flag the wire stays plaintext and
//! rejects sealed peers — mixed fleets fail loudly, never silently.
//!
//! Every fabric role also accepts `--data-plane epoll|threads`
//! (§Scale, wire-compatible — frames are identical): which transport
//! carries the data connections. `threads` (the default) is the
//! blocking thread-per-connection reference; `epoll` multiplexes all
//! connections onto one readiness loop per process. The
//! `REMUS_DATA_PLANE` environment variable overrides the default when
//! the flag is absent, which is how the integration and chaos suites
//! re-run unchanged under the reactor.
//!
//! `fabric-serve` and `fabric-route` also take the flight-recorder
//! flags (§Observability, wire v6): `--journal-dir <dir>` spills the
//! reliability journal into a checksummed, segment-rotated WAL that
//! `remus postmortem` reads back after a crash (`fabric-soak` forwards
//! it to its children as per-shard subdirectories), and
//! `--metrics-addr <host:port>` serves the Prometheus text exposition
//! at `GET /metrics` — the shard's own counters on `fabric-serve`, the
//! merged fleet snapshot on `fabric-route`. The WAL is tunable with
//! `--wal-segment-bytes` (rotation threshold), `--wal-max-bytes`
//! (total per-directory footprint; oldest closed segments are deleted
//! past it) and `--wal-fsync` (fsync per drained batch instead of
//! OS-buffered appends).
//!
//! Every serving role — `fabric-serve`, the `fabric-soak` children,
//! and the in-process coordinator behind `loadgen` — also takes
//! `--schedule` (§Perf list scheduling, wire v7): compiled plans are
//! packed across a uniform partition grid of `--partitions` segments
//! (default 16), so independent micro-ops share cycles. Without the
//! flag plans stay the serial program-order reference. The achieved
//! packing shows up as `plan_ops`/`plan_bundles` in the fleet
//! snapshot and as `remus_plan_*_total` on `/metrics`.

use std::collections::HashMap;

use anyhow::Result;

use remus::analysis::lifetime::{simulate, LifetimeConfig};
use remus::analysis::{fig4::MultReliability, overhead};
use remus::bitlet::BitletModel;
use remus::coordinator::{Coordinator, CoordinatorConfig, MetricsSnapshot, Submitter};
use remus::errs::ErrorModel;
use remus::fabric::loadgen::{self, LoadgenConfig};
use remus::fabric::{
    shutdown_endpoint_auth, DataPlane, FabricServer, Psk, RouteOptions, Router, RouterConfig,
    ServeOptions,
};
use remus::health::{HealthConfig, WearModel};
use remus::isa::ScheduleConfig;
use remus::mmpu::{controller::quick_exec, FunctionKind, ReliabilityPolicy};
use remus::nn::degradation::DegradationModel;
use remus::telemetry::{
    read_wal_dir, stage_summaries, unix_now_ns, EpochTimeline, EventKind, FsyncMode, StageSummary,
    WalConfig, SHARD_NONE,
};
use remus::tmr::TmrMode;
use remus::util::cli::Args;
use remus::util::stats::logspace;
use remus::util::table::{sci, Table};
use remus::xbar::device::DeviceModel;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("info") => info(),
        Some("demo") => demo(&args),
        Some("fig4") => fig4(&args),
        Some("fig5") => fig5(&args),
        Some("overhead") => overhead_cmd(&args),
        Some("tradeoff") => tradeoff(&args),
        Some("serve") => serve(&args),
        Some("soak") => soak(&args),
        Some("lifetime") => lifetime_cmd(&args),
        Some("fabric-serve") => fabric_serve(&args),
        Some("fabric-route") => fabric_route(&args),
        Some("fabric-soak") => fabric_soak(&args),
        Some("loadgen") => loadgen_cmd(&args),
        Some("top") => top_cmd(&args),
        Some("trace") => trace_cmd(&args),
        Some("postmortem") => postmortem_cmd(&args),
        _ => {
            eprintln!(
                "usage: remus <info|demo|fig4|fig5|overhead|tradeoff|serve|soak|lifetime|\
                 fabric-serve|fabric-route|fabric-soak|loadgen|top|trace|postmortem> [--opts]\n \
                 see doc comments in rust/src/main.rs"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let d = DeviceModel::default_rram();
    println!("REMUS — Reliable Memristive Processing-in-Memory");
    println!(
        "device model: Ron={}Ω Roff={}Ω cycle={}ns f={}MHz",
        d.r_on,
        d.r_off,
        d.cycle_ns,
        d.freq_mhz()
    );
    println!("variability-derived p_gate estimate: {:.3e}", d.derived_p_gate());
    let b = BitletModel::paper();
    println!(
        "fleet model: {} crossbars x {}x{} = {} MiB @ {} MHz -> peak {:.1} TB/s",
        b.crossbars,
        b.rows,
        b.cols,
        b.total_bytes() >> 20,
        b.freq_mhz,
        b.peak_tb_per_sec()
    );
    Ok(())
}

fn demo(args: &Args) -> Result<()> {
    let p_gate = args.get_or("p-gate", 1e-4);
    let n: Vec<u64> = (0..16).collect();
    let m: Vec<u64> = (0..16).map(|i| i + 100).collect();
    println!("vector multiply, 16 elements, p_gate = {p_gate}");
    for (label, tmr) in
        [("baseline (unprotected)", TmrMode::Off), ("serial TMR", TmrMode::Serial)]
    {
        let r = quick_exec(
            FunctionKind::Mul(16),
            ReliabilityPolicy { ecc_m: Some(16), tmr },
            ErrorModel::direct_only(p_gate),
            42,
            &n,
            &m,
        )?;
        let wrong =
            r.values.iter().zip(n.iter().zip(&m)).filter(|(&v, (&a, &b))| v != a * b).count();
        println!(
            "  {label:<24} wrong={wrong}/16  compute_cycles={}  ecc_cycles={}",
            r.compute_cycles, r.ecc_cycles
        );
    }
    Ok(())
}

fn fig4(args: &Args) -> Result<()> {
    let bits = args.get_or("bits", 32u32);
    let trials = args.get_or("trials", 2000usize);
    let points = args.get_or("points", 13usize);
    let rel = MultReliability::measure(bits, trials, 0xF164);
    println!(
        "measured masking: alpha={:.3} gamma={:.3} over G={} gates",
        rel.alpha, rel.gamma, rel.gates
    );
    let grid = logspace(1e-10, 1e-4, points);
    let mut t = Table::new(
        &format!("Fig 4 (top): {bits}-bit multiplication failure probability"),
        &["p_gate", "baseline", "tmr", "tmr_ideal"],
    );
    for row in rel.series(&grid) {
        t.row(&[sci(row.p_gate), sci(row.baseline), sci(row.tmr), sci(row.tmr_ideal)]);
    }
    t.print();
    let model = remus::nn::alexnet::AlexNetModel::paper();
    let mut t = Table::new(
        "Fig 4 (bottom): NN misclassification probability",
        &["p_gate", "baseline", "tmr", "tmr_ideal"],
    );
    for row in rel.series(&grid) {
        t.row(&[
            sci(row.p_gate),
            sci(model.p_network(row.baseline)),
            sci(model.p_network(row.tmr)),
            sci(model.p_network(row.tmr_ideal)),
        ]);
    }
    t.print();
    Ok(())
}

fn fig5(args: &Args) -> Result<()> {
    let model = DegradationModel::paper();
    let tmax = args.get_or("tmax", 1e8);
    let mut t = Table::new(
        "Fig 5: expected corrupted weights (baseline vs mMPU ECC)",
        &["batches", "p_input", "baseline", "ecc"],
    );
    for &p in &[1e-10, 1e-9, 1e-8] {
        let mut tt = 1.0;
        while tt <= tmax {
            t.row(&[
                format!("{tt:.0e}"),
                sci(p),
                format!("{:.3e}", model.expected_corrupted_baseline(p, tt)),
                format!("{:.3e}", model.expected_corrupted_ecc(p, tt)),
            ]);
            tt *= 10.0;
        }
    }
    t.print();
    Ok(())
}

fn overhead_cmd(args: &Args) -> Result<()> {
    let m = args.get_or("m", 16usize);
    let (rows, avg) = overhead::suite_overhead(m);
    let mut t = Table::new(
        &format!("ECC latency overhead per function (m={m})"),
        &["function", "base_cycles", "ecc_cycles", "overhead_%"],
    );
    for r in rows {
        t.row(&[
            r.name,
            r.base_cycles.to_string(),
            r.ecc_cycles.to_string(),
            format!("{:.1}", r.overhead_pct),
        ]);
    }
    t.print();
    println!("suite average: {avg:.1}%  (paper: 26% average)");
    Ok(())
}

fn tradeoff(_args: &Args) -> Result<()> {
    let mut t = Table::new(
        "TMR trade-offs (analytical; measured version: cargo bench tab_tmr_tradeoff)",
        &["function", "mode", "latency_x", "area_x", "throughput_x"],
    );
    for (name, prog) in overhead::function_suite() {
        if !name.starts_with("mul") && !name.starts_with("add32") {
            continue;
        }
        for r in overhead::tmr_tradeoffs(&name, &prog) {
            t.row(&[
                r.func,
                r.mode.to_string(),
                format!("{:.2}", r.latency_x),
                format!("{:.2}", r.area_x),
                format!("{:.2}", r.throughput_x),
            ]);
        }
    }
    t.print();
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let requests = args.get_or("requests", 4096u64);
    let workers = args.get_or("workers", 4usize);
    // The load path is Submitter-generic: --shards (and/or --listen-reg,
    // which discovers shards through registration) swaps the in-process
    // coordinator for a fabric router with no other change.
    if args.get("shards").is_some() || args.get("listen-reg").is_some() {
        let router = router_from_args(args, shard_addrs_from_args(args), "serve", 0)?;
        println!("serving through the fabric router over {} shards", router.shard_count());
        serve_load(&router, requests)?;
        let m = router.metrics();
        println!("fleet shards: {} total, {} down", m.shards_total, m.shards_down);
        router.shutdown();
        return Ok(());
    }
    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        policy: ReliabilityPolicy { ecc_m: None, tmr: TmrMode::Serial },
        ..Default::default()
    })?;
    serve_load(&coord, requests)?;
    coord.shutdown();
    Ok(())
}

fn serve_load(sub: &dyn Submitter, requests: u64) -> Result<()> {
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| (i, sub.submit(FunctionKind::Mul(16), i % 1000, (i * 7) % 1000)))
        .collect();
    let mut ok = 0u64;
    let mut errors = 0u64;
    for (i, rx) in rxs {
        let r = rx.recv()?;
        if !r.is_ok() {
            // Infrastructure error results are not wrong *values*.
            errors += 1;
        } else if r.value == (i % 1000) * ((i * 7) % 1000) {
            ok += 1;
        }
    }
    let dt = t0.elapsed();
    let m = sub.metrics();
    println!(
        "served {requests} requests in {:.2?}: {:.0} req/s, correct {ok}/{requests} \
         ({errors} error results)",
        dt,
        requests as f64 / dt.as_secs_f64()
    );
    println!(
        "batches={} mean_batch={:.1} p50={}us p99={}us",
        m.batches,
        m.mean_batch_size(),
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0)
    );
    Ok(())
}

/// Closed-loop load in bounded waves over any [`Submitter`] — the same
/// driver feeds the in-process coordinator (`remus soak`) and the
/// sharded fabric router (`remus fabric-route` / `fabric-soak`). Being
/// closed-loop it self-throttles at saturation; the *open-loop*
/// `remus loadgen` (`fabric::loadgen`) is the tool that measures where
/// that saturation knee actually is.
/// Returns (ok, wrong, error_results, elapsed).
fn drive_load(
    sub: &dyn Submitter,
    kinds: &[FunctionKind],
    requests: u64,
    chunk: u64,
) -> (u64, u64, u64, std::time::Duration) {
    let (mut ok, mut wrong, mut errs) = (0u64, 0u64, 0u64);
    let t0 = std::time::Instant::now();
    let mut sent = 0u64;
    while sent < requests {
        let n = chunk.min(requests - sent);
        let rxs: Vec<_> = (0..n)
            .map(|i| {
                let v = sent + i;
                let kind = kinds[(v % kinds.len() as u64) as usize];
                let (a, b) = (v % 251, (v * 7) % 251);
                (kind, a, b, sub.submit(kind, a, b))
            })
            .collect();
        for (kind, a, b, rx) in rxs {
            match rx.recv() {
                Ok(r) if r.is_ok() => {
                    // A wrong value = an uncorrected error escaping to
                    // the user (checked against the library's oracle).
                    if r.value == kind.reference(a, b) {
                        ok += 1;
                    } else {
                        wrong += 1;
                    }
                }
                _ => errs += 1,
            }
        }
        sent += n;
    }
    (ok, wrong, errs, t0.elapsed())
}

/// Per-worker §Health lines from a (possibly fleet-merged) snapshot.
fn print_worker_health(label: &str, m: &MetricsSnapshot) {
    for (w, wh) in m.worker_health.iter().enumerate() {
        if wh.batches > 0 {
            println!(
                "  [{label}] worker {w}: {} batches, {} scrubs, corrected {}, \
                 stuck {} (remapped {} rows, {} spares left), level {}{}",
                wh.batches,
                wh.scrubs,
                wh.corrected,
                wh.stuck_detected,
                wh.remapped_rows,
                wh.spares_left,
                wh.policy_level,
                if wh.retired { ", RETIRED" } else { "" }
            );
        }
    }
}

/// One soak configuration: adds a table row, returns req/s.
fn soak_run(
    label: &str,
    health: Option<HealthConfig>,
    requests: u64,
    workers: usize,
    t: &mut Table,
) -> Result<f64> {
    let coord = Coordinator::start(CoordinatorConfig {
        workers,
        rows: 64,
        cols: 1024,
        errors: ErrorModel::nominal(),
        max_batch: 64,
        max_wait: std::time::Duration::from_micros(300),
        health,
        ..Default::default()
    })?;
    let (ok, wrong, errs, dt) = drive_load(&coord, &[FunctionKind::Add(8)], requests, 8192);
    let tp = requests as f64 / dt.as_secs_f64();
    let m = coord.metrics();
    t.row(&[
        label.into(),
        format!("{tp:.0}"),
        ok.to_string(),
        wrong.to_string(),
        errs.to_string(),
        format!("{}/{workers}", m.retired_workers()),
    ]);
    print_worker_health(label, &m);
    coord.shutdown();
    Ok(tp)
}

fn soak(args: &Args) -> Result<()> {
    let requests = args.get_or("requests", 1_000_000u64);
    let workers = args.get_or("workers", 4usize);
    let endurance = args.get_or("endurance", 3e4f64);
    println!(
        "soak: {requests} Add(8) requests x2 configs, {workers} workers, \
         ErrorModel::nominal() + wear-out (median endurance {endurance:.1e} switches)"
    );
    let health = HealthConfig {
        wear: WearModel::accelerated(endurance),
        spare_rows: 8,
        scrub_interval: 64,
        scrub_rows_per_pass: 8,
        ..Default::default()
    };
    let mut t = Table::new(
        "soak: uncorrected errors stay bounded, throughput within 15%",
        &["config", "req/s", "ok", "wrong", "error_results", "retired"],
    );
    let tp_health = soak_run("health on", Some(health), requests, workers, &mut t)?;
    let tp_base = soak_run("health off", None, requests, workers, &mut t)?;
    t.print();
    println!(
        "throughput ratio (health on / off): {:.3}  (acceptance: >= 0.85)",
        tp_health / tp_base
    );
    println!("\nclosed-form check (health disabled degradation == Fig. 5 model):");
    lifetime_cmd(args)
}

fn lifetime_cmd(args: &Args) -> Result<()> {
    let cfg = LifetimeConfig {
        batches: args.get_or("batches", 512u64),
        p_input: args.get_or("p-input", 1e-4f64),
        ..Default::default()
    };
    let report = simulate(&cfg);
    let mut t = Table::new(
        &format!(
            "lifetime: {}x{} m={} p_input={:.1e} (sim vs closed form)",
            cfg.rows, cfg.cols, cfg.m, cfg.p_input
        ),
        &["batch", "base_sim", "base_mod", "blk_sim", "blk_mod", "eccw_sim", "eccw_mod"],
    );
    for p in &report.points {
        t.row(&[
            p.batch.to_string(),
            format!("{:.0}", p.sim_baseline_weights),
            format!("{:.1}", p.model_baseline_weights),
            format!("{:.0}", p.sim_failed_blocks),
            format!("{:.1}", p.model_failed_blocks),
            format!("{:.0}", p.sim_ecc_weights),
            format!("{:.1}", p.model_ecc_weights),
        ]);
    }
    t.print();
    let (rel_base, rel_blocks) = report.final_errors();
    println!(
        "final relative error vs closed form: baseline {:.1}% (gate <= 10%), \
         failed blocks {:.1}% (MC tolerance <= 25%)",
        rel_base * 100.0,
        rel_blocks * 100.0
    );
    Ok(())
}

/// `Router::announce_and_wait` with the `--min-shards` CLI default (the
/// static shard count, at least 1). Shared by `serve` and `fabric-route`.
fn announce_registration(router: &Router, args: &Args, static_shards: usize, ctx: &str) {
    let min = args.get_or("min-shards", static_shards.max(1));
    router.announce_and_wait(min, std::time::Duration::from_secs(30), ctx);
}

/// Parse the comma-separated `--shards` list (empty when absent).
fn shard_addrs_from_args(args: &Args) -> Vec<String> {
    args.get("shards").map(|s| s.split(',').map(str::to_string).collect()).unwrap_or_default()
}

/// Load the fabric pre-shared key named by `--psk-file` (§Security,
/// wire v4). `None` without the flag: the wire stays plaintext. Every
/// fabric role — `fabric-serve`, `fabric-route`, `fabric-soak`,
/// `loadgen`, `serve --shards` — takes the same flag, and mixed fleets
/// refuse each other by construction (sealed peers reject plaintext
/// frames and vice versa), so a partially-authenticated fleet cannot
/// silently serve.
fn psk_from_args(args: &Args) -> Result<Option<Psk>> {
    args.get("psk-file").map(Psk::load).transpose()
}

/// Resolve `--data-plane` (§Scale): `epoll` or `threads`. Without the
/// flag the `REMUS_DATA_PLANE` environment override applies, then the
/// threads default — the same resolution the `ServeOptions` and
/// `RouterConfig` defaults run, so the flag only needs explicit
/// forwarding where a config is built field by field.
fn data_plane_from_args(args: &Args) -> Result<DataPlane> {
    match args.get("data-plane") {
        Some(s) => DataPlane::parse(s),
        None => Ok(DataPlane::from_env_or(DataPlane::Threads)),
    }
}

/// WAL tuning from the shared flag surface (inert without
/// `--journal-dir`): `--wal-segment-bytes` sets the rotation
/// threshold, `--wal-max-bytes` the per-directory footprint bound,
/// and `--wal-fsync` trades a syscall per drained batch for
/// power-loss durability.
fn wal_from_args(args: &Args) -> WalConfig {
    let dflt = WalConfig::default();
    WalConfig {
        segment_bytes: args.get_or("wal-segment-bytes", dflt.segment_bytes),
        max_total_bytes: args.get_or("wal-max-bytes", dflt.max_total_bytes),
        fsync: if args.flag("wal-fsync") { FsyncMode::PerBatch } else { FsyncMode::Buffered },
        ..dflt
    }
}

/// Build a fabric router from the shared CLI flag surface — the one
/// place `--probe-ms`, `--retry-ms`, `--listen-reg`, `--hb-ms`,
/// `--hb-timeout-ms`, `--psk-file`, `--trace-sample`, `--journal-dir`
/// and `--metrics-addr` are wired, so `serve`, `fabric-route`,
/// `loadgen`, `top` and `trace` cannot drift apart — then announce the
/// registration port and wait for `--min-shards`. `trace_default` is
/// the `--trace-sample` fallback (0 everywhere except `remus trace`,
/// which samples by default).
fn router_from_args(
    args: &Args,
    addrs: Vec<String>,
    ctx: &str,
    trace_default: u64,
) -> Result<Router> {
    let rcfg = RouterConfig {
        probe_period: std::time::Duration::from_millis(args.get_or("probe-ms", 250u64)),
        retry_window: std::time::Duration::from_millis(args.get_or("retry-ms", 1000u64)),
        listen: args.get("listen-reg").map(str::to_string),
        heartbeat_period: std::time::Duration::from_millis(args.get_or("hb-ms", 1000u64)),
        heartbeat_timeout: std::time::Duration::from_millis(args.get_or("hb-timeout-ms", 1000u64)),
        psk: psk_from_args(args)?,
        trace_sample: args.get_or("trace-sample", trace_default),
        data_plane: data_plane_from_args(args)?,
    };
    let opts = RouteOptions {
        journal_dir: args.get("journal-dir").map(std::path::PathBuf::from),
        metrics_addr: args.get("metrics-addr").map(str::to_string),
        wal: wal_from_args(args),
    };
    let router = Router::with_options(&addrs, rcfg, opts)?;
    if let Some(m) = router.metrics_addr() {
        println!("METRICS http://{m}/metrics");
    }
    announce_registration(&router, args, addrs.len(), ctx);
    Ok(router)
}

/// Build one shard's coordinator config from CLI options (shared by
/// `fabric-serve`; `fabric-soak` passes the same flags to its children).
fn shard_config(args: &Args) -> CoordinatorConfig {
    CoordinatorConfig {
        workers: args.get_or("workers", 4usize),
        rows: args.get_or("rows", 64usize),
        cols: args.get_or("cols", 1024usize),
        spare_workers: args.get_or("spares", 0usize),
        errors: if args.flag("nominal-errors") {
            ErrorModel::nominal()
        } else {
            ErrorModel::none()
        },
        seed: args.get_or("seed", 0xC0u64),
        max_batch: args.get_or("max-batch", 64usize),
        max_wait: std::time::Duration::from_micros(args.get_or("max-wait-us", 300u64)),
        trace_sample: args.get_or("trace-sample", 0u64),
        // §Perf list scheduling: --schedule packs every compiled plan
        // across a uniform partition grid (--partitions, default 16);
        // without the flag plans stay the serial program-order
        // reference, bit-identical to every pre-PR-9 run.
        schedule: if args.flag("schedule") {
            ScheduleConfig::packed(args.get_or("partitions", 16u32))
        } else {
            ScheduleConfig::off()
        },
        health: if args.flag("health") {
            Some(HealthConfig {
                wear: WearModel::accelerated(args.get_or("endurance", 3e4f64)),
                spare_rows: 8,
                ..Default::default()
            })
        } else {
            None
        },
        ..Default::default()
    }
}

/// One fabric shard process: a TCP front end over one coordinator.
/// Prints `LISTENING <addr>` (parsed by the `fabric-soak` parent when
/// binding port 0), then serves until a `Shutdown` frame arrives.
fn fabric_serve(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:4870");
    let opts = ServeOptions {
        psk: psk_from_args(args)?,
        journal_dir: args.get("journal-dir").map(std::path::PathBuf::from),
        metrics_addr: args.get("metrics-addr").map(str::to_string),
        wal: wal_from_args(args),
        data_plane: data_plane_from_args(args)?,
        ..ServeOptions::default()
    };
    let server = FabricServer::start_with_options(addr, shard_config(args), opts)?;
    // The LISTENING banner must stay the first stdout line: the
    // fabric-soak parent parses it to learn an ephemeral port.
    println!("LISTENING {}", server.local_addr());
    if let Some(m) = server.metrics_addr() {
        println!("METRICS http://{m}/metrics");
    }
    println!("boot epoch {:#018x}", server.boot_epoch());
    use std::io::Write as _;
    std::io::stdout().flush()?;
    // Registration-based discovery: announce this shard to a router's
    // registration port instead of appearing in its --shards list. The
    // stable --name lets a restarted process reclaim its ring slot.
    if let Some(reg) = args.get("register") {
        let name = args
            .get("name")
            .map(str::to_string)
            .unwrap_or_else(|| server.local_addr().to_string());
        server.register_with(reg, &name, args.flag("spare"));
    }
    server.wait();
    eprintln!("fabric-serve: shutdown frame received, draining");
    server.shutdown();
    Ok(())
}

/// Client-side router over already-running shard endpoints and/or a
/// registration listener for shards that announce themselves.
fn fabric_route(args: &Args) -> Result<()> {
    let shards: Vec<String> = match (args.get("shards"), args.get("listen-reg")) {
        (None, None) => vec!["127.0.0.1:4870".to_string()],
        _ => shard_addrs_from_args(args),
    };
    let requests = args.get_or("requests", 8192u64);
    let router = router_from_args(args, shards, "fabric-route", 0)?;
    // add8 and xor16 land on different shards of a 2-entry ring.
    let kinds = [FunctionKind::Add(8), FunctionKind::Xor(16), FunctionKind::Mul(8)];
    for k in kinds {
        println!("  {} -> shard {:?}", k.name(), router.shard_for(k));
    }
    let (ok, wrong, errs, dt) = drive_load(&router, &kinds, requests, 4096);
    println!(
        "routed {requests} requests over {}/{} live shards in {dt:.2?}: {:.0} req/s \
         (ok {ok}, wrong {wrong}, error results {errs})",
        router.live_shards(),
        router.shard_count(),
        requests as f64 / dt.as_secs_f64()
    );
    let m = router.metrics();
    println!(
        "fleet: shards {}/{} up ({} down) completed={} failed={} mean_batch={:.1} \
         p50={}us p99={}us retired={} hb pings={} pongs={} timeouts={}",
        m.shards_total - m.shards_down,
        m.shards_total,
        m.shards_down,
        m.completed,
        m.failed,
        m.mean_batch_size(),
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0),
        m.retired_workers(),
        m.hb_pings,
        m.hb_pongs,
        m.hb_timeouts
    );
    print_worker_health("fleet", &m);
    router.shutdown();
    Ok(())
}

/// One spawned `fabric-serve` shard process: the child plus its stdout
/// reader (kept open so the child never writes into a closed pipe).
type ShardProc = (std::process::Child, std::io::BufReader<std::process::ChildStdout>);

/// Spawn one `fabric-serve` child on `addr` (port 0 for ephemeral) and
/// parse its `LISTENING <addr>` banner. `register` = (router
/// registration addr, spare flag) makes the child announce itself under
/// the stable name `shard{shard}`.
fn spawn_shard(
    args: &Args,
    exe: &std::path::Path,
    shard: usize,
    addr: &str,
    register: Option<(&str, bool)>,
) -> Result<(ShardProc, String)> {
    let workers = args.get_or("workers", 2usize);
    let mut cmd = std::process::Command::new(exe);
    cmd.args(["fabric-serve", "--addr", addr])
        .args(["--workers", &workers.to_string()])
        .args(["--seed", &(0xC0 + shard as u64).to_string()])
        .stdout(std::process::Stdio::piped());
    if let Some((reg, spare)) = register {
        cmd.args(["--register", reg]).args(["--name", &format!("shard{shard}")]);
        if spare {
            cmd.arg("--spare");
        }
    }
    // Forward every shard_config option so the children run exactly the
    // configuration the user asked for.
    let keys = [
        "rows",
        "cols",
        "spares",
        "max-batch",
        "max-wait-us",
        "endurance",
        "psk-file",
        "trace-sample",
        "partitions",
        "wal-segment-bytes",
        "wal-max-bytes",
        "data-plane",
    ];
    for key in keys {
        if let Some(v) = args.get(key) {
            cmd.arg(format!("--{key}")).arg(v);
        }
    }
    for flag in ["health", "nominal-errors", "wal-fsync", "schedule"] {
        if args.flag(flag) {
            cmd.arg(format!("--{flag}"));
        }
    }
    // Flight recorder: each child journals into its own subdirectory —
    // the WAL footprint bound is per-directory, so a shared dir would
    // let one shard's rotation delete another's segments.
    if let Some(dir) = args.get("journal-dir") {
        cmd.arg("--journal-dir").arg(std::path::Path::new(dir).join(format!("shard{shard}")));
    }
    let mut child = cmd.spawn()?;
    use std::io::BufRead as _;
    let stdout = child.stdout.take().expect("piped child stdout");
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    if let Err(e) = reader.read_line(&mut line) {
        let _ = child.kill();
        let _ = child.wait();
        return Err(e.into());
    }
    let Some(addr) = line.trim().strip_prefix("LISTENING ") else {
        let _ = child.kill();
        let _ = child.wait();
        return Err(anyhow::anyhow!("unexpected shard banner: {line:?}"));
    };
    let addr = addr.to_string();
    println!("shard {shard}: pid {} on {addr}", child.id());
    Ok(((child, reader), addr))
}

/// §Scale loopback soak: spawn one `fabric-serve` *process* per shard
/// on an ephemeral loopback port, shard an open-loop load across them
/// through the router, then stop the fleet over the wire. The fleet is
/// always torn down — also on error paths — so no child outlives the
/// parent. `--spare-shards N` spawns N extra children that register as
/// hot spares; `--chaos-kill` SIGKILLs shard 0 mid-run, serves through
/// the outage, restarts it on the same port, waits for the router to
/// revive it, and proves zero lost/wrong replies (the `CHAOS-OK` line
/// is machine-checked by `tests/integration_fabric.rs` and CI).
fn fabric_soak(args: &Args) -> Result<()> {
    let nshards = args.get_or("shards", 2usize);
    let requests = args.get_or("requests", 100_000u64);
    let spare_shards = args.get_or("spare-shards", 0usize);
    let chaos = args.flag("chaos-kill");
    if chaos && nshards < 2 {
        anyhow::bail!("--chaos-kill needs at least 2 shards to serve through the outage");
    }
    let exe = std::env::current_exe()?;
    let mut children: Vec<ShardProc> = Vec::new();
    let mut addrs: Vec<String> = Vec::new();
    let mut setup_err = None;
    for shard in 0..nshards {
        match spawn_shard(args, &exe, shard, "127.0.0.1:0", None) {
            Ok((proc_, addr)) => {
                children.push(proc_);
                addrs.push(addr);
            }
            Err(e) => {
                setup_err = Some(e);
                break;
            }
        }
    }
    // Drive the load only with a fully spawned fleet; either way, fall
    // through to the teardown below.
    let result = match setup_err {
        Some(e) => Err(e),
        None => (|| {
            let rcfg = RouterConfig {
                probe_period: std::time::Duration::from_millis(100),
                retry_window: std::time::Duration::from_secs(3),
                listen: (spare_shards > 0).then(|| "127.0.0.1:0".to_string()),
                psk: psk_from_args(args)?,
                data_plane: data_plane_from_args(args)?,
                ..Default::default()
            };
            let static_addrs = addrs.clone();
            let router = Router::with_config(&static_addrs, rcfg)?;
            if spare_shards > 0 {
                let reg = router
                    .registration_addr()
                    .expect("listener configured above")
                    .to_string();
                for j in 0..spare_shards {
                    let (proc_, addr) = spawn_shard(
                        args,
                        &exe,
                        nshards + j,
                        "127.0.0.1:0",
                        Some((reg.as_str(), true)),
                    )?;
                    children.push(proc_);
                    addrs.push(addr);
                }
                if !router.wait_for_live(
                    nshards + spare_shards,
                    std::time::Duration::from_secs(15),
                ) {
                    anyhow::bail!(
                        "only {}/{} shards (incl. spares) live after 15s",
                        router.live_shards(),
                        nshards + spare_shards
                    );
                }
                println!("spares: {spare_shards} hot-spare shard(s) registered and connected");
            }
            let kinds = [FunctionKind::Add(8), FunctionKind::Xor(16)];
            let total_live = nshards + spare_shards;
            let (ok, wrong, errs, dt) = if chaos {
                let seg = requests / 3;
                let t0 = std::time::Instant::now();
                let (ok1, w1, e1, _) = drive_load(&router, &kinds, seg, 8192);
                // SIGKILL shard 0 (abrupt socket death, no goodbye).
                let _ = children[0].0.kill();
                let _ = children[0].0.wait();
                // The router notices via reader EOF within moments.
                let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
                while router.live_shards() >= total_live {
                    anyhow::ensure!(
                        std::time::Instant::now() < deadline,
                        "router never noticed the killed shard"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                let down = router.metrics();
                println!(
                    "chaos: killed shard 0; fleet sees {} of {} shards down",
                    down.shards_down, down.shards_total
                );
                // Serve through the outage (failover keeps every reply).
                let (ok2, w2, e2, _) = drive_load(&router, &kinds, seg, 8192);
                // Restart on the same port (brief retry: the kernel may
                // hold the port for a moment after the kill); the
                // supervisor's probe loop revives it into its original
                // ring slot.
                let mut restarted = None;
                for attempt in 0..20 {
                    match spawn_shard(args, &exe, 0, &addrs[0], None) {
                        Ok(p) => {
                            restarted = Some(p);
                            break;
                        }
                        Err(e) => {
                            anyhow::ensure!(attempt < 19, "restart of shard 0 failed: {e:#}");
                            std::thread::sleep(std::time::Duration::from_millis(250));
                        }
                    }
                }
                let (proc_, _) = restarted.expect("restart loop sets or bails");
                children[0] = proc_;
                anyhow::ensure!(
                    router.wait_for_live(total_live, std::time::Duration::from_secs(15)),
                    "killed shard was not revived within 15s"
                );
                println!("chaos: revived shard 0 into its original ring slot");
                let (ok3, w3, e3, _) = drive_load(&router, &kinds, requests - 2 * seg, 8192);
                (ok1 + ok2 + ok3, w1 + w2 + w3, e1 + e2 + e3, t0.elapsed())
            } else {
                drive_load(&router, &kinds, requests, 8192)
            };
            println!(
                "fabric soak: {requests} requests over {nshards} shard processes in \
                 {dt:.2?}: {:.0} req/s (ok {ok}, wrong {wrong}, error results {errs})",
                requests as f64 / dt.as_secs_f64()
            );
            let m = router.metrics();
            println!(
                "fleet: shards {}/{} up ({} down) completed={} failed={} retired={}/{}",
                m.shards_total - m.shards_down,
                m.shards_total,
                m.shards_down,
                m.completed,
                m.failed,
                m.retired_workers(),
                m.worker_health.len()
            );
            print_worker_health("fleet", &m);
            router.shutdown();
            if chaos {
                anyhow::ensure!(
                    wrong == 0 && errs == 0 && ok == requests,
                    "chaos run lost or corrupted replies: ok {ok}/{requests}, \
                     wrong {wrong}, error results {errs}"
                );
                println!(
                    "CHAOS-OK requests={requests} ok={ok} wrong={wrong} error_results={errs}"
                );
            }
            Ok(())
        })(),
    };
    // Teardown: graceful Shutdown frame first, kill as the fallback.
    let psk = psk_from_args(args)?;
    for (i, (mut child, _reader)) in children.into_iter().enumerate() {
        let graceful = addrs.get(i).map(|a| shutdown_endpoint_auth(a, psk.as_ref()));
        if let Some(Err(e)) = graceful {
            eprintln!("fabric-soak: shard {i} wire shutdown failed ({e:#}); killing");
            let _ = child.kill();
        }
        let _ = child.wait();
    }
    result
}

/// Run the open-loop sweep against any target, print the per-kind
/// percentile table + knee verdict, and write the JSON artifact.
fn run_loadgen_sweep(
    sub: &dyn Submitter,
    cfg: &LoadgenConfig,
    qps_points: &[f64],
    out: &str,
) -> Result<()> {
    println!(
        "loadgen: {} requests/point at {:?} offered qps, window {}, seed {:#x}",
        cfg.requests, qps_points, cfg.window, cfg.seed
    );
    let sweep = loadgen::sweep(sub, cfg, qps_points);
    let mut t = Table::new(
        "open-loop sweep: per-kind latency percentiles (us) per offered rate",
        &["offered", "achieved", "stalls", "kind", "count", "p50", "p90", "p99", "max"],
    );
    for p in &sweep.points {
        anyhow::ensure!(
            p.wrong == 0 && p.errors == 0,
            "loadgen verification failed at {} qps: ok {}/{} wrong {} errors {}",
            p.offered_qps,
            p.ok,
            p.requests,
            p.wrong,
            p.errors
        );
        for (kind, k) in &p.kinds {
            t.row(&[
                format!("{:.0}", p.offered_qps),
                format!("{:.0}", p.achieved_qps),
                p.window_stalls.to_string(),
                kind.name(),
                k.hist.count().to_string(),
                k.hist.percentile_us(50.0).to_string(),
                k.hist.percentile_us(90.0).to_string(),
                k.hist.percentile_us(99.0).to_string(),
                k.hist.max_us().to_string(),
            ]);
        }
    }
    t.print();
    match sweep.knee_qps {
        Some(k) => println!(
            "knee: highest sustained offered rate = {k:.0} qps \
             (criterion: achieved >= 90% of offered)"
        ),
        None => println!("knee: none — every sweep point collapsed below 90% of its offer"),
    }
    // Informational sealed-vs-plaintext frame cost (§Security): always
    // measured in-process so the artifact carries the crypto tax next
    // to the latency data it contextualizes, whether or not this sweep
    // itself ran sealed.
    let seal = loadgen::measure_seal_overhead(4096);
    println!(
        "seal overhead (codec-only, {} frames): plain {:.0}ns/frame, sealed {:.0}ns/frame \
         ({:+.1}%)",
        seal.frames, seal.plain_ns_per_frame, seal.sealed_ns_per_frame, seal.overhead_pct
    );
    // Informational telemetry hot-path cost (§Telemetry): the same
    // methodology for the tracing tax — the disabled arm must stay
    // within noise of the baseline, which is the acceptance bar for
    // shipping tracing machinery on the data path at all.
    let telemetry = loadgen::measure_telemetry_overhead(4096);
    println!(
        "telemetry overhead ({} requests): baseline {:.0}ns/req, disabled tracer {:.0}ns/req \
         ({:+.1}%), 1-in-{} sampling {:.0}ns/req ({:+.1}%)",
        telemetry.requests,
        telemetry.baseline_ns_per_req,
        telemetry.disabled_ns_per_req,
        telemetry.disabled_overhead_pct,
        loadgen::TELEMETRY_PROBE_SAMPLE,
        telemetry.sampled_ns_per_req,
        telemetry.sampled_overhead_pct
    );
    // Informational flight-recorder cost (§Observability): what
    // --journal-dir adds per recorded journal event — no WAL vs
    // buffered appends vs an fsync per drained batch — so the artifact
    // records the persistence tax before anyone enables it fleet-wide.
    let journal = loadgen::measure_journal_overhead(4096)?;
    println!(
        "journal persistence overhead ({} events): off {:.0}ns/event, buffered WAL \
         {:.0}ns/event ({:+.1}%), fsync-per-batch {:.0}ns/event ({:+.1}%)",
        journal.events,
        journal.off_ns_per_event,
        journal.buffered_ns_per_event,
        journal.buffered_overhead_pct,
        journal.fsync_ns_per_event,
        journal.fsync_overhead_pct
    );
    loadgen::write_json(out, cfg, &sweep, Some(&seal), Some(&telemetry), Some(&journal))?;
    println!("(machine-readable results written to {out})");
    Ok(())
}

/// Open-loop fleet load generator (§Scale): the measurement tool the
/// closed-loop drivers above cannot be — it keeps offering requests on
/// a seeded Poisson schedule when the target saturates, so the sweep
/// exposes the knee instead of silently throttling to match.
fn loadgen_cmd(args: &Args) -> Result<()> {
    // Strict parse: a typo must fail the run, not silently shrink the
    // sweep (CI archives the artifact — a lost point would go unseen).
    let mut qps_points: Vec<f64> = Vec::new();
    for tok in args.get("qps").unwrap_or("1000,2000,4000").split(',') {
        let q: f64 = tok
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--qps: cannot parse rate {tok:?}"))?;
        anyhow::ensure!(q > 0.0, "--qps rates must be positive (got {q})");
        qps_points.push(q);
    }
    anyhow::ensure!(!qps_points.is_empty(), "--qps needs a comma-separated list of rates");
    let cfg = LoadgenConfig {
        qps: qps_points[0],
        requests: args.get_or("requests", 8192u64),
        seed: args.get_or("seed", 0x10ADu64),
        window: args.get_or("window", 1024usize),
        ..Default::default()
    };
    // --connections switches to the knee-vs-connection-count mode
    // (§Scale): self-hosted loopback fleets swept under each data
    // plane instead of one external target.
    if args.get("connections").is_some() {
        let out = args.get("out").unwrap_or("BENCH_loadgen_epoll.json").to_string();
        return loadgen_connections(args, &qps_points, &cfg, &out);
    }
    let out = args.get("out").unwrap_or("BENCH_loadgen.json").to_string();
    // Target: a fabric router (static shards and/or registration) when
    // any fabric flag is given, the in-process coordinator otherwise —
    // the generator itself is Submitter-generic.
    if args.get("shards").is_some() || args.get("listen-reg").is_some() {
        let router = router_from_args(args, shard_addrs_from_args(args), "loadgen", 0)?;
        let res = run_loadgen_sweep(&router, &cfg, &qps_points, &out);
        let m = router.metrics();
        println!(
            "fleet after sweep: shards {}/{} up, completed={} hb pings={} pongs={} timeouts={}",
            m.shards_total - m.shards_down,
            m.shards_total,
            m.completed,
            m.hb_pings,
            m.hb_pongs,
            m.hb_timeouts
        );
        router.shutdown();
        res
    } else {
        let coord = Coordinator::start(shard_config(args))?;
        let res = run_loadgen_sweep(&coord, &cfg, &qps_points, &out);
        coord.shutdown();
        res
    }
}

/// §Scale knee-vs-connections sweep (`remus loadgen --connections
/// 1,8,64,256`): for each data plane (threads always, epoll where the
/// platform supports it) and each connection count C, self-host a
/// fresh 2-shard loopback fleet on that plane, fan the open-loop QPS
/// sweep out over C routers — each owning its own data connections,
/// so the serving side really carries C conn-thread pairs or C
/// reactor registrations — and record where the knee lands. Writes
/// `BENCH_loadgen_epoll.json`; CI gates the epoll knee at 64
/// connections against the threads knee from the *same* run.
fn loadgen_connections(
    args: &Args,
    qps_points: &[f64],
    cfg: &LoadgenConfig,
    out: &str,
) -> Result<()> {
    let mut conns: Vec<usize> = Vec::new();
    for tok in args.get("connections").unwrap_or("1,8,64,256").split(',') {
        let c: usize = tok
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("--connections: cannot parse count {tok:?}"))?;
        anyhow::ensure!(c >= 1, "--connections counts must be at least 1");
        conns.push(c);
    }
    anyhow::ensure!(!conns.is_empty(), "--connections needs a comma-separated list of counts");
    let planes = if remus::fabric::reactor::supported() {
        vec![DataPlane::Threads, DataPlane::Epoll]
    } else {
        eprintln!(
            "loadgen: the epoll data plane is not supported on this platform; \
             sweeping threads only"
        );
        vec![DataPlane::Threads]
    };
    let mut reports: Vec<loadgen::ConnSweepReport> = Vec::new();
    for plane in planes {
        let mut points = Vec::new();
        for &c in &conns {
            // A fresh fleet per point: two shards (so consistent
            // hashing spreads the kinds) serving C client routers.
            let mk_server = || {
                FabricServer::start_with_options(
                    "127.0.0.1:0",
                    shard_config(args),
                    ServeOptions { data_plane: plane, ..ServeOptions::default() },
                )
            };
            let s1 = mk_server()?;
            let s2 = mk_server()?;
            let addrs = vec![s1.local_addr().to_string(), s2.local_addr().to_string()];
            let mut routers = Vec::with_capacity(c);
            for _ in 0..c {
                routers.push(Router::with_config(
                    &addrs,
                    RouterConfig { data_plane: plane, ..Default::default() },
                )?);
            }
            let multi = loadgen::MultiConn::new(routers);
            println!("connections sweep [{plane}]: {c} connection(s) at {qps_points:?} qps");
            let sweep = loadgen::sweep(&multi, cfg, qps_points);
            for p in &sweep.points {
                anyhow::ensure!(
                    p.wrong == 0 && p.errors == 0,
                    "loadgen verification failed at {c} connections / {} qps: \
                     ok {}/{} wrong {} errors {}",
                    p.offered_qps,
                    p.ok,
                    p.requests,
                    p.wrong,
                    p.errors
                );
            }
            match sweep.knee_qps {
                Some(k) => println!("  knee at {c} connection(s): {k:.0} qps"),
                None => println!("  knee at {c} connection(s): none (every point collapsed)"),
            }
            for r in multi.into_inner() {
                r.shutdown();
            }
            s1.shutdown();
            s2.shutdown();
            points.push(loadgen::ConnPoint {
                connections: c,
                points: sweep.points,
                knee_qps: sweep.knee_qps,
            });
        }
        reports.push(loadgen::ConnSweepReport { plane: plane.to_string(), points });
    }
    // Intra-run verdict: both planes measured the same schedule on the
    // same machine, so their knees are directly comparable.
    if let [threads, epoll] = &reports[..] {
        let fmt =
            |k: Option<f64>| k.map_or_else(|| "none".to_string(), |q| format!("{q:.0} qps"));
        for &c in &conns {
            println!(
                "verdict at {c} connection(s): threads knee {} vs epoll knee {}",
                fmt(threads.knee_at(c)),
                fmt(epoll.knee_at(c))
            );
        }
    }
    loadgen::write_connections_json(out, cfg, qps_points, &reports)?;
    println!("(machine-readable results written to {out})");
    Ok(())
}

/// One `remus top` frame: merged fleet metrics, per-kind counters,
/// per-worker health, and the newest entries of the fleet-merged
/// reliability event journal (each pulled over the wire with per-shard
/// cursors, so repeated frames are incremental). `prev_epochs` carries
/// the per-slot boot epochs seen by the previous frame so a shard that
/// restarted between frames is flagged explicitly (wire v6).
fn print_top_frame(router: &Router, prev_epochs: &mut HashMap<usize, u64>) {
    let m = router.metrics();
    let uptime_s = m.uptime_ns as f64 / 1e9;
    let qps = if uptime_s > 0.0 {
        m.completed as f64 / uptime_s
    } else {
        0.0
    };
    println!(
        "== remus top: {}/{} shards up ({} down), fleet uptime {:.1}s ==",
        m.shards_total - m.shards_down,
        m.shards_total,
        m.shards_down,
        uptime_s
    );
    println!(
        "requests: submitted={} completed={} failed={} (~{qps:.0} req/s over the uptime)",
        m.submitted, m.completed, m.failed
    );
    println!(
        "latency: p50={}us p99={}us max={}us ({} samples past the top histogram bin)",
        m.latency_percentile_us(50.0),
        m.latency_percentile_us(99.0),
        m.lat_max_us,
        m.lat_overflow
    );
    for (family, k) in m.kind_stats.iter().enumerate() {
        if k.submitted + k.completed + k.failed > 0 {
            println!(
                "  kind {:<9} submitted={} completed={} failed={}",
                FunctionKind::family_name(family),
                k.submitted,
                k.completed,
                k.failed
            );
        }
    }
    print_worker_health("fleet", &m);
    let events = router.fleet_events();
    let now = unix_now_ns();
    let tail = events.len().saturating_sub(16);
    println!(
        "events: {} in the merged fleet journal (newest {} shown)",
        events.len(),
        events.len() - tail
    );
    for e in &events[tail..] {
        let origin = if e.shard == SHARD_NONE {
            "fabric".to_string()
        } else {
            format!("shard {}", e.shard)
        };
        let age_s = now.saturating_sub(e.at_ns) as f64 / 1e9;
        println!("  [{age_s:>9.3}s ago] {origin:<8} {}", e.kind.describe());
    }
    // Boot-epoch watch (wire v6): a changed epoch means the shard
    // process restarted between frames — its journal cursor was reset
    // and a shard_restarted marker merged above.
    let epochs = router.fleet_epochs();
    let mut restarted: Vec<(usize, u64, u64)> = epochs
        .iter()
        .filter_map(|(&slot, &ep)| match prev_epochs.get(&slot) {
            Some(&old) if old != 0 && old != ep => Some((slot, old, ep)),
            _ => None,
        })
        .collect();
    restarted.sort_unstable();
    for (slot, old, new) in restarted {
        println!("  !! shard {slot} RESTARTED since last frame (boot epoch {old:#x} -> {new:#x})");
    }
    *prev_epochs = epochs;
}

/// §Telemetry live fleet inspection (`remus top`): attach a read-only
/// router to a running fleet and print dashboard frames. One-shot by
/// default (`--once` is accepted as the explicit synonym); `--watch`
/// redraws every `--interval-ms`, bounded by `--rounds` so CI can
/// smoke-test the watch loop without hanging.
fn top_cmd(args: &Args) -> Result<()> {
    let shards = shard_addrs_from_args(args);
    anyhow::ensure!(
        !shards.is_empty() || args.get("listen-reg").is_some(),
        "remus top needs a fleet: --shards a:p,b:p and/or --listen-reg host:port"
    );
    let router = router_from_args(args, shards, "top", 0)?;
    let rounds = if args.flag("watch") {
        args.get_or("rounds", u64::MAX)
    } else {
        1
    };
    let interval = std::time::Duration::from_millis(args.get_or("interval-ms", 1000u64));
    let mut epochs = HashMap::new();
    for round in 0..rounds {
        if round > 0 {
            std::thread::sleep(interval);
        }
        print_top_frame(&router, &mut epochs);
    }
    router.shutdown();
    Ok(())
}

/// The `remus trace` JSON artifact (CI archives it next to the bench
/// JSON files): sampling config, span/trace counts, and the per-stage
/// percentile summaries.
fn write_trace_json(
    path: &str,
    sample: u64,
    requests: u64,
    spans: usize,
    traces: usize,
    summaries: &[StageSummary],
) -> Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"telemetry\",\n");
    out.push_str(&format!("  \"trace_sample\": {sample},\n"));
    out.push_str(&format!("  \"requests\": {requests},\n"));
    out.push_str(&format!("  \"spans\": {spans},\n"));
    out.push_str(&format!("  \"traces\": {traces},\n"));
    out.push_str("  \"stages\": [\n");
    for (i, s) in summaries.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"stage\": \"{}\", \"count\": {}, \"p50_ns\": {}, \"p90_ns\": {}, \
             \"p99_ns\": {}, \"max_ns\": {}, \"total_ns\": {}}}{}\n",
            s.stage.name(),
            s.count,
            s.p50_ns,
            s.p90_ns,
            s.p99_ns,
            s.max_ns,
            s.total_ns,
            if i + 1 < summaries.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)?;
    Ok(())
}

/// §Telemetry stage tracing (`remus trace`): drive sampled closed-loop
/// load, collect the per-request stage spans (router queue and wire
/// transit on the router side; batcher wait, worker exec, ECC verify,
/// TMR vote and readback on the shard side), and print per-stage
/// latency percentiles. Fabric flags pull spans fleet-wide over the
/// wire; without them an in-process coordinator records the five
/// worker-side stages. Fabric shards must run the same --trace-sample
/// rate (sampling is deterministic in the trace id, so agreeing rates
/// make every hop keep the same requests). `--json` writes the
/// machine-readable artifact to `--out` (default BENCH_telemetry.json).
fn trace_cmd(args: &Args) -> Result<()> {
    let sample = args.get_or("trace-sample", 16u64);
    anyhow::ensure!(sample > 0, "remus trace needs --trace-sample >= 1 (1 = trace everything)");
    let requests = args.get_or("requests", 2048u64);
    let kinds = [FunctionKind::Add(8), FunctionKind::Xor(16), FunctionKind::Mul(8)];
    let fabric = args.get("shards").is_some() || args.get("listen-reg").is_some();
    let (spans, label) = if fabric {
        let router = router_from_args(args, shard_addrs_from_args(args), "trace", 16)?;
        let (ok, wrong, errs, dt) = drive_load(&router, &kinds, requests, 2048);
        println!(
            "traced {requests} requests over {} live shards in {dt:.2?} (ok {ok}, wrong {wrong}, \
             error results {errs})",
            router.live_shards()
        );
        let spans = router.fleet_spans();
        router.shutdown();
        (spans, "fleet")
    } else {
        let mut cfg = shard_config(args);
        cfg.trace_sample = sample;
        let coord = Coordinator::start(cfg)?;
        let (ok, wrong, errs, dt) = drive_load(&coord, &kinds, requests, 2048);
        println!(
            "traced {requests} in-process requests in {dt:.2?} (ok {ok}, wrong {wrong}, \
             error results {errs})"
        );
        let spans = coord.tracer().spans();
        coord.shutdown();
        (spans, "in-process")
    };
    let mut traces: Vec<u64> = spans.iter().map(|s| s.trace).collect();
    traces.sort_unstable();
    traces.dedup();
    println!(
        "collected {} stage spans from {} sampled traces ({label}, 1-in-{sample} sampling)",
        spans.len(),
        traces.len()
    );
    let summaries = stage_summaries(&spans);
    let mut t = Table::new(
        "per-stage latency across sampled traces (us)",
        &["stage", "count", "p50", "p90", "p99", "max", "total_ms"],
    );
    for s in &summaries {
        t.row(&[
            s.stage.name().to_string(),
            s.count.to_string(),
            format!("{:.1}", s.p50_ns as f64 / 1e3),
            format!("{:.1}", s.p90_ns as f64 / 1e3),
            format!("{:.1}", s.p99_ns as f64 / 1e3),
            format!("{:.1}", s.max_ns as f64 / 1e3),
            format!("{:.2}", s.total_ns as f64 / 1e6),
        ]);
    }
    t.print();
    if args.flag("json") {
        let out = args.get("out").unwrap_or("BENCH_telemetry.json");
        write_trace_json(out, sample, requests, spans.len(), traces.len(), &summaries)?;
        println!("(machine-readable results written to {out})");
    }
    Ok(())
}

/// Newest events shown per epoch on stdout; the `--json` artifact
/// always carries the full log.
const POSTMORTEM_TAIL: usize = 32;

/// Per-epoch reliability summary accumulated from a recovered WAL
/// timeline — the numbers a post-mortem reads first.
#[derive(Default)]
struct PmSummary {
    scrubs: u64,
    corrected: u64,
    stuck_cells: u64,
    remapped_rows: u64,
    escalations: u64,
    peak_level: u8,
    deescalations: u64,
    retired_workers: u64,
    membership_events: u64,
    auth_rejects: u64,
    shard_restarts: u64,
}

fn summarize_epoch(tl: &EpochTimeline) -> PmSummary {
    let mut s = PmSummary::default();
    let mut retired: Vec<u32> = Vec::new();
    for e in &tl.events {
        match e.kind {
            EventKind::Scrub { corrected, detected, remapped, .. } => {
                s.scrubs += 1;
                s.corrected += corrected;
                s.stuck_cells += detected as u64;
                s.remapped_rows += remapped as u64;
            }
            EventKind::StuckCell { cells, .. } => s.stuck_cells += cells,
            EventKind::RowRemap { rows, .. } => s.remapped_rows += rows,
            EventKind::PolicyEscalate { level, .. } => {
                s.escalations += 1;
                s.peak_level = s.peak_level.max(level);
            }
            EventKind::PolicyDeescalate { .. } => s.deescalations += 1,
            EventKind::WorkerRetire { worker } => {
                if !retired.contains(&worker) {
                    retired.push(worker);
                }
            }
            EventKind::SparePromote { .. }
            | EventKind::SpareDemote { .. }
            | EventKind::ShardDown { .. }
            | EventKind::ShardRevive { .. }
            | EventKind::HeartbeatTimeout { .. }
            | EventKind::FailoverReplay { .. } => s.membership_events += 1,
            EventKind::AuthReject => s.auth_rejects += 1,
            EventKind::ShardRestarted { .. } => s.shard_restarts += 1,
        }
    }
    s.retired_workers = retired.len() as u64;
    s
}

/// §Observability crash forensics (`remus postmortem`): read a dead
/// process's `--journal-dir` WAL back from disk — no fleet, no socket,
/// just the segment files — and reconstruct its reliability timeline.
/// Epochs print oldest boot first; within an epoch events are in
/// journal (causal) order. A torn tail is called out, never fatal:
/// a crash mid-record loses at most that suffix.
fn postmortem_cmd(args: &Args) -> Result<()> {
    let dir = args
        .get("journal-dir")
        .ok_or_else(|| anyhow::anyhow!("remus postmortem needs --journal-dir <dir>"))?;
    let timelines = read_wal_dir(std::path::Path::new(dir))?;
    anyhow::ensure!(!timelines.is_empty(), "no readable WAL segments under {dir}");
    println!("postmortem: {} boot epoch(s) recovered from {dir}", timelines.len());
    for (i, tl) in timelines.iter().enumerate() {
        let s = summarize_epoch(tl);
        let t0 = tl.events.first().map(|e| e.at_ns).unwrap_or(0);
        let wall_s = tl
            .events
            .last()
            .map(|last| last.at_ns.saturating_sub(t0) as f64 / 1e9)
            .unwrap_or(0.0);
        println!(
            "\n== boot {}/{}: epoch {:#018x} — {} event(s) over {:.3}s across {} segment(s){} ==",
            i + 1,
            timelines.len(),
            tl.epoch,
            tl.events.len(),
            wall_s,
            tl.segments,
            if tl.torn_tail { ", TORN TAIL (crash mid-record; suffix lost)" } else { "" }
        );
        println!(
            "  scrubs {} (corrected {}), stuck cells {}, remapped rows {}, escalations {} \
             (peak level {}), de-escalations {}, retired workers {}, membership events {}, \
             auth rejects {}, shard restarts seen {}",
            s.scrubs,
            s.corrected,
            s.stuck_cells,
            s.remapped_rows,
            s.escalations,
            s.peak_level,
            s.deescalations,
            s.retired_workers,
            s.membership_events,
            s.auth_rejects,
            s.shard_restarts
        );
        let tail = tl.events.len().saturating_sub(POSTMORTEM_TAIL);
        if tail > 0 {
            println!("  ... {tail} earlier event(s) elided (full log in the --json artifact)");
        }
        let mut t = Table::new(
            "causal event chain (oldest shown first)",
            &["seq", "shard", "t+ms", "event"],
        );
        for e in &tl.events[tail..] {
            let origin =
                if e.shard == SHARD_NONE { "fabric".to_string() } else { e.shard.to_string() };
            t.row(&[
                e.seq.to_string(),
                origin,
                format!("{:.3}", e.at_ns.saturating_sub(t0) as f64 / 1e6),
                e.kind.describe(),
            ]);
        }
        t.print();
    }
    if args.flag("json") {
        let out = args.get("out").unwrap_or("BENCH_postmortem.json");
        write_postmortem_json(out, dir, &timelines)?;
        println!("(machine-readable results written to {out})");
    }
    Ok(())
}

/// Escape for embedding in a hand-rolled JSON string (the journal's
/// describe() strings are plain ASCII, but a journal dir path is
/// user-controlled).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// The `remus postmortem --json` artifact: per-epoch summary counters
/// plus the complete recovered event log (CI machine-checks the
/// escalation story from it and archives it next to the bench JSONs).
fn write_postmortem_json(path: &str, dir: &str, timelines: &[EpochTimeline]) -> Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"postmortem\",\n");
    out.push_str(&format!("  \"journal_dir\": \"{}\",\n", json_escape(dir)));
    out.push_str("  \"epochs\": [\n");
    for (i, tl) in timelines.iter().enumerate() {
        let s = summarize_epoch(tl);
        out.push_str("    {\n");
        out.push_str(&format!("      \"epoch\": \"{:#018x}\",\n", tl.epoch));
        out.push_str(&format!("      \"segments\": {},\n", tl.segments));
        out.push_str(&format!("      \"torn_tail\": {},\n", tl.torn_tail));
        out.push_str(&format!("      \"events\": {},\n", tl.events.len()));
        out.push_str(&format!("      \"scrubs\": {},\n", s.scrubs));
        out.push_str(&format!("      \"corrected\": {},\n", s.corrected));
        out.push_str(&format!("      \"stuck_cells\": {},\n", s.stuck_cells));
        out.push_str(&format!("      \"remapped_rows\": {},\n", s.remapped_rows));
        out.push_str(&format!("      \"escalations\": {},\n", s.escalations));
        out.push_str(&format!("      \"peak_policy_level\": {},\n", s.peak_level));
        out.push_str(&format!("      \"deescalations\": {},\n", s.deescalations));
        out.push_str(&format!("      \"retired_workers\": {},\n", s.retired_workers));
        out.push_str(&format!("      \"membership_events\": {},\n", s.membership_events));
        out.push_str(&format!("      \"auth_rejects\": {},\n", s.auth_rejects));
        out.push_str(&format!("      \"shard_restarts\": {},\n", s.shard_restarts));
        out.push_str("      \"log\": [\n");
        for (j, e) in tl.events.iter().enumerate() {
            out.push_str(&format!(
                "        {{\"seq\": {}, \"shard\": {}, \"at_ns\": {}, \"event\": \"{}\"}}{}\n",
                e.seq,
                e.shard,
                e.at_ns,
                json_escape(&e.kind.describe()),
                if j + 1 < tl.events.len() { "," } else { "" }
            ));
        }
        out.push_str("      ]\n");
        out.push_str(&format!("    }}{}\n", if i + 1 < timelines.len() { "," } else { "" }));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)?;
    Ok(())
}
