//! Lifetime simulation harness: Monte-Carlo weight degradation on the
//! real ECC machinery, validated against the closed-form
//! [`crate::nn::degradation::DegradationModel`] (paper §VI-B2, Fig. 5).
//!
//! Setup mirrors the closed-form model exactly: a crossbar stores
//! 32-bit weights; every "batch" accesses all of them, drifting each
//! stored bit with probability `p_input`; the protected copy is scrubbed
//! (verify + correct) once per batch. Tracked observables:
//!
//! * **baseline corrupted weights** — weights whose bits differ from the
//!   golden copy (no protection); closed-form
//!   `W * (1 - (1 - p_w)^T)`.
//! * **failed ECC blocks** — blocks that ever saw >= 2 errors within one
//!   scrub interval (the code's uncorrectable regime); closed-form
//!   `B * (1 - (1 - p_block)^T)`. This is the tight comparison: the
//!   closed-form weight estimate multiplies it by a constant
//!   weights-per-block factor.
//! * **ECC corrupted weights** — distinct weights corrupted in a failed
//!   block at the moment it first failed (the closed-form's definition:
//!   damage is assessed at first failure, ~1.87 weights/block).
//!
//! The soak acceptance gate ("health disabled matches the closed form")
//! is asserted by the in-tree test and reported by `remus lifetime` and
//! `cargo bench --bench lifetime` (-> `BENCH_lifetime.json`).

use std::collections::HashSet;

use crate::ecc::DiagonalEcc;
use crate::errs::{ErrorModel, Injector};
use crate::nn::degradation::DegradationModel;
use crate::util::bitmat::BitMatrix;
use crate::util::rng::Pcg64;
use crate::util::stats::{one_minus_pow, prob_at_least_two};

/// Parameters of one lifetime run.
#[derive(Clone, Copy, Debug)]
pub struct LifetimeConfig {
    pub rows: usize,
    pub cols: usize,
    /// ECC block size.
    pub m: usize,
    /// Per-bit drift probability per batch (access drift).
    pub p_input: f64,
    pub batches: u64,
    pub record_every: u64,
    pub seed: u64,
}

impl Default for LifetimeConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cols: 1024,
            m: 16,
            p_input: 1e-4,
            batches: 512,
            record_every: 128,
            seed: 0x11FE,
        }
    }
}

/// One recorded point of the simulated and closed-form curves.
#[derive(Clone, Copy, Debug)]
pub struct LifetimePoint {
    pub batch: u64,
    pub sim_baseline_weights: f64,
    pub model_baseline_weights: f64,
    pub sim_failed_blocks: f64,
    pub model_failed_blocks: f64,
    pub sim_ecc_weights: f64,
    pub model_ecc_weights: f64,
}

/// Full run output plus the model it was compared against.
#[derive(Clone, Debug)]
pub struct LifetimeReport {
    pub cfg: LifetimeConfig,
    pub model: DegradationModel,
    pub points: Vec<LifetimePoint>,
}

impl LifetimeReport {
    /// Relative errors |sim - model| / model at the final point:
    /// `(baseline weights, failed blocks)`.
    pub fn final_errors(&self) -> (f64, f64) {
        let p = self.points.last().expect("at least one recorded point");
        let rel = |sim: f64, model: f64| {
            if model <= 0.0 {
                0.0
            } else {
                (sim - model).abs() / model
            }
        };
        (
            rel(p.sim_baseline_weights, p.model_baseline_weights),
            rel(p.sim_failed_blocks, p.model_failed_blocks),
        )
    }
}

fn corrupted_weights(now: &BitMatrix, golden: &BitMatrix) -> usize {
    let cols = now.cols();
    let mut weights: HashSet<usize> = HashSet::new();
    for r in 0..now.rows() {
        for c in 0..cols {
            if now.get(r, c) != golden.get(r, c) {
                weights.insert((r * cols + c) / 32);
            }
        }
    }
    weights.len()
}

/// Run the lifetime simulation.
pub fn simulate(cfg: &LifetimeConfig) -> LifetimeReport {
    let (rows, cols, m) = (cfg.rows, cfg.cols, cfg.m);
    assert!(rows % m == 0 && cols % m == 0, "m must divide the array");
    assert!(cols % 32 == 0, "cols must be a multiple of 32 (weights tile each row)");
    let bits = rows * cols;
    let total_blocks = (bits / (m * m)) as f64;
    let p_block = prob_at_least_two((m * m) as f64, cfg.p_input);
    let model = DegradationModel { weights: bits as f64 / 32.0, bits: 32.0, m: m as f64 };

    let mut seed_rng = Pcg64::new(cfg.seed, 0);
    let golden = BitMatrix::from_fn(rows, cols, |_, _| seed_rng.bernoulli(0.5));
    let mut base = golden.clone();
    let mut prot = golden.clone();
    let mut ecc = DiagonalEcc::new(rows, cols, m);
    ecc.encode(&prot);
    let drift_model = ErrorModel::indirect_only(cfg.p_input);
    let mut inj_base = Injector::new(drift_model, cfg.seed, 1);
    let mut inj_prot = Injector::new(drift_model, cfg.seed, 2);

    let mut failed_blocks: HashSet<(usize, usize)> = HashSet::new();
    let mut frozen_weights: HashSet<usize> = HashSet::new();
    let mut points = Vec::new();
    for t in 1..=cfg.batches {
        inj_base.input_drifts(bits, |i| base.flip(i / cols, i % cols));
        inj_prot.input_drifts(bits, |i| prot.flip(i / cols, i % cols));
        let out = ecc.correct(&mut prot);
        for &(bi, bj) in &out.uncorrectable_blocks {
            if failed_blocks.insert((bi, bj)) {
                // Assess the damage at first failure (the closed-form's
                // per-block weight estimate).
                for r in bi * m..(bi + 1) * m {
                    for c in bj * m..(bj + 1) * m {
                        if prot.get(r, c) != golden.get(r, c) {
                            frozen_weights.insert((r * cols + c) / 32);
                        }
                    }
                }
            }
        }
        if t % cfg.record_every == 0 || t == cfg.batches {
            points.push(LifetimePoint {
                batch: t,
                sim_baseline_weights: corrupted_weights(&base, &golden) as f64,
                model_baseline_weights: model.expected_corrupted_baseline(cfg.p_input, t as f64),
                sim_failed_blocks: failed_blocks.len() as f64,
                model_failed_blocks: total_blocks * one_minus_pow(p_block, t as f64),
                sim_ecc_weights: frozen_weights.len() as f64,
                model_ecc_weights: model.expected_corrupted_ecc(cfg.p_input, t as f64),
            });
        }
    }
    LifetimeReport { cfg: *cfg, model, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulated_curves_track_closed_form() {
        // Acceptance gate: with health disabled, simulated degradation
        // matches the closed-form model. Sized so expectations are large
        // enough that the (seeded, deterministic) Monte-Carlo noise sits
        // far inside the tolerance.
        let cfg = LifetimeConfig {
            rows: 64,
            cols: 256,
            m: 16,
            p_input: 4e-4,
            batches: 256,
            record_every: 64,
            seed: 7,
        };
        let rep = simulate(&cfg);
        assert_eq!(rep.points.len(), 4);
        let last = rep.points.last().unwrap();
        assert!(last.model_baseline_weights > 100.0, "regime check");
        assert!(last.model_failed_blocks > 10.0, "regime check");
        let (rel_base, rel_blocks) = rep.final_errors();
        assert!(rel_base < 0.10, "baseline rel err {rel_base}");
        assert!(rel_blocks < 0.25, "failed-block rel err {rel_blocks}");
        // The ECC weight count agrees with the closed form up to its
        // constant weights-per-block approximation.
        assert!(last.sim_ecc_weights > 0.0);
        assert!(last.sim_ecc_weights < 4.0 * last.model_ecc_weights);
        assert!(4.0 * last.sim_ecc_weights > last.model_ecc_weights);
        // And protection helps: ECC loses far fewer weights.
        assert!(last.sim_ecc_weights < 0.5 * last.sim_baseline_weights);
        // Curves are monotone in t (cumulative failure definitions).
        for w in rep.points.windows(2) {
            assert!(w[1].sim_failed_blocks >= w[0].sim_failed_blocks);
            assert!(w[1].sim_ecc_weights >= w[0].sim_ecc_weights);
            assert!(w[1].model_baseline_weights >= w[0].model_baseline_weights);
        }
    }

    #[test]
    fn corrupted_weight_counting() {
        let golden = BitMatrix::zeros(4, 64);
        let mut now = golden.clone();
        assert_eq!(corrupted_weights(&now, &golden), 0);
        now.flip(0, 3);
        now.flip(0, 17); // same 32-bit weight
        now.flip(0, 40); // second weight of row 0
        now.flip(2, 0); // row 2, weight index 4
        assert_eq!(corrupted_weights(&now, &golden), 3);
    }
}
