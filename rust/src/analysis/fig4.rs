//! Multiplication + network reliability analysis (paper §VI-A/B, Fig. 4).
//!
//! Method, mirroring the paper's:
//! 1. Monte-Carlo fault injection on the real MultPIM micro-code
//!    measures the **logical masking**: `alpha` = P[a single random gate
//!    fault corrupts the product] and `gamma` = P[two independently
//!    faulty copies share a wrong output bit].
//! 2. Extrapolation to un-simulatable rates (p_gate down to 1e-10):
//!    * baseline    `p_mult(p) = 1 - (1 - alpha * p)^G`,
//!    * TMR (ideal) `3 * gamma * q^2` with `q = p_mult(p)` (two of three
//!      copies wrong AND overlapping),
//!    * TMR (real)  adds the in-memory voting stage: each voted bit
//!      passes Min3 + NOT, each fallible, so a bit flips with
//!      `2 p (1 - p)` and the product fails with
//!      `v(p) = 1 - (1 - 2p(1-p))^bits` — this term is what overtakes
//!      the quadratic near p = 1e-9 in the paper.
//! 3. Direct MC validation at simulatable rates (>= ~1e-5) checks the
//!    model before it is trusted below them.

use crate::arith::multiplier::{multpim_program, MultLayout};
use crate::isa::program::Program;
use crate::util::rng::Pcg64;
use crate::util::stats::{one_minus_pow, wilson_interval};

use super::lane::{FaultPlan, LaneSim};

/// Measured masking constants + model evaluation for one multiplier.
#[derive(Clone, Debug)]
pub struct MultReliability {
    pub n_bits: u32,
    pub gates: usize,
    pub alpha: f64,
    pub gamma: f64,
    prog: Program,
    layout: MultLayout,
}

/// One row of the Fig. 4 data series.
#[derive(Clone, Copy, Debug)]
pub struct Fig4Row {
    pub p_gate: f64,
    pub baseline: f64,
    pub tmr: f64,
    pub tmr_ideal: f64,
}

impl MultReliability {
    /// Build the n-bit multiplier and measure alpha / gamma with
    /// `trials` Monte-Carlo single-fault injections.
    pub fn measure(n_bits: u32, trials: usize, seed: u64) -> Self {
        let (prog, layout) = multpim_program(n_bits);
        let gates = prog.logic_gates_per_lane();
        let mut rng = Pcg64::new(seed, 0);
        let mask = if n_bits == 32 { u64::MAX } else { (1u64 << (2 * n_bits)) - 1 };

        // alpha: single uniform fault.
        let mut wrong = 0usize;
        for _ in 0..trials {
            let a = rng.next_u64() & ((1u64 << n_bits) - 1);
            let b = rng.next_u64() & ((1u64 << n_bits) - 1);
            let idx = rng.below(gates as u64) as usize;
            let mut lane = LaneSim::new(layout.width as usize);
            lane.load(&layout.a_cols, a);
            lane.load(&layout.b_cols, b);
            lane.run(&prog, FaultPlan::Exact(&[idx]));
            if lane.read(&layout.result.cols()) & mask != a.wrapping_mul(b) & mask {
                wrong += 1;
            }
        }
        let alpha = wrong as f64 / trials as f64;

        // gamma: overlap of wrong bits between two independently-faulty
        // wrong copies (conditioned on both being wrong).
        let mut overlap = 0usize;
        let mut both_wrong = 0usize;
        while both_wrong < trials / 4 {
            let a = rng.next_u64() & ((1u64 << n_bits) - 1);
            let b = rng.next_u64() & ((1u64 << n_bits) - 1);
            let truth = a.wrapping_mul(b) & mask;
            let sample = |rng: &mut Pcg64| {
                let idx = rng.below(gates as u64) as usize;
                let mut lane = LaneSim::new(layout.width as usize);
                lane.load(&layout.a_cols, a);
                lane.load(&layout.b_cols, b);
                lane.run(&prog, FaultPlan::Exact(&[idx]));
                lane.read(&layout.result.cols()) & mask
            };
            let r1 = sample(&mut rng);
            let r2 = sample(&mut rng);
            if r1 != truth && r2 != truth {
                both_wrong += 1;
                if (r1 ^ truth) & (r2 ^ truth) != 0 {
                    overlap += 1;
                }
            }
        }
        let gamma = overlap as f64 / both_wrong as f64;

        Self { n_bits, gates, alpha, gamma, prog, layout }
    }

    /// Analytical baseline multiplication failure probability.
    pub fn p_mult(&self, p_gate: f64) -> f64 {
        one_minus_pow(self.alpha * p_gate, self.gates as f64)
    }

    /// Voting-stage failure: 2 fallible gates per output bit.
    pub fn p_vote(&self, p_gate: f64) -> f64 {
        let bits = 2.0 * self.n_bits as f64;
        one_minus_pow(2.0 * p_gate * (1.0 - p_gate), bits)
    }

    /// TMR with ideal (error-free) voting — the dashed line of Fig. 4.
    pub fn p_tmr_ideal(&self, p_gate: f64) -> f64 {
        let q = self.p_mult(p_gate);
        (3.0 * self.gamma * q * q).min(1.0)
    }

    /// TMR with in-memory Minority3 voting.
    pub fn p_tmr(&self, p_gate: f64) -> f64 {
        (self.p_tmr_ideal(p_gate) + self.p_vote(p_gate)).min(1.0)
    }

    /// Generate the Fig. 4 (top) series over a p_gate grid.
    pub fn series(&self, p_grid: &[f64]) -> Vec<Fig4Row> {
        p_grid
            .iter()
            .map(|&p| Fig4Row {
                p_gate: p,
                baseline: self.p_mult(p),
                tmr: self.p_tmr(p),
                tmr_ideal: self.p_tmr_ideal(p),
            })
            .collect()
    }

    /// Direct Monte-Carlo estimate of the baseline p_mult at a
    /// simulatable p_gate (used to validate the model).
    pub fn mc_baseline(&self, p_gate: f64, trials: usize, seed: u64) -> (f64, f64, f64) {
        let mask =
            if self.n_bits == 32 { u64::MAX } else { (1u64 << (2 * self.n_bits)) - 1 };
        let mut rng = Pcg64::new(seed, 1);
        let mut wrong = 0u64;
        for _ in 0..trials {
            let a = rng.next_u64() & ((1u64 << self.n_bits) - 1);
            let b = rng.next_u64() & ((1u64 << self.n_bits) - 1);
            let mut lane = LaneSim::new(self.layout.width as usize);
            lane.load(&self.layout.a_cols, a);
            lane.load(&self.layout.b_cols, b);
            lane.run(&self.prog, FaultPlan::Random { p: p_gate, rng: &mut rng });
            if lane.read(&self.layout.result.cols()) & mask != a.wrapping_mul(b) & mask {
                wrong += 1;
            }
        }
        let (lo, hi) = wilson_interval(wrong, trials as u64, 1.96);
        (wrong as f64 / trials as f64, lo, hi)
    }

    /// Direct Monte-Carlo estimate of TMR (serial, faulty per-bit
    /// voting) at a simulatable p_gate.
    pub fn mc_tmr(&self, p_gate: f64, trials: usize, seed: u64) -> (f64, f64, f64) {
        let mask =
            if self.n_bits == 32 { u64::MAX } else { (1u64 << (2 * self.n_bits)) - 1 };
        let bits = 2 * self.n_bits;
        let mut rng = Pcg64::new(seed, 2);
        let mut wrong = 0u64;
        for _ in 0..trials {
            let a = rng.next_u64() & ((1u64 << self.n_bits) - 1);
            let b = rng.next_u64() & ((1u64 << self.n_bits) - 1);
            let truth = a.wrapping_mul(b) & mask;
            let copy = |rng: &mut Pcg64| {
                let mut lane = LaneSim::new(self.layout.width as usize);
                lane.load(&self.layout.a_cols, a);
                lane.load(&self.layout.b_cols, b);
                lane.run(&self.prog, FaultPlan::Random { p: p_gate, rng });
                lane.read(&self.layout.result.cols()) & mask
            };
            let (r1, r2, r3) = (copy(&mut rng), copy(&mut rng), copy(&mut rng));
            // Per-bit Min3+NOT voting with fallible gates:
            // voted_bit = maj ^ f_min ^ f_not.
            let mut voted = (r1 & r2) | (r1 & r3) | (r2 & r3);
            for bit in 0..bits {
                let f_min = rng.bernoulli(p_gate);
                let f_not = rng.bernoulli(p_gate);
                if f_min != f_not {
                    voted ^= 1u64 << bit;
                }
            }
            if voted & mask != truth {
                wrong += 1;
            }
        }
        let (lo, hi) = wilson_interval(wrong, trials as u64, 1.96);
        (wrong as f64 / trials as f64, lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel8() -> MultReliability {
        MultReliability::measure(8, 400, 0xF16)
    }

    #[test]
    fn alpha_is_a_real_masking_factor() {
        let r = rel8();
        assert!(r.alpha > 0.05 && r.alpha < 0.95, "alpha = {}", r.alpha);
        assert!(r.gamma > 0.0 && r.gamma <= 1.0, "gamma = {}", r.gamma);
    }

    #[test]
    fn model_matches_mc_at_simulatable_p() {
        let r = rel8();
        let p = 3e-4;
        let model = r.p_mult(p);
        let (mc, lo, hi) = r.mc_baseline(p, 3000, 7);
        // Model must sit within ~2x of the MC interval (binomial model vs
        // exact masking correlations).
        assert!(
            model > lo * 0.5 && model < hi * 2.0,
            "model {model} vs mc {mc} [{lo},{hi}]"
        );
    }

    #[test]
    fn tmr_beats_baseline_and_ideal_beats_tmr() {
        let r = rel8();
        for &p in &[1e-8, 1e-7, 1e-6] {
            assert!(r.p_tmr(p) < r.p_mult(p), "p={p}");
            assert!(r.p_tmr_ideal(p) <= r.p_tmr(p), "p={p}");
        }
    }

    #[test]
    fn voting_becomes_bottleneck_at_low_p() {
        // The paper's observation: near p = 1e-9 the non-ideal voting
        // term dominates the quadratic TMR term.
        let r = rel8();
        let p = 1e-9;
        assert!(r.p_vote(p) > r.p_tmr_ideal(p), "voting dominates at {p}");
        // And far above, the quadratic dominates.
        let p = 1e-4;
        assert!(r.p_vote(p) < r.p_tmr_ideal(p).max(1e-12) * 100.0);
    }

    #[test]
    fn series_is_monotone() {
        let r = rel8();
        let grid: Vec<f64> = crate::util::stats::logspace(1e-10, 1e-4, 7);
        let rows = r.series(&grid);
        for w in rows.windows(2) {
            assert!(w[0].baseline <= w[1].baseline + 1e-15);
            assert!(w[0].tmr <= w[1].tmr + 1e-15);
        }
    }
}
