//! ECC latency-overhead analysis (paper §IV: "moderate latency overhead
//! of 26 % on average") and the TMR trade-off table (paper §V).

#[cfg(test)]
use crate::arith::adder::ripple_adder;
#[cfg(test)]
use crate::arith::multiplier::{multpim_program, naive_mult_program};
use crate::ecc::DiagonalEcc;
use crate::isa::program::Program;
use crate::mmpu::functions::{FunctionKind, FunctionSpec};

/// One function's ECC overhead datapoint.
#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub name: String,
    pub base_cycles: u64,
    pub ecc_cycles: u64,
    pub overhead_pct: f64,
}

/// The function suite the overhead average is computed over — a mix of
/// short vector ops (where ECC is proportionally expensive) and long
/// arithmetic (where it amortizes), like the DAC'21 evaluation.
pub fn function_suite() -> Vec<(String, Program)> {
    let mut suite: Vec<(String, Program)> = vec![];
    for kind in [
        FunctionKind::Xor(8),
        FunctionKind::Xor(32),
        FunctionKind::Add(16),
        FunctionKind::Add(32),
        FunctionKind::Mul(8),
        FunctionKind::Mul(16),
        FunctionKind::Mul(32),
    ] {
        let f = FunctionSpec::build(kind);
        suite.push((kind.name(), f.prog));
    }
    // A raw copy (the cheapest possible function, worst-case ratio).
    {
        use crate::arith::{layout::ColAlloc, logic};
        use crate::isa::program::RowProgramBuilder;
        let mut b = RowProgramBuilder::new("copy32");
        let mut alloc = ColAlloc::new(64, 128);
        b.inputs(&(0..32).collect::<Vec<_>>());
        for i in 0..32 {
            logic::copy_bit(&mut b, &mut alloc, i, 32 + i);
        }
        b.outputs(&(32..64).collect::<Vec<_>>());
        suite.push(("copy32".into(), b.finish()));
    }
    suite
}

/// ECC latency overhead for one function under the diagonal code:
/// verify touched blocks before + update output check bits after
/// (the extension runs in parallel; these are the serialization points).
pub fn ecc_overhead(prog: &Program, m: usize) -> OverheadRow {
    // Cost model constants come from the engine itself.
    let ecc = DiagonalEcc::new(m * 4, m * 4, m);
    let base = prog.cycles() as u64;
    let verify = ecc.verify_cost();
    let update = ecc.update_cost(prog.output_cols.len().max(1) as u64);
    let total = verify + update;
    OverheadRow {
        name: prog.name.clone(),
        base_cycles: base,
        ecc_cycles: total,
        overhead_pct: 100.0 * total as f64 / base as f64,
    }
}

/// The suite-average ECC overhead (the paper's "26 % on average").
pub fn suite_overhead(m: usize) -> (Vec<OverheadRow>, f64) {
    let rows: Vec<OverheadRow> =
        function_suite().iter().map(|(_, p)| ecc_overhead(p, m)).collect();
    let avg = rows.iter().map(|r| r.overhead_pct).sum::<f64>() / rows.len() as f64;
    (rows, avg)
}

/// TMR trade-off datapoint (latency/area/throughput vs the unreliable
/// baseline), computed from the synthesized programs' cost model.
#[derive(Clone, Debug)]
pub struct TradeoffRow {
    pub func: String,
    pub mode: &'static str,
    pub latency_x: f64,
    pub area_x: f64,
    pub throughput_x: f64,
}

/// Analytical trade-off rows for a function (the measured-on-crossbar
/// version lives in benches/tab_tmr_tradeoff.rs).
pub fn tmr_tradeoffs(name: &str, prog: &Program) -> Vec<TradeoffRow> {
    let base_cycles = prog.cycles() as f64;
    let base_area = prog.width as f64;
    let o = prog.output_cols.len() as f64;
    let vote_cycles = 4.0 * o; // Min3+NOT (+2 inits) per output bit
    vec![
        TradeoffRow {
            func: name.into(),
            mode: "serial",
            latency_x: (3.0 * base_cycles + vote_cycles) / base_cycles,
            area_x: (base_area + 3.0 * o + 1.0) / base_area,
            throughput_x: base_cycles / (3.0 * base_cycles + vote_cycles),
        },
        TradeoffRow {
            func: name.into(),
            mode: "parallel",
            latency_x: (base_cycles + vote_cycles) / base_cycles,
            area_x: (3.0 * base_area + o + 1.0) / base_area,
            throughput_x: base_cycles / (base_cycles + vote_cycles),
        },
        TradeoffRow {
            func: name.into(),
            mode: "semi-parallel",
            latency_x: 1.0, // voting amortizes per item across the batch
            area_x: 1.0,
            throughput_x: 1.0 / 3.0,
        },
    ]
}

/// The Fig. 2 cycle-cost comparison: parity update cost after an
/// in-column operation, naive horizontal vs diagonal, as n grows.
pub fn fig2_update_costs(ns: &[usize]) -> Vec<(usize, u64, u64)> {
    ns.iter()
        .map(|&n| {
            let horiz = crate::ecc::HorizontalEcc::new(n, n, 8);
            let diag = DiagonalEcc::new(n, n, 16);
            (n, horiz.update_cost_in_col(), diag.update_cost(1))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_average_near_paper_26pct() {
        let (rows, avg) = suite_overhead(16);
        assert!(rows.len() >= 8);
        // The paper reports 26 % on average over its function mix; our
        // suite must land in the same regime (15..40 %).
        assert!((10.0..45.0).contains(&avg), "avg overhead = {avg:.1}%");
        // Long functions amortize: mul32 overhead must be far below the
        // copy32 worst case.
        let get = |n: &str| rows.iter().find(|r| r.name.contains(n)).unwrap().overhead_pct;
        assert!(get("multpim32") < get("copy32") / 3.0);
    }

    #[test]
    fn tradeoffs_match_paper_headline() {
        let (prog, _) = multpim_program(16);
        let rows = tmr_tradeoffs("mul16", &prog);
        let serial = &rows[0];
        assert!((2.9..3.6).contains(&serial.latency_x), "{}", serial.latency_x);
        assert!(serial.area_x < 1.5);
        let par = &rows[1];
        assert!(par.latency_x < 1.3);
        assert!((2.9..3.3).contains(&par.area_x), "{}", par.area_x);
        let semi = &rows[2];
        assert!((semi.throughput_x - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn fig2_gap_grows_linearly() {
        let costs = fig2_update_costs(&[64, 256, 1024]);
        assert_eq!(costs[0].2, costs[2].2, "diagonal is O(1)");
        assert_eq!(costs[2].1, 1024, "horizontal in-column is O(n)");
        assert!(costs[2].1 / costs[2].2 > 200, "gap at n=1024");
    }

    #[test]
    fn naive_vs_multpim_latency_gap() {
        // Sanity for the ablation bench: partitions are what make TMR's
        // "1x latency" claim meaningful.
        let (mp, _) = multpim_program(16);
        let (nv, _) = naive_mult_program(16);
        assert!(nv.cycles() > 4 * mp.cycles());
        let (add, _) = ripple_adder(32);
        assert!(add.cycles() < nv.cycles());
    }
}
