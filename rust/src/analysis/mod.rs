//! Reliability analysis (paper §VI) — Monte-Carlo fault injection on the
//! real micro-code plus the paper's analytical extrapolations. These are
//! the engines behind every Fig. 4 / Fig. 5 / table reproduction in
//! `rust/benches/`.

pub mod fig4;
pub mod lane;
pub mod overhead;

pub use fig4::{Fig4Row, MultReliability};
pub use lane::{FaultPlan, LaneSim};
