//! Reliability analysis (paper §VI) — Monte-Carlo fault injection on the
//! real micro-code plus the paper's analytical extrapolations. These are
//! the engines behind every Fig. 4 / Fig. 5 / table reproduction in
//! `rust/benches/`, and the [`lifetime`] harness that validates the
//! simulated long-run degradation against the closed-form
//! `nn::degradation` model (§Health acceptance gate).

pub mod fig4;
pub mod lane;
pub mod lifetime;
pub mod overhead;

pub use fig4::{Fig4Row, MultReliability};
pub use lane::{FaultPlan, LaneSim};
pub use lifetime::{LifetimeConfig, LifetimePoint, LifetimeReport};
