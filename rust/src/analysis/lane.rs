//! Single-lane program interpreter with fault injection.
//!
//! The paper's §VI-A method: "the original simulator involved requests
//! from the algorithm micro-code to perform stateful gates; we inject
//! soft-errors into these requests and measure the logical masking."
//! This interpreter replays one crossbar row (a single multiplication)
//! through a micro-op program and flips selected gate outputs — orders of
//! magnitude faster than the full-array simulator for Monte-Carlo
//! campaigns, and validated against it in `rust/tests/`.

use crate::isa::microop::Dir;
use crate::isa::program::Program;
use crate::util::rng::Pcg64;

/// Which logic-gate executions to corrupt (indices in flattened
/// program order, counting only logic gates).
pub enum FaultPlan<'a> {
    /// Clean run.
    None,
    /// Flip exactly these logic-gate outputs.
    Exact(&'a [usize]),
    /// Flip each logic-gate output independently with probability p
    /// (geometric skipping; the Fig. 4 direct-error model).
    Random { p: f64, rng: &'a mut Pcg64 },
}

/// One crossbar row as a plain bool vector.
pub struct LaneSim {
    state: Vec<bool>,
}

impl LaneSim {
    pub fn new(width: usize) -> Self {
        Self { state: vec![false; width] }
    }

    pub fn set(&mut self, col: u32, v: bool) {
        self.state[col as usize] = v;
    }

    pub fn get(&self, col: u32) -> bool {
        self.state[col as usize]
    }

    /// Load a little-endian value into the given columns.
    pub fn load(&mut self, cols: &[u32], value: u64) {
        for (k, &c) in cols.iter().enumerate() {
            self.state[c as usize] = (value >> k) & 1 == 1;
        }
    }

    pub fn read(&self, cols: &[u32]) -> u64 {
        cols.iter()
            .enumerate()
            .fold(0u64, |acc, (k, &c)| acc | ((self.state[c as usize] as u64) << k))
    }

    /// Execute the program in this lane; returns the number of logic
    /// gates executed (the soft-error site count G).
    pub fn run(&mut self, prog: &Program, mut faults: FaultPlan) -> usize {
        let mut gate_idx = 0usize;
        // Pre-sample for Random (indices ascending).
        let mut next_fault: Option<usize> = match &mut faults {
            FaultPlan::Random { p, rng } => {
                let g = rng.geometric(*p);
                (g != u64::MAX).then_some(g as usize)
            }
            _ => None,
        };
        let mut exact_pos = 0usize;
        for step in &prog.steps {
            for op in &step.ops {
                debug_assert_eq!(op.dir, Dir::InRow, "lane sim is in-row only");
                let a = self.state[op.a as usize];
                let b = self.state[op.b as usize];
                let c = self.state[op.c as usize];
                let prev = self.state[op.out as usize];
                let mut v = op.gate.eval_bit(a, b, c, prev);
                if op.gate.is_logic() {
                    let flip = match &mut faults {
                        FaultPlan::None => false,
                        FaultPlan::Exact(list) => {
                            let hit = exact_pos < list.len() && list[exact_pos] == gate_idx;
                            if hit {
                                exact_pos += 1;
                            }
                            hit
                        }
                        FaultPlan::Random { p, rng } => {
                            if next_fault == Some(gate_idx) {
                                let g = rng.geometric(*p);
                                next_fault = if g == u64::MAX {
                                    None
                                } else {
                                    Some(gate_idx + 1 + g as usize)
                                };
                                true
                            } else {
                                false
                            }
                        }
                    };
                    if flip {
                        v = !v;
                    }
                    gate_idx += 1;
                }
                self.state[op.out as usize] = v;
            }
        }
        gate_idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::multiplier::multpim_program;
    use crate::testutil::prop::Cases;

    #[test]
    fn clean_lane_matches_crossbar_multiplier() {
        let (prog, lay) = multpim_program(8);
        Cases::new(30).run(|g| {
            let a = g.u64() & 0xFF;
            let b = g.u64() & 0xFF;
            let mut lane = LaneSim::new(lay.width as usize);
            lane.load(&lay.a_cols, a);
            lane.load(&lay.b_cols, b);
            let gates = lane.run(&prog, FaultPlan::None);
            assert_eq!(gates, prog.logic_gates_per_lane());
            assert_eq!(lane.read(&lay.result.cols()), a * b, "{a}*{b}");
        });
    }

    #[test]
    fn exact_fault_changes_some_gate_output() {
        // A fault on the *final* gate writing a result bit must corrupt it.
        let (prog, lay) = multpim_program(4);
        let g = prog.logic_gates_per_lane();
        let mut lane = LaneSim::new(lay.width as usize);
        lane.load(&lay.a_cols, 5);
        lane.load(&lay.b_cols, 7);
        // Find the gate writing the top result bit by brute force: flip
        // each gate until the result changes.
        let mut any_corrupted = false;
        for idx in [g - 1, g - 2, g / 2] {
            let mut lane = LaneSim::new(lay.width as usize);
            lane.load(&lay.a_cols, 5);
            lane.load(&lay.b_cols, 7);
            lane.run(&prog, FaultPlan::Exact(&[idx]));
            if lane.read(&lay.result.cols()) != 35 {
                any_corrupted = true;
            }
        }
        assert!(any_corrupted, "at least one of the probed gates must matter");
    }

    #[test]
    fn random_faults_rate() {
        let (prog, lay) = multpim_program(8);
        let g = prog.logic_gates_per_lane() as f64;
        let p = 0.01;
        let mut rng = Pcg64::new(3, 0);
        let trials = 400;
        let mut wrong = 0;
        for t in 0..trials {
            let mut lane = LaneSim::new(lay.width as usize);
            lane.load(&lay.a_cols, (t * 13) % 256);
            lane.load(&lay.b_cols, (t * 29) % 256);
            lane.run(&prog, FaultPlan::Random { p, rng: &mut rng });
            if lane.read(&lay.result.cols()) != ((t * 13) % 256) * ((t * 29) % 256) {
                wrong += 1;
            }
        }
        // E[faults/run] = G*p ~ 8+; virtually every run has faults and
        // most produce wrong outputs (masking < 1).
        let rate = wrong as f64 / trials as f64;
        assert!(rate > 0.5, "rate {rate}, G*p = {}", g * p);
    }
}
