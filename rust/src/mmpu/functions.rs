//! Function registry: the arithmetic functions the mMPU controller can
//! schedule, each synthesized once and cached (paper §III-B: the
//! controller converts CPU instructions into pre-mapped stateful-logic
//! sequences).

use crate::arith::adder::ripple_adder;
use crate::arith::multiplier::{multpim_program, naive_mult_program};
use crate::arith::{layout::ColAlloc, logic};
use crate::isa::program::{Program, RowProgramBuilder};

/// Number of [`FunctionKind`] families (see [`FunctionKind::index`]) —
/// sizes the per-kind counter arrays in `coordinator::metrics` and
/// their fixed-width wire encoding.
pub const KIND_FAMILIES: usize = 4;

/// A function-level mMPU instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FunctionKind {
    /// n-bit + n-bit -> (n+1)-bit vector addition.
    Add(u32),
    /// n x n -> 2n-bit vector multiplication (partition-parallel MultPIM).
    Mul(u32),
    /// n x n -> 2n-bit serial baseline multiplication.
    MulNaive(u32),
    /// n-bit bitwise XOR.
    Xor(u32),
}

impl FunctionKind {
    pub fn name(&self) -> String {
        match self {
            FunctionKind::Add(n) => format!("add{n}"),
            FunctionKind::Mul(n) => format!("mul{n}"),
            FunctionKind::MulNaive(n) => format!("mul_naive{n}"),
            FunctionKind::Xor(n) => format!("xor{n}"),
        }
    }

    /// Dense family index in `0..KIND_FAMILIES`, ignoring operand
    /// width — the key for per-kind load attribution counters.
    pub fn index(&self) -> usize {
        match self {
            FunctionKind::Add(_) => 0,
            FunctionKind::Mul(_) => 1,
            FunctionKind::MulNaive(_) => 2,
            FunctionKind::Xor(_) => 3,
        }
    }

    /// Family name for the dense [`FunctionKind::index`] (fleet views
    /// label per-kind counter rows with this).
    pub fn family_name(index: usize) -> &'static str {
        ["add", "mul", "mul_naive", "xor"].get(index).copied().unwrap_or("?")
    }

    pub fn operand_bits(&self) -> u32 {
        match self {
            FunctionKind::Add(n)
            | FunctionKind::Mul(n)
            | FunctionKind::MulNaive(n)
            | FunctionKind::Xor(n) => *n,
        }
    }

    /// Golden scalar semantics for in-range operands — what a
    /// fault-free execution returns. Load generators, benches and the
    /// fabric tests check served values against this single oracle
    /// instead of each keeping their own copy of the kind -> operator
    /// mapping.
    pub fn reference(&self, a: u64, b: u64) -> u64 {
        match self {
            FunctionKind::Add(_) => a + b,
            FunctionKind::Mul(_) | FunctionKind::MulNaive(_) => a * b,
            FunctionKind::Xor(_) => a ^ b,
        }
    }
}

/// A synthesized function: program + operand/result column map.
#[derive(Clone, Debug)]
pub struct FunctionSpec {
    pub kind: FunctionKind,
    pub prog: Program,
    /// Columns of operand A bits (little-endian order).
    pub a_cols: Vec<u32>,
    /// Columns of operand B bits.
    pub b_cols: Vec<u32>,
    /// Result width in bits (result columns come from the TMR run, since
    /// voting may retarget them).
    pub out_bits: u32,
}

impl FunctionSpec {
    pub fn build(kind: FunctionKind) -> Self {
        match kind {
            FunctionKind::Add(n) => {
                let (prog, lay) = ripple_adder(n);
                FunctionSpec {
                    kind,
                    prog,
                    a_cols: lay.a.cols(),
                    b_cols: lay.b.cols(),
                    out_bits: n + 1,
                }
            }
            FunctionKind::Mul(n) => {
                let (prog, lay) = multpim_program(n);
                FunctionSpec { kind, prog, a_cols: lay.a_cols, b_cols: lay.b_cols, out_bits: 2 * n }
            }
            FunctionKind::MulNaive(n) => {
                let (prog, lay) = naive_mult_program(n);
                FunctionSpec { kind, prog, a_cols: lay.a_cols, b_cols: lay.b_cols, out_bits: 2 * n }
            }
            FunctionKind::Xor(n) => {
                let mut b = RowProgramBuilder::new(&format!("xor{n}"));
                let a_cols: Vec<u32> = (0..n).collect();
                let b_cols: Vec<u32> = (n..2 * n).collect();
                let out: Vec<u32> = (2 * n..3 * n).collect();
                let mut alloc = ColAlloc::new(3 * n, 3 * n + 8);
                b.inputs(&a_cols);
                b.inputs(&b_cols);
                for i in 0..n as usize {
                    logic::xor2(&mut b, &mut alloc, a_cols[i], b_cols[i], out[i]);
                }
                b.outputs(&out);
                FunctionSpec { kind, prog: b.finish(), a_cols, b_cols, out_bits: n }
            }
        }
    }

    /// Decode the result value from output bit columns read LSB-first.
    pub fn result_mask(&self) -> u64 {
        if self.out_bits >= 64 {
            u64::MAX
        } else {
            (1u64 << self.out_bits) - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_kinds() {
        for kind in [
            FunctionKind::Add(8),
            FunctionKind::Mul(8),
            FunctionKind::MulNaive(8),
            FunctionKind::Xor(8),
        ] {
            let f = FunctionSpec::build(kind);
            assert_eq!(f.a_cols.len(), 8, "{kind:?}");
            assert_eq!(f.b_cols.len(), 8);
            assert!(f.prog.cycles() > 0);
            assert!(!f.prog.output_cols.is_empty());
            assert_eq!(f.prog.output_cols.len() as u32, f.out_bits);
        }
    }

    #[test]
    fn names_and_bits() {
        assert_eq!(FunctionKind::Mul(32).name(), "mul32");
        assert_eq!(FunctionKind::Mul(32).operand_bits(), 32);
        assert_eq!(FunctionSpec::build(FunctionKind::Xor(4)).result_mask(), 0xF);
    }

    #[test]
    fn family_index_is_dense_and_width_independent() {
        let kinds = [
            FunctionKind::Add(8),
            FunctionKind::Mul(8),
            FunctionKind::MulNaive(8),
            FunctionKind::Xor(8),
        ];
        for (i, k) in kinds.iter().enumerate() {
            assert_eq!(k.index(), i);
            assert!(k.index() < KIND_FAMILIES);
        }
        assert_eq!(FunctionKind::Add(4).index(), FunctionKind::Add(32).index());
        assert_eq!(FunctionKind::family_name(FunctionKind::MulNaive(8).index()), "mul_naive");
        assert_eq!(FunctionKind::family_name(99), "?");
    }

    #[test]
    fn reference_oracle() {
        assert_eq!(FunctionKind::Add(8).reference(20, 22), 42);
        assert_eq!(FunctionKind::Mul(8).reference(7, 6), 42);
        assert_eq!(FunctionKind::MulNaive(8).reference(7, 6), 42);
        assert_eq!(FunctionKind::Xor(8).reference(0b1100, 0b1010), 0b0110);
    }
}
