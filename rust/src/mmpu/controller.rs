//! The mMPU controller: crossbar fleet + reliability policy + data
//! marshalling.
//!
//! §Perf: the serving path is plan-compiled and word-parallel end to end.
//! [`Mmpu::exec_vector`] resolves a [`CompiledFunction`] from an internal
//! [`PlanCache`] (the coordinator shares one cache across workers and
//! calls [`Mmpu::exec_vector_compiled`] directly), loads operands with a
//! 64x64 bit-transpose scatter — O(bits) word writes instead of
//! O(items x bits) `write_bit` calls, with write-failure injection
//! aggregated over the same canonical bit order and cycle/switch
//! accounting preserved — executes through `Crossbar::run_plan`, and
//! gathers results with the symmetric word-parallel readback.
//! [`Mmpu::exec_vector_legacy`] keeps the per-bit path as the bit-exact
//! reference (`rust/tests/prop_plan_equivalence.rs`).

use anyhow::{ensure, Result};

use crate::ecc::DiagonalEcc;
use crate::errs::{ErrorModel, Injector};
use crate::tmr::{TmrEngine, TmrMode, TmrRun};
use crate::util::bitmat::{transpose64, BitMatrix};
use crate::xbar::crossbar::Crossbar;

use super::compiled::{CompiledFunction, PlanCache};
use super::functions::{FunctionKind, FunctionSpec};

/// Reliability policy applied to every function execution.
#[derive(Clone, Copy, Debug)]
pub struct ReliabilityPolicy {
    /// Diagonal ECC block size m (None = unprotected storage).
    pub ecc_m: Option<usize>,
    /// TMR strategy for computation.
    pub tmr: TmrMode,
}

impl ReliabilityPolicy {
    pub fn none() -> Self {
        Self { ecc_m: None, tmr: TmrMode::Off }
    }

    pub fn full() -> Self {
        Self { ecc_m: Some(16), tmr: TmrMode::Serial }
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct MmpuConfig {
    pub rows: usize,
    pub cols: usize,
    pub num_crossbars: usize,
    pub policy: ReliabilityPolicy,
    pub errors: ErrorModel,
    pub seed: u64,
}

impl Default for MmpuConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cols: 1024,
            num_crossbars: 4,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 0xACE1,
        }
    }
}

/// One crossbar with its private error stream and ECC extension.
struct XbarUnit {
    xbar: Crossbar,
    inj: Injector,
    ecc: Option<DiagonalEcc>,
}

/// Result of a vectored function execution.
#[derive(Clone, Debug)]
pub struct VectorResult {
    pub values: Vec<u64>,
    /// Compute cycles (stateful logic, incl. TMR voting).
    pub compute_cycles: u64,
    /// ECC extension cycles added on the critical path
    /// (verify-before + update-after).
    pub ecc_cycles: u64,
    /// Errors the ECC pre-verification corrected in the input region.
    pub ecc_corrected: u64,
}

/// Row/replica layout of a vectored execution (shared by the word and
/// per-bit marshalling paths so both consume the injector identically).
struct BatchLayout {
    items: usize,
    replicas: usize,
    item_stride: usize,
    n: usize,
    /// Column bases of the extra parallel-TMR input copies.
    parallel_bases: Vec<u32>,
}

impl BatchLayout {
    fn resolve(tmr: TmrMode, rows: usize, n_items: usize, func: &FunctionSpec) -> Result<Self> {
        let (items, replicas) = match tmr {
            TmrMode::SemiParallel => {
                let k = (rows - 1) / 3;
                ensure!(n_items <= k, "too many items for semi-parallel TMR ({k} max)");
                (n_items, 3usize)
            }
            _ => {
                ensure!(n_items <= rows, "too many items ({rows} rows)");
                (n_items, 1usize)
            }
        };
        let item_stride = if replicas == 3 { (rows - 1) / 3 } else { 0 };
        let parallel_bases: Vec<u32> = if tmr == TmrMode::Parallel {
            TmrEngine::parallel_copy_bases(&func.prog)[1..].to_vec()
        } else {
            vec![]
        };
        let n = func.kind.operand_bits() as usize;
        Ok(Self { items, replicas, item_stride, n, parallel_bases })
    }

    /// Total operand bits written = injector write-failure sites, in the
    /// canonical (legacy) order: items-major over the primary replicas
    /// (`a` bits then `b` bits per copy), then the parallel extras.
    fn total_bits(&self) -> usize {
        (self.replicas + self.parallel_bases.len()) * self.items * 2 * self.n
    }

    /// Decompose a canonical flat bit index into
    /// `(copy index, item, operand 0=a/1=b, bit)`.
    fn decode(&self, idx: usize) -> (usize, usize, usize, usize) {
        let n = self.n;
        let primary = self.items * self.replicas * 2 * n;
        if idx < primary {
            let bit = idx % n;
            let rest = idx / n;
            let which = rest % 2;
            let rest = rest / 2;
            let rep = rest % self.replicas;
            let item = rest / self.replicas;
            (rep, item, which, bit)
        } else {
            let idx = idx - primary;
            let bit = idx % n;
            let rest = idx / n;
            let which = rest % 2;
            let rest = rest / 2;
            let item = rest % self.items;
            let base_idx = rest / self.items;
            (self.replicas + base_idx, item, which, bit)
        }
    }

    /// `(row_start, column base)` of each input copy, primary replicas
    /// first, then the parallel extras.
    fn copies(&self) -> Vec<(usize, u32)> {
        let mut out: Vec<(usize, u32)> =
            (0..self.replicas).map(|rep| (rep * self.item_stride, 0u32)).collect();
        out.extend(self.parallel_bases.iter().map(|&b| (0usize, b)));
        out
    }
}

/// The memristive Memory Processing Unit.
pub struct Mmpu {
    cfg: MmpuConfig,
    units: Vec<XbarUnit>,
    plans: PlanCache,
}

impl Mmpu {
    pub fn new(cfg: MmpuConfig) -> Self {
        let mut root = Injector::new(cfg.errors, cfg.seed, 0);
        let units = (0..cfg.num_crossbars)
            .map(|_| XbarUnit {
                xbar: Crossbar::new(cfg.rows, cfg.cols),
                inj: root.split(),
                ecc: cfg.policy.ecc_m.map(|m| DiagonalEcc::new(cfg.rows, cfg.cols, m)),
            })
            .collect();
        Self { cfg, units, plans: PlanCache::new() }
    }

    pub fn config(&self) -> &MmpuConfig {
        &self.cfg
    }

    pub fn num_crossbars(&self) -> usize {
        self.units.len()
    }

    pub fn rows(&self) -> usize {
        self.cfg.rows
    }

    pub fn crossbar(&self, id: usize) -> &Crossbar {
        &self.units[id].xbar
    }

    pub fn crossbar_mut(&mut self, id: usize) -> &mut Crossbar {
        &mut self.units[id].xbar
    }

    pub fn injector_counters(&self, id: usize) -> crate::errs::ErrorCounters {
        self.units[id].inj.counters
    }

    /// Execute a vectored function: element i of `a`/`b` occupies row i
    /// (replicated per the TMR strategy's needs). Returns element
    /// results in order. Compiles (once, cached per kind/shape/mode) and
    /// dispatches to the word-parallel compiled path.
    pub fn exec_vector(
        &mut self,
        xbar_id: usize,
        func: &FunctionSpec,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        let (rows, cols, tmr) = (self.cfg.rows, self.cfg.cols, self.cfg.policy.tmr);
        // The spec clone happens only inside the builder, i.e. on a cache
        // miss — hits stay O(1).
        let cf = self.plans.get_or_compile(func.kind, rows, cols, tmr, || {
            CompiledFunction::from_spec(func.clone(), rows, cols, tmr)
        })?;
        self.exec_vector_compiled(xbar_id, &cf, a, b)
    }

    /// Execute a pre-compiled function (the coordinator's hot path: the
    /// `CompiledFunction` comes from a cache shared across workers).
    pub fn exec_vector_compiled(
        &mut self,
        xbar_id: usize,
        cf: &CompiledFunction,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        ensure!(xbar_id < self.units.len(), "bad crossbar id");
        ensure!(
            cf.rows() == self.cfg.rows && cf.cols() == self.cfg.cols,
            "function compiled for {}x{}, mMPU is {}x{}",
            cf.rows(),
            cf.cols(),
            self.cfg.rows,
            self.cfg.cols
        );
        ensure!(
            cf.mode() == self.cfg.policy.tmr,
            "function compiled for {:?}, policy is {:?}",
            cf.mode(),
            self.cfg.policy.tmr
        );
        let unit = &mut self.units[xbar_id];
        let layout = BatchLayout::resolve(self.cfg.policy.tmr, unit.xbar.rows(), a.len(), &cf.spec)?;

        // --- load operands: word-parallel bit-transpose scatter --------
        // Write failures are sampled in ONE aggregate pass over the
        // canonical bit order (identical to the per-bit path), applied to
        // the staged values, then scattered with whole-word writes.
        let mut flips: Vec<usize> = Vec::new();
        unit.inj.write_fails(layout.total_bits(), |i| flips.push(i));
        let copies = layout.copies();
        let mut staged: Vec<(Vec<u64>, Vec<u64>)> =
            copies.iter().map(|_| (a.to_vec(), b.to_vec())).collect();
        for &f in &flips {
            let (copy, item, which, bit) = layout.decode(f);
            let vals = if which == 0 { &mut staged[copy].0 } else { &mut staged[copy].1 };
            vals[item] ^= 1u64 << bit;
        }
        let mut switched = 0u64;
        for ((row_start, col_base), (av, bv)) in copies.iter().zip(&staged) {
            switched += scatter_operand(
                unit.xbar.state_mut(),
                &cf.spec.a_cols,
                *col_base,
                *row_start,
                av,
                layout.n,
            );
            switched += scatter_operand(
                unit.xbar.state_mut(),
                &cf.spec.b_cols,
                *col_base,
                *row_start,
                bv,
                layout.n,
            );
        }
        // Cycle accounting preserved: one memory-write cycle per operand
        // bit, as the per-bit interface charges.
        unit.xbar.stats.switched_bits += switched;
        unit.xbar.stats.cycles += layout.total_bits() as u64;

        // --- ECC + compute + readback ---------------------------------
        let silent = self.cfg.errors.is_silent();
        let (run, ecc_cycles, ecc_corrected) =
            Self::ecc_and_compute(unit, silent, |x, inj| cf.tmr.run(x, inj))?;
        let values = gather_results(unit.xbar.state(), &run.output_cols, layout.items, cf.spec.result_mask())?;
        Ok(VectorResult {
            values,
            compute_cycles: run.cycles,
            ecc_cycles,
            ecc_corrected,
        })
    }

    /// Per-bit reference path: `write_bit` operand loads, uncompiled TMR
    /// execution, per-bit readback. Consumes the injector identically to
    /// the word-parallel path (same aggregate write-failure sampling,
    /// same gate-error stream), so the two are bit-identical under any
    /// seed — property-tested.
    ///
    /// Reproducibility note: both paths sample write failures in ONE
    /// aggregate `write_fails(total_bits)` pass. The pre-§Perf code drew
    /// one geometric sample *per bit* (`write_bit(.., Some(inj))`), so
    /// seeded results with `p_write > 0` differ from v0 recordings (the
    /// failure distribution is unchanged; only the stream positions
    /// moved). Models with `p_write == 0` consume no RNG in either
    /// version and reproduce v0 exactly.
    pub fn exec_vector_legacy(
        &mut self,
        xbar_id: usize,
        func: &FunctionSpec,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        ensure!(xbar_id < self.units.len(), "bad crossbar id");
        let tmr = self.cfg.policy.tmr;
        let unit = &mut self.units[xbar_id];
        let layout = BatchLayout::resolve(tmr, unit.xbar.rows(), a.len(), func)?;

        let mut flips: Vec<usize> = Vec::new();
        unit.inj.write_fails(layout.total_bits(), |i| flips.push(i));
        let flip_set: std::collections::HashSet<usize> = flips.into_iter().collect();
        // Canonical order: items-major over primary replicas, a then b.
        let n = layout.n;
        let mut bit_idx = 0usize;
        let mut write = |xbar: &mut Crossbar, row: usize, cols: &[u32], base: u32, value: u64| {
            for (k, &c) in cols.iter().enumerate().take(n) {
                let mut v = (value >> k) & 1 == 1;
                if flip_set.contains(&bit_idx) {
                    v = !v;
                }
                bit_idx += 1;
                xbar.write_bit(row, (c + base) as usize, v, None);
            }
        };
        for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
            for rep in 0..layout.replicas {
                let row = i + rep * layout.item_stride;
                write(&mut unit.xbar, row, &func.a_cols, 0, av);
                write(&mut unit.xbar, row, &func.b_cols, 0, bv);
            }
        }
        for &base in &layout.parallel_bases {
            for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
                write(&mut unit.xbar, i, &func.a_cols, base, av);
                write(&mut unit.xbar, i, &func.b_cols, base, bv);
            }
        }

        let silent = self.cfg.errors.is_silent();
        let engine = TmrEngine::new(tmr);
        let prog = func.prog.clone();
        let (run, ecc_cycles, ecc_corrected) =
            Self::ecc_and_compute(unit, silent, move |x, inj| engine.execute(x, &prog, inj))?;
        let mask = func.result_mask();
        let values = (0..layout.items)
            .map(|i| {
                run.output_cols.iter().enumerate().fold(0u64, |acc, (k, &c)| {
                    acc | ((unit.xbar.get(i, c as usize) as u64) << k)
                }) & mask
            })
            .collect();
        Ok(VectorResult {
            values,
            compute_cycles: run.cycles,
            ecc_cycles,
            ecc_corrected,
        })
    }

    /// Shared middle phase: ECC verify-before, TMR compute, ECC
    /// update-after — identical for the word and per-bit paths.
    fn ecc_and_compute(
        unit: &mut XbarUnit,
        silent: bool,
        compute: impl FnOnce(&mut Crossbar, Option<&mut Injector>) -> Result<TmrRun>,
    ) -> Result<(TmrRun, u64, u64)> {
        // --- ECC: encode freshly-written inputs, verify before compute -
        let mut ecc_cycles = 0;
        let mut ecc_corrected = 0;
        if let Some(ecc) = unit.ecc.as_mut() {
            ecc.encode(unit.xbar.state());
            let v0 = ecc.stats.verify_cycles + ecc.stats.update_cycles;
            let outcome = ecc.correct(unit.xbar.state_mut());
            ecc_corrected += outcome.corrected_bits.len() as u64;
            ecc_cycles += ecc.stats.verify_cycles + ecc.stats.update_cycles - v0;
        }

        // --- compute under TMR ---------------------------------------
        let inj = if silent { None } else { Some(&mut unit.inj) };
        let run = compute(&mut unit.xbar, inj)?;

        // --- ECC: update check bits for the produced outputs ----------
        if let Some(ecc) = unit.ecc.as_mut() {
            for &c in &run.output_cols {
                let col = unit.xbar.state().col_bitvec(c as usize);
                // parity' = parity ^ old ^ new; the controller models the
                // old column as it was before compute — the engine tracks
                // only cycle cost here, then re-syncs the block parities.
                ecc.note_col_write(c as usize, &col, &col);
            }
            // Re-sync (outputs & intermediates changed during compute).
            ecc.encode(unit.xbar.state());
            ecc_cycles += ecc.update_cost(run.output_cols.len() as u64);
        }
        Ok((run, ecc_cycles, ecc_corrected))
    }

    /// Periodic ECC scrub of a crossbar (correct accumulated indirect
    /// errors). Returns corrected data-bit count.
    pub fn scrub(&mut self, xbar_id: usize) -> Result<u64> {
        let unit = &mut self.units[xbar_id];
        match unit.ecc.as_mut() {
            Some(ecc) => {
                let out = ecc.correct(unit.xbar.state_mut());
                Ok(out.corrected_bits.len() as u64)
            }
            None => Ok(0),
        }
    }

    /// Expose accumulated crossbar stats (cycles, energy, ...).
    pub fn stats(&self, xbar_id: usize) -> crate::xbar::crossbar::XbarStats {
        self.units[xbar_id].xbar.stats
    }

    /// Age the stored data by `dt` seconds (retention + abrupt events) —
    /// drives the Fig. 5 style degradation experiments.
    pub fn age(&mut self, xbar_id: usize, dt: f64) {
        let unit = &mut self.units[xbar_id];
        let rows = unit.xbar.rows();
        let cols = unit.xbar.cols();
        let bits = rows * cols;
        let state = unit.xbar.state_mut();
        unit.inj.retention(bits, dt, |i| state.flip(i / cols, i % cols));
        unit.inj.abrupt(bits, dt, |i| state.flip(i / cols, i % cols));
    }
}

/// Scatter one operand's values into its bit-plane columns: per 64-item
/// block, a 64x64 bit transpose turns item-major values into bit-plane
/// words, each stored with a single word splice. Returns switched bits.
fn scatter_operand(
    state: &mut BitMatrix,
    cols: &[u32],
    col_base: u32,
    row_start: usize,
    vals: &[u64],
    n: usize,
) -> u64 {
    let mut switched = 0u64;
    let n = n.min(cols.len());
    let mut block = 0usize;
    while block * 64 < vals.len() {
        let len = (vals.len() - block * 64).min(64);
        let mut tile = [0u64; 64];
        tile[..len].copy_from_slice(&vals[block * 64..block * 64 + len]);
        transpose64(&mut tile);
        for (k, &col) in cols.iter().enumerate().take(n) {
            switched += state.splice_col_word(
                (col + col_base) as usize,
                row_start + block * 64,
                len,
                tile[k],
            ) as u64;
        }
        block += 1;
    }
    switched
}

/// Word-parallel result readback: gather each output bit-plane word,
/// transpose back to item-major values.
fn gather_results(
    state: &BitMatrix,
    output_cols: &[u32],
    items: usize,
    mask: u64,
) -> Result<Vec<u64>> {
    ensure!(output_cols.len() <= 64, "result wider than 64 bits");
    let mut values = Vec::with_capacity(items);
    let mut block = 0usize;
    while block * 64 < items {
        let len = (items - block * 64).min(64);
        let mut tile = [0u64; 64];
        for (k, &c) in output_cols.iter().enumerate() {
            tile[k] = state.gather_col_word(c as usize, block * 64, len);
        }
        transpose64(&mut tile);
        for row in tile.iter().take(len) {
            values.push(row & mask);
        }
        block += 1;
    }
    Ok(values)
}

/// Convenience: build a spec and run it on crossbar 0 of a fresh
/// single-purpose mMPU (used by examples/tests).
pub fn quick_exec(
    kind: FunctionKind,
    policy: ReliabilityPolicy,
    errors: ErrorModel,
    seed: u64,
    a: &[u64],
    b: &[u64],
) -> Result<VectorResult> {
    let func = FunctionSpec::build(kind);
    let need = match policy.tmr {
        TmrMode::Serial => TmrEngine::serial_layout(&func.prog).width,
        TmrMode::Parallel => 3 * func.prog.width + func.out_bits + 2,
        _ => func.prog.width,
    };
    let mut cols = need.next_power_of_two().max(64) as usize;
    if let Some(m) = policy.ecc_m {
        cols = cols.div_ceil(m) * m;
    }
    let mut rows = a.len().max(4);
    if policy.tmr == TmrMode::SemiParallel {
        rows = 3 * a.len() + 1;
    }
    if let Some(m) = policy.ecc_m {
        rows = rows.div_ceil(m) * m;
    }
    let cfg = MmpuConfig {
        rows,
        cols,
        num_crossbars: 1,
        policy,
        errors,
        seed,
    };
    let mut mmpu = Mmpu::new(cfg);
    mmpu.exec_vector(0, &func, a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_add_clean() {
        let a: Vec<u64> = (0..32).map(|i| i * 31 % 256).collect();
        let b: Vec<u64> = (0..32).map(|i| i * 17 % 256).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy::none(),
            ErrorModel::none(),
            1,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..32 {
            assert_eq!(r.values[i], a[i] + b[i], "{i}");
        }
        assert_eq!(r.ecc_cycles, 0);
    }

    #[test]
    fn vector_mul_clean_all_policies() {
        let a: Vec<u64> = (0..16).map(|i| i * 131 % 65536).collect();
        let b: Vec<u64> = (0..16).map(|i| i * 77 % 65536).collect();
        for tmr in [TmrMode::Off, TmrMode::Serial] {
            let r = quick_exec(
                FunctionKind::Mul(16),
                ReliabilityPolicy { ecc_m: None, tmr },
                ErrorModel::none(),
                2,
                &a,
                &b,
            )
            .unwrap();
            for i in 0..16 {
                assert_eq!(r.values[i], a[i] * b[i], "{tmr:?} {i}");
            }
        }
    }

    #[test]
    fn vector_xor_with_ecc() {
        let a: Vec<u64> = (0..16).collect();
        let b: Vec<u64> = (16..32).collect();
        let r = quick_exec(
            FunctionKind::Xor(8),
            ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Off },
            ErrorModel::none(),
            3,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..16 {
            assert_eq!(r.values[i], a[i] ^ b[i]);
        }
        assert!(r.ecc_cycles > 0, "ECC path must account extension cycles");
    }

    #[test]
    fn semi_parallel_policy_roundtrip() {
        let a: Vec<u64> = (0..10).map(|i| i * 3).collect();
        let b: Vec<u64> = (0..10).map(|i| i * 5).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy { ecc_m: None, tmr: TmrMode::SemiParallel },
            ErrorModel::none(),
            4,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(r.values[i], a[i] + b[i]);
        }
    }

    #[test]
    fn word_marshalling_matches_legacy_reference() {
        // Same config + same seed: the word-parallel path and the
        // per-bit reference must agree on values, cycle accounting and
        // injector consumption — including under write failures.
        let a: Vec<u64> = (0..48).map(|i| i * 37 % 256).collect();
        let b: Vec<u64> = (0..48).map(|i| i * 91 % 256).collect();
        let errors = ErrorModel { p_write: 5e-3, ..ErrorModel::direct_only(1e-3) };
        let cfg = MmpuConfig {
            rows: 64,
            cols: 512,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors,
            seed: 41,
        };
        let func = FunctionSpec::build(FunctionKind::Mul(8));
        let mut fast = Mmpu::new(cfg.clone());
        let rf = fast.exec_vector(0, &func, &a, &b).unwrap();
        let mut slow = Mmpu::new(cfg);
        let rs = slow.exec_vector_legacy(0, &func, &a, &b).unwrap();
        assert_eq!(rf.values, rs.values);
        assert_eq!(rf.compute_cycles, rs.compute_cycles);
        assert_eq!(fast.stats(0), slow.stats(0));
        assert_eq!(fast.injector_counters(0), slow.injector_counters(0));
        assert_eq!(fast.crossbar(0).state(), slow.crossbar(0).state());
    }

    #[test]
    fn aging_corrupts_and_scrub_repairs() {
        let cfg = MmpuConfig {
            rows: 32,
            cols: 32,
            num_crossbars: 1,
            policy: ReliabilityPolicy { ecc_m: Some(8), tmr: TmrMode::Off },
            errors: ErrorModel { lambda_retention: 2e-5, ..ErrorModel::none() },
            seed: 5,
        };
        let mut mmpu = Mmpu::new(cfg);
        // Write a known pattern, encode.
        for r in 0..32 {
            for c in 0..32 {
                let v = (r * c) % 3 == 0;
                mmpu.crossbar_mut(0).state_mut().set(r, c, v);
            }
        }
        let snapshot = mmpu.crossbar(0).state().clone();
        // (encode happens inside exec; here drive the ECC directly)
        mmpu.units[0].ecc.as_mut().unwrap().encode(&snapshot);
        mmpu.age(0, 1000.0); // expect ~ 32*32*2e-2 ~ 20 flips? (2e-5*1000=2e-2/bit)
        let flips = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(flips > 0, "aging must corrupt some bits");
        let corrected = mmpu.scrub(0).unwrap();
        assert!(corrected > 0);
        // Every block with exactly one flip is now clean; with ~20 flips
        // over 16 blocks some blocks may be uncorrectable — just require
        // that scrubbing reduced the damage.
        let remaining = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(remaining < flips, "scrub must repair: {remaining} vs {flips}");
    }

    #[test]
    fn injected_gate_errors_reach_results() {
        let a: Vec<u64> = vec![7; 64];
        let b: Vec<u64> = vec![9; 64];
        let r = quick_exec(
            FunctionKind::Mul(8),
            ReliabilityPolicy::none(),
            ErrorModel::direct_only(1e-3),
            6,
            &a,
            &b,
        )
        .unwrap();
        let wrong = r.values.iter().filter(|&&v| v != 63).count();
        assert!(wrong > 0, "p_gate=1e-3 over ~800 gates must corrupt something");
    }

    #[test]
    fn batch_layout_decode_roundtrip() {
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let layout = BatchLayout::resolve(TmrMode::SemiParallel, 64, 15, &func).unwrap();
        assert_eq!(layout.replicas, 3);
        assert_eq!(layout.item_stride, 21);
        // Every canonical index decodes to in-range coordinates, and the
        // encoding is a bijection.
        let mut seen = std::collections::HashSet::new();
        for idx in 0..layout.total_bits() {
            let (copy, item, which, bit) = layout.decode(idx);
            assert!(copy < 3 && item < 15 && which < 2 && bit < 8, "idx {idx}");
            assert!(seen.insert((copy, item, which, bit)), "idx {idx} duplicates");
        }
    }
}
