//! The mMPU controller: crossbar fleet + reliability policy + data
//! marshalling.
//!
//! §Perf: the serving path is plan-compiled and word-parallel end to end.
//! [`Mmpu::exec_vector`] resolves a [`CompiledFunction`] from an internal
//! [`PlanCache`] (the coordinator shares one cache across workers and
//! calls [`Mmpu::exec_vector_compiled`] directly), loads operands with a
//! 64x64 bit-transpose scatter — O(bits) word writes instead of
//! O(items x bits) `write_bit` calls, with write-failure injection
//! aggregated over the same canonical bit order and cycle/switch
//! accounting preserved — executes through `Crossbar::run_plan`, and
//! gathers results with the symmetric word-parallel readback.
//! [`Mmpu::exec_vector_legacy`] keeps the per-bit path as the bit-exact
//! reference (`rust/tests/prop_plan_equivalence.rs`).
//!
//! All six `ErrorModel` classes fire on the serving path: `p_gate`,
//! `p_input` during compute, `p_write` on operand marshalling,
//! `p_proximity` as write disturb around the marshalled cells, and
//! `lambda_retention` / `lambda_abrupt` over the batch's wall-clock time
//! (crossbar cycles x the device cycle time). Both marshalling paths
//! consume the injector identically.
//!
//! §Health: each crossbar optionally carries a
//! [`crate::health::CrossbarHealth`] manager ([`Mmpu::enable_health`]).
//! On the serving path the manager translates remapped logical rows to
//! their spares during scatter/readback, clamps stuck cells after every
//! write phase, and advances endurance wear from `switched_bits`;
//! between batches the owner drives [`Mmpu::health_scrub`] and
//! [`Mmpu::set_policy`] (adaptive escalation).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::ecc::DiagonalEcc;
use crate::errs::{ErrorModel, Injector};
use crate::health::{CrossbarHealth, HealthConfig, ScrubReport};
use crate::isa::plan::{CompiledPlan, ScheduleConfig};
use crate::tmr::{TmrEngine, TmrMode, TmrRun};
use crate::util::bitmat::{transpose64, BitMatrix};
use crate::xbar::crossbar::Crossbar;

use super::compiled::{CompiledFunction, PlanCache};
use super::functions::{FunctionKind, FunctionSpec};

/// Reliability policy applied to every function execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReliabilityPolicy {
    /// Diagonal ECC block size m (None = unprotected storage).
    pub ecc_m: Option<usize>,
    /// TMR strategy for computation.
    pub tmr: TmrMode,
}

impl ReliabilityPolicy {
    pub fn none() -> Self {
        Self { ecc_m: None, tmr: TmrMode::Off }
    }

    pub fn full() -> Self {
        Self { ecc_m: Some(16), tmr: TmrMode::Serial }
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct MmpuConfig {
    pub rows: usize,
    pub cols: usize,
    pub num_crossbars: usize,
    pub policy: ReliabilityPolicy,
    pub errors: ErrorModel,
    pub seed: u64,
    /// §Perf: list-scheduling configuration threaded into every plan
    /// compilation (`off` = the serial program-order reference).
    pub schedule: ScheduleConfig,
}

impl Default for MmpuConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cols: 1024,
            num_crossbars: 4,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 0xACE1,
            schedule: ScheduleConfig::off(),
        }
    }
}

/// One crossbar with its private error stream, ECC extension and
/// (optional) online health manager.
struct XbarUnit {
    xbar: Crossbar,
    inj: Injector,
    ecc: Option<DiagonalEcc>,
    health: Option<CrossbarHealth>,
    /// §Health + SemiParallel: per-function vote plans re-addressed
    /// through this crossbar's spare-row remap, recompiled only when
    /// the remap state changes (remap events are rare; remapped state
    /// is permanent, so the batch path must stay compiled).
    semi_votes: HashMap<FunctionKind, (Vec<(u32, u32)>, Arc<CompiledPlan>)>,
}

/// Result of a vectored function execution.
#[derive(Clone, Debug)]
pub struct VectorResult {
    pub values: Vec<u64>,
    /// Compute cycles (stateful logic, incl. TMR voting).
    pub compute_cycles: u64,
    /// ECC extension cycles added on the critical path
    /// (verify-before + update-after).
    pub ecc_cycles: u64,
    /// Bits the ECC verify-before pass corrected (drift accumulated
    /// since the previous batch's parity re-sync).
    pub ecc_corrected: u64,
    /// §Telemetry: host wall-clock ns spent in the ECC extension
    /// (verify-before + parity update-after) during this execution.
    /// Simulator time, not modeled device time — `ecc_cycles` is the
    /// modeled cost; these ns feed the request-path stage spans.
    pub ecc_ns: u64,
    /// §Telemetry: host wall-clock ns of the (possibly TMR-replicated)
    /// in-crossbar compute phase.
    pub compute_ns: u64,
    /// §Telemetry: host wall-clock ns of result gather + remapped-row
    /// readback overrides.
    pub readback_ns: u64,
}

/// Row/replica layout of a vectored execution (shared by the word and
/// per-bit marshalling paths so both consume the injector identically).
struct BatchLayout {
    items: usize,
    replicas: usize,
    item_stride: usize,
    n: usize,
    /// Column bases of the extra parallel-TMR input copies.
    parallel_bases: Vec<u32>,
}

impl BatchLayout {
    fn resolve(tmr: TmrMode, rows: usize, n_items: usize, func: &FunctionSpec) -> Result<Self> {
        let (items, replicas) = match tmr {
            TmrMode::SemiParallel => {
                let k = (rows - 1) / 3;
                ensure!(n_items <= k, "too many items for semi-parallel TMR ({k} max)");
                (n_items, 3usize)
            }
            _ => {
                ensure!(n_items <= rows, "too many items ({rows} rows)");
                (n_items, 1usize)
            }
        };
        let item_stride = if replicas == 3 { (rows - 1) / 3 } else { 0 };
        let parallel_bases: Vec<u32> = if tmr == TmrMode::Parallel {
            TmrEngine::parallel_copy_bases(&func.prog)[1..].to_vec()
        } else {
            vec![]
        };
        let n = func.kind.operand_bits() as usize;
        Ok(Self { items, replicas, item_stride, n, parallel_bases })
    }

    /// Total operand bits written = injector write-failure sites, in the
    /// canonical (legacy) order: items-major over the primary replicas
    /// (`a` bits then `b` bits per copy), then the parallel extras.
    fn total_bits(&self) -> usize {
        (self.replicas + self.parallel_bases.len()) * self.items * 2 * self.n
    }

    /// Decompose a canonical flat bit index into
    /// `(copy index, item, operand 0=a/1=b, bit)`.
    fn decode(&self, idx: usize) -> (usize, usize, usize, usize) {
        let n = self.n;
        let primary = self.items * self.replicas * 2 * n;
        if idx < primary {
            let bit = idx % n;
            let rest = idx / n;
            let which = rest % 2;
            let rest = rest / 2;
            let rep = rest % self.replicas;
            let item = rest / self.replicas;
            (rep, item, which, bit)
        } else {
            let idx = idx - primary;
            let bit = idx % n;
            let rest = idx / n;
            let which = rest % 2;
            let rest = rest / 2;
            let item = rest % self.items;
            let base_idx = rest / self.items;
            (self.replicas + base_idx, item, which, bit)
        }
    }

    /// `(row_start, column base)` of each input copy, primary replicas
    /// first, then the parallel extras.
    fn copies(&self) -> Vec<(usize, u32)> {
        let mut out: Vec<(usize, u32)> =
            (0..self.replicas).map(|rep| (rep * self.item_stride, 0u32)).collect();
        out.extend(self.parallel_bases.iter().map(|&b| (0usize, b)));
        out
    }

    /// Physical `(row, col)` of canonical operand bit `idx`, resolved
    /// against the copy layout from [`BatchLayout::copies`] (the same
    /// table the scatter path walks, so the two can never diverge).
    fn site(&self, idx: usize, copies: &[(usize, u32)], func: &FunctionSpec) -> (usize, usize) {
        let (copy, item, which, bit) = self.decode(idx);
        let (row_start, col_base) = copies[copy];
        let cols = if which == 0 { &func.a_cols } else { &func.b_cols };
        (row_start + item, (cols[bit] + col_base) as usize)
    }
}

/// Proximity disturb around the marshalled operand cells: each written
/// bit may disturb its two horizontally adjacent cells (paper §II-B2).
/// Consumed identically by the word and per-bit marshalling paths.
/// `remap` translates logical rows whose writes were redirected to
/// spare rows (§Health), so disturbs land where the writes physically
/// did; the injector stream itself is remap-independent.
fn apply_proximity(
    inj: &mut Injector,
    layout: &BatchLayout,
    func: &FunctionSpec,
    remap: &[(u32, u32)],
    state: &mut BitMatrix,
) {
    if inj.model.p_proximity <= 0.0 {
        return;
    }
    let copies = layout.copies();
    let cols = state.cols();
    let sites = layout.total_bits() * 2;
    inj.proximity(sites, |i| {
        let (r, c) = layout.site(i / 2, &copies, func);
        let r = remap
            .iter()
            .find(|&&(l, _)| l as usize == r)
            .map_or(r, |&(_, p)| p as usize);
        let nc = if i % 2 == 0 { c.wrapping_sub(1) } else { c + 1 };
        if nc < cols {
            state.flip(r, nc);
        }
    });
}

/// The memristive Memory Processing Unit.
pub struct Mmpu {
    cfg: MmpuConfig,
    units: Vec<XbarUnit>,
    plans: PlanCache,
}

impl Mmpu {
    pub fn new(cfg: MmpuConfig) -> Self {
        let mut root = Injector::new(cfg.errors, cfg.seed, 0);
        let units = (0..cfg.num_crossbars)
            .map(|_| XbarUnit {
                xbar: Crossbar::new(cfg.rows, cfg.cols),
                inj: root.split(),
                ecc: cfg.policy.ecc_m.map(|m| DiagonalEcc::new(cfg.rows, cfg.cols, m)),
                health: None,
                semi_votes: HashMap::new(),
            })
            .collect();
        Self { cfg, units, plans: PlanCache::new() }
    }

    pub fn config(&self) -> &MmpuConfig {
        &self.cfg
    }

    pub fn num_crossbars(&self) -> usize {
        self.units.len()
    }

    pub fn rows(&self) -> usize {
        self.cfg.rows
    }

    pub fn crossbar(&self, id: usize) -> &Crossbar {
        &self.units[id].xbar
    }

    pub fn crossbar_mut(&mut self, id: usize) -> &mut Crossbar {
        &mut self.units[id].xbar
    }

    pub fn injector_counters(&self, id: usize) -> crate::errs::ErrorCounters {
        self.units[id].inj.counters
    }

    /// Execute a vectored function: element i of `a`/`b` occupies row i
    /// (replicated per the TMR strategy's needs). Returns element
    /// results in order. Compiles (once, cached per kind/shape/mode) and
    /// dispatches to the word-parallel compiled path.
    pub fn exec_vector(
        &mut self,
        xbar_id: usize,
        func: &FunctionSpec,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        let (rows, cols, tmr) = (self.cfg.rows, self.cfg.cols, self.cfg.policy.tmr);
        let sched = self.cfg.schedule;
        // The spec clone happens only inside the builder, i.e. on a cache
        // miss — hits stay O(1).
        let cf = self.plans.get_or_compile(func.kind, rows, cols, tmr, sched, || {
            CompiledFunction::from_spec(func.clone(), rows, cols, tmr, sched)
        })?;
        self.exec_vector_compiled(xbar_id, &cf, a, b)
    }

    /// Execute a pre-compiled function (the coordinator's hot path: the
    /// `CompiledFunction` comes from a cache shared across workers).
    pub fn exec_vector_compiled(
        &mut self,
        xbar_id: usize,
        cf: &CompiledFunction,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        ensure!(xbar_id < self.units.len(), "bad crossbar id");
        ensure!(
            cf.rows() == self.cfg.rows && cf.cols() == self.cfg.cols,
            "function compiled for {}x{}, mMPU is {}x{}",
            cf.rows(),
            cf.cols(),
            self.cfg.rows,
            self.cfg.cols
        );
        ensure!(
            cf.mode() == self.cfg.policy.tmr,
            "function compiled for {:?}, policy is {:?}",
            cf.mode(),
            self.cfg.policy.tmr
        );
        ensure!(
            cf.schedule() == self.cfg.schedule,
            "function compiled under schedule {:?}, mMPU wants {:?}",
            cf.schedule(),
            self.cfg.schedule
        );
        let tmr = self.cfg.policy.tmr;
        let unit = &mut self.units[xbar_id];
        let c0 = unit.xbar.stats.cycles;
        let layout = BatchLayout::resolve(tmr, unit.xbar.rows(), a.len(), &cf.spec)?;
        // §Health: spare rows are reserved out of the logical row space,
        // and scrub-detected stuck rows are routed through the spare-row
        // remap under every TMR mode. (SemiParallel used to skip the
        // remap and let row-triple voting absorb the stuck copy — that
        // silently consumed the triple's voting margin; now the replica
        // mirrors into its spare and the vote re-addresses it, freeing
        // the margin for transient faults.)
        let remapped: Vec<(u32, u32)> = match unit.health.as_ref() {
            Some(h) if tmr == TmrMode::SemiParallel => {
                // Replica triples {i, i+k, i+2k} must stay inside the
                // data rows so the reserved spares (and the vote scratch
                // row) are never part of a triple.
                let k = layout.item_stride;
                ensure!(
                    layout.items + 2 * k <= h.data_rows(),
                    "semi-parallel batch of {} (stride {k}) exceeds {} health-managed data rows",
                    layout.items,
                    h.data_rows()
                );
                h.remapped_pairs()
            }
            Some(h) => {
                ensure!(
                    layout.items <= h.data_rows(),
                    "batch of {} exceeds {} health-managed data rows",
                    layout.items,
                    h.data_rows()
                );
                h.remapped_pairs()
            }
            None => Vec::new(),
        };

        // --- ECC verify-before: repair drift since the last batch -----
        let t_ecc = Instant::now();
        let (mut ecc_cycles, ecc_corrected) = Self::ecc_verify_before(unit);
        let mut ecc_ns = t_ecc.elapsed().as_nanos() as u64;

        // --- load operands: word-parallel bit-transpose scatter --------
        // Write failures are sampled in ONE aggregate pass over the
        // canonical bit order (identical to the per-bit path), applied to
        // the staged values, then scattered with whole-word writes.
        let mut flips: Vec<usize> = Vec::new();
        unit.inj.write_fails(layout.total_bits(), |i| flips.push(i));
        let copies = layout.copies();
        let mut staged: Vec<(Vec<u64>, Vec<u64>)> =
            copies.iter().map(|_| (a.to_vec(), b.to_vec())).collect();
        for &f in &flips {
            let (copy, item, which, bit) = layout.decode(f);
            let vals = if which == 0 { &mut staged[copy].0 } else { &mut staged[copy].1 };
            vals[item] ^= 1u64 << bit;
        }
        let mut switched = 0u64;
        for ((row_start, col_base), (av, bv)) in copies.iter().zip(&staged) {
            switched += scatter_operand(
                unit.xbar.state_mut(),
                &cf.spec.a_cols,
                *col_base,
                *row_start,
                av,
                layout.n,
            );
            switched += scatter_operand(
                unit.xbar.state_mut(),
                &cf.spec.b_cols,
                *col_base,
                *row_start,
                bv,
                layout.n,
            );
        }
        // Cycle accounting preserved: one memory-write cycle per operand
        // bit, as the per-bit interface charges.
        unit.xbar.stats.switched_bits += switched;
        unit.xbar.stats.cycles += layout.total_bits() as u64;

        // §Health: mirror remapped rows' operand copies into their spare
        // rows (the in-row compute covers every physical lane, spares
        // included, so only operand placement, the semi vote schedule
        // and readback need translation). Each mirror job is
        // (copy index, item, spare row): one row per item for
        // Off/Serial/Parallel (every copy shares the row, at its column
        // base); for SemiParallel, row l backs exactly one replica
        // (copy l/k of item l%k within the occupied ranges), and that
        // copy's flip-adjusted staging is what migrates.
        let mirror_jobs: Vec<(usize, usize, u32)> = remapped
            .iter()
            .flat_map(|&(l, p)| {
                let l = l as usize;
                if layout.replicas == 3 {
                    let k = layout.item_stride;
                    (0..3usize)
                        .filter(|&rep| l >= rep * k && l - rep * k < layout.items)
                        .map(|rep| (rep, l - rep * k, p))
                        .collect::<Vec<_>>()
                } else if l < layout.items {
                    (0..copies.len()).map(|c| (c, l, p)).collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        if !mirror_jobs.is_empty() {
            let mut extra_switched = 0u64;
            let mut extra_bits = 0u64;
            for &(copy, item, p) in &mirror_jobs {
                let (_, col_base) = copies[copy];
                let (av, bv) = &staged[copy];
                for (operand, vals) in [(&cf.spec.a_cols, av), (&cf.spec.b_cols, bv)] {
                    for (k, &col) in operand.iter().enumerate().take(layout.n) {
                        let v = (vals[item] >> k) & 1 == 1;
                        let c = (col + col_base) as usize;
                        if unit.xbar.state().get(p as usize, c) != v {
                            extra_switched += 1;
                        }
                        unit.xbar.state_mut().set(p as usize, c, v);
                        extra_bits += 1;
                    }
                }
            }
            unit.xbar.stats.switched_bits += extra_switched;
            unit.xbar.stats.cycles += extra_bits;
        }

        // Proximity disturb around the written cells (translated through
        // the row remap so disturbs land where the writes physically
        // did); then stuck cells reassert themselves over the load.
        apply_proximity(&mut unit.inj, &layout, &cf.spec, &remapped, unit.xbar.state_mut());
        if let Some(h) = unit.health.as_ref() {
            h.clamp(unit.xbar.state_mut());
        }

        // §Health + SemiParallel: resolve the vote plan re-addressed
        // through this crossbar's remap (so a scrubbed-out row stops
        // consuming one of its triple's votes), recompiling only when
        // the remap state changed since the last batch of this kind.
        let semi_vote: Option<Arc<CompiledPlan>> =
            if tmr == TmrMode::SemiParallel && !remapped.is_empty() {
                let stale = unit
                    .semi_votes
                    .get(&cf.spec.kind)
                    .is_none_or(|(pairs, _)| *pairs != remapped);
                if stale {
                    let plan = Arc::new(cf.tmr.compile_semi_remapped_vote(&remapped)?);
                    unit.semi_votes.insert(cf.spec.kind, (remapped.clone(), plan));
                }
                unit.semi_votes.get(&cf.spec.kind).map(|(_, p)| p.clone())
            } else {
                None
            };

        // --- compute + ECC re-sync + aging + readback -----------------
        let silent = self.cfg.errors.is_silent();
        let (run, post_ecc_cycles, compute_ns, ecc_update_ns) =
            Self::ecc_and_compute(unit, silent, c0, |x, inj| match &semi_vote {
                Some(vote) => cf.tmr.run_semi_with_vote(x, inj, vote),
                None => cf.tmr.run(x, inj),
            })?;
        ecc_cycles += post_ecc_cycles;
        ecc_ns += ecc_update_ns;
        if let Some(h) = unit.health.as_ref() {
            h.clamp(unit.xbar.state_mut());
        }
        let t_readback = Instant::now();
        let mask = cf.spec.result_mask();
        let mut values = gather_results(unit.xbar.state(), &run.output_cols, layout.items, mask)?;
        for &(l, p) in &remapped {
            let li = l as usize;
            if li >= layout.items {
                continue;
            }
            values[li] = run.output_cols.iter().enumerate().fold(0u64, |acc, (k, &c)| {
                acc | ((unit.xbar.get(p as usize, c as usize) as u64) << k)
            }) & mask;
        }
        let readback_ns = t_readback.elapsed().as_nanos() as u64;
        // §Health: endurance wear-out + serving telemetry.
        let switched_total = unit.xbar.stats.switched_bits;
        if let Some(h) = unit.health.as_mut() {
            h.on_batch(switched_total, ecc_corrected);
        }
        Ok(VectorResult {
            values,
            compute_cycles: run.cycles,
            ecc_cycles,
            ecc_corrected,
            ecc_ns,
            compute_ns,
            readback_ns,
        })
    }

    /// Per-bit reference path: `write_bit` operand loads, uncompiled TMR
    /// execution, per-bit readback. Consumes the injector identically to
    /// the word-parallel path (same aggregate write-failure sampling,
    /// same gate-error stream), so the two are bit-identical under any
    /// seed — property-tested.
    ///
    /// Reproducibility note: both paths sample write failures in ONE
    /// aggregate `write_fails(total_bits)` pass. The pre-§Perf code drew
    /// one geometric sample *per bit* (`write_bit(.., Some(inj))`), so
    /// seeded results with `p_write > 0` differ from v0 recordings (the
    /// failure distribution is unchanged; only the stream positions
    /// moved). Models with `p_write == 0` consume no RNG in either
    /// version and reproduce v0 exactly.
    pub fn exec_vector_legacy(
        &mut self,
        xbar_id: usize,
        func: &FunctionSpec,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        ensure!(xbar_id < self.units.len(), "bad crossbar id");
        ensure!(
            self.units[xbar_id].health.is_none(),
            "the health manager requires the compiled path (exec_vector)"
        );
        let tmr = self.cfg.policy.tmr;
        let unit = &mut self.units[xbar_id];
        let c0 = unit.xbar.stats.cycles;
        let layout = BatchLayout::resolve(tmr, unit.xbar.rows(), a.len(), func)?;

        // ECC verify-before (same position in the stream as the word
        // path: before marshalling, consuming no injector draws).
        let t_ecc = Instant::now();
        let (mut ecc_cycles, ecc_corrected) = Self::ecc_verify_before(unit);
        let mut ecc_ns = t_ecc.elapsed().as_nanos() as u64;

        let mut flips: Vec<usize> = Vec::new();
        unit.inj.write_fails(layout.total_bits(), |i| flips.push(i));
        let flip_set: std::collections::HashSet<usize> = flips.into_iter().collect();
        // Canonical order: items-major over primary replicas, a then b.
        let n = layout.n;
        let mut bit_idx = 0usize;
        let mut write = |xbar: &mut Crossbar, row: usize, cols: &[u32], base: u32, value: u64| {
            for (k, &c) in cols.iter().enumerate().take(n) {
                let mut v = (value >> k) & 1 == 1;
                if flip_set.contains(&bit_idx) {
                    v = !v;
                }
                bit_idx += 1;
                xbar.write_bit(row, (c + base) as usize, v, None);
            }
        };
        for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
            for rep in 0..layout.replicas {
                let row = i + rep * layout.item_stride;
                write(&mut unit.xbar, row, &func.a_cols, 0, av);
                write(&mut unit.xbar, row, &func.b_cols, 0, bv);
            }
        }
        for &base in &layout.parallel_bases {
            for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
                write(&mut unit.xbar, i, &func.a_cols, base, av);
                write(&mut unit.xbar, i, &func.b_cols, base, bv);
            }
        }
        apply_proximity(&mut unit.inj, &layout, func, &[], unit.xbar.state_mut());

        let silent = self.cfg.errors.is_silent();
        let engine = TmrEngine::new(tmr);
        let prog = func.prog.clone();
        let (run, post_ecc_cycles, compute_ns, ecc_update_ns) =
            Self::ecc_and_compute(unit, silent, c0, move |x, inj| engine.execute(x, &prog, inj))?;
        ecc_cycles += post_ecc_cycles;
        ecc_ns += ecc_update_ns;
        let t_readback = Instant::now();
        let mask = func.result_mask();
        let values = (0..layout.items)
            .map(|i| {
                run.output_cols.iter().enumerate().fold(0u64, |acc, (k, &c)| {
                    acc | ((unit.xbar.get(i, c as usize) as u64) << k)
                }) & mask
            })
            .collect();
        let readback_ns = t_readback.elapsed().as_nanos() as u64;
        Ok(VectorResult {
            values,
            compute_cycles: run.cycles,
            ecc_cycles,
            ecc_corrected,
            ecc_ns,
            compute_ns,
            readback_ns,
        })
    }

    /// ECC verify-before: detect and repair drift accumulated since the
    /// last batch's parity re-sync. Parities are kept consistent with
    /// the array at every batch end (post-compute re-sync) and at ECC
    /// install time, so no re-encode happens here — encoding first
    /// would absorb the very drift this pass exists to catch, making
    /// serving-path correction (and its telemetry) a permanent no-op.
    /// Returns `(ecc cycles, corrected bits)`.
    fn ecc_verify_before(unit: &mut XbarUnit) -> (u64, u64) {
        match unit.ecc.as_mut() {
            Some(ecc) => {
                let v0 = ecc.stats.verify_cycles + ecc.stats.update_cycles;
                let outcome = ecc.correct(unit.xbar.state_mut());
                let cycles = ecc.stats.verify_cycles + ecc.stats.update_cycles - v0;
                (cycles, outcome.corrected_bits.len() as u64)
            }
            None => (0, 0),
        }
    }

    /// Shared middle phase: TMR compute, ECC update-after (parity
    /// re-sync), then time-domain aging (retention + abrupt events) over
    /// the batch's wall-clock span — identical for the word and per-bit
    /// paths. `start_cycles` is the crossbar cycle count at the start of
    /// the batch (marshalling included in the elapsed time). Returns the
    /// run, the ECC extension cycles of the update phase, and the host
    /// wall-clock split `(compute_ns, ecc_update_ns)` for the telemetry
    /// stage spans (aging stays untimed: it lands in the worker-exec
    /// remainder).
    fn ecc_and_compute(
        unit: &mut XbarUnit,
        silent: bool,
        start_cycles: u64,
        compute: impl FnOnce(&mut Crossbar, Option<&mut Injector>) -> Result<TmrRun>,
    ) -> Result<(TmrRun, u64, u64, u64)> {
        let mut ecc_cycles = 0;

        // --- compute under TMR ---------------------------------------
        let t_compute = Instant::now();
        let inj = if silent { None } else { Some(&mut unit.inj) };
        let run = compute(&mut unit.xbar, inj)?;
        let compute_ns = t_compute.elapsed().as_nanos() as u64;

        // --- ECC: update check bits for the produced outputs ----------
        let t_ecc = Instant::now();
        if let Some(ecc) = unit.ecc.as_mut() {
            for &c in &run.output_cols {
                let col = unit.xbar.state().col_bitvec(c as usize);
                // parity' = parity ^ old ^ new; the controller models the
                // old column as it was before compute — the engine tracks
                // only cycle cost here, then re-syncs the block parities.
                ecc.note_col_write(c as usize, &col, &col);
            }
            // Re-sync (outputs & intermediates changed during compute).
            ecc.encode(unit.xbar.state());
            ecc_cycles += ecc.update_cost(run.output_cols.len() as u64);
        }
        let ecc_update_ns = t_ecc.elapsed().as_nanos() as u64;

        // --- time-domain aging over the batch's wall-clock span -------
        // Retention drift and abrupt events accrue while the batch sits
        // in the array: dt = elapsed cycles x device cycle time. Flips
        // land after the post-compute ECC re-sync, so the next scrub
        // (not this batch's bookkeeping) observes them — and before
        // readback, so long-lived batches can corrupt their own results.
        let cycles = unit.xbar.stats.cycles - start_cycles;
        let dt = cycles as f64 * unit.xbar.device.cycle_ns * 1e-9;
        let cols = unit.xbar.cols();
        let bits = unit.xbar.rows() * cols;
        let state = unit.xbar.state_mut();
        unit.inj.retention(bits, dt, |i| state.flip(i / cols, i % cols));
        unit.inj.abrupt(bits, dt, |i| state.flip(i / cols, i % cols));
        Ok((run, ecc_cycles, compute_ns, ecc_update_ns))
    }

    /// Periodic ECC scrub of a crossbar (correct accumulated indirect
    /// errors). Returns corrected data-bit count.
    pub fn scrub(&mut self, xbar_id: usize) -> Result<u64> {
        let unit = &mut self.units[xbar_id];
        match unit.ecc.as_mut() {
            Some(ecc) => {
                let out = ecc.correct(unit.xbar.state_mut());
                Ok(out.corrected_bits.len() as u64)
            }
            None => Ok(0),
        }
    }

    /// Expose accumulated crossbar stats (cycles, energy, ...).
    pub fn stats(&self, xbar_id: usize) -> crate::xbar::crossbar::XbarStats {
        self.units[xbar_id].xbar.stats
    }

    /// Age the stored data by `dt` seconds (retention + abrupt events) —
    /// drives the Fig. 5 style degradation experiments.
    pub fn age(&mut self, xbar_id: usize, dt: f64) {
        let unit = &mut self.units[xbar_id];
        let rows = unit.xbar.rows();
        let cols = unit.xbar.cols();
        let bits = rows * cols;
        let state = unit.xbar.state_mut();
        unit.inj.retention(bits, dt, |i| state.flip(i / cols, i % cols));
        unit.inj.abrupt(bits, dt, |i| state.flip(i / cols, i % cols));
    }

    /// Install an online health manager on every crossbar (§Health).
    /// Each unit gets an independent fault-sampling stream. Under
    /// SemiParallel TMR the vote scratch row (the last physical row) is
    /// reserved out of the spare pool — the engine overwrites it every
    /// batch, so it must never back remapped data.
    pub fn enable_health(&mut self, cfg: HealthConfig) {
        let (rows, cols) = (self.cfg.rows, self.cfg.cols);
        let semi = self.cfg.policy.tmr == TmrMode::SemiParallel;
        for (i, unit) in self.units.iter_mut().enumerate() {
            let seed = cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut h = CrossbarHealth::new(rows, cols, cfg.clone(), seed);
            if semi {
                h.reserve_spare((rows - 1) as u32);
            }
            unit.health = Some(h);
        }
    }

    pub fn health(&self, xbar_id: usize) -> Option<&CrossbarHealth> {
        self.units[xbar_id].health.as_ref()
    }

    pub fn health_mut(&mut self, xbar_id: usize) -> Option<&mut CrossbarHealth> {
        self.units[xbar_id].health.as_mut()
    }

    /// Whether the crossbar's scrub interval has elapsed.
    pub fn scrub_due(&self, xbar_id: usize) -> bool {
        self.units[xbar_id].health.as_ref().is_some_and(|h| h.scrub_due())
    }

    /// Run one health scrub pass (ECC drift repair + march test +
    /// spare-row remapping) on a crossbar. `None` without a manager.
    pub fn health_scrub(&mut self, xbar_id: usize) -> Option<ScrubReport> {
        let XbarUnit { xbar, ecc, health, .. } = &mut self.units[xbar_id];
        health.as_mut().map(|h| h.scrub(xbar.state_mut(), ecc.as_mut()))
    }

    /// Switch the reliability policy at runtime (adaptive escalation).
    /// Rebuilds the per-crossbar ECC extensions when the ECC setting
    /// changes; compiled functions for the new TMR mode come from the
    /// plan cache on the next execution.
    pub fn set_policy(&mut self, policy: ReliabilityPolicy) -> Result<()> {
        if let Some(m) = policy.ecc_m {
            ensure!(
                m >= 2 && self.cfg.rows % m == 0 && self.cfg.cols % m == 0,
                "ecc m={m} must divide the {}x{} crossbar",
                self.cfg.rows,
                self.cfg.cols
            );
        }
        let old = self.cfg.policy;
        // Switching into SemiParallel at runtime claims the vote
        // scratch row from any health manager's spare pool. If a scrub
        // already remapped data ONTO that row (spares are handed out
        // top-down, so it goes first), the switch is rejected before any
        // state changes — the engine would trample the remapped replica
        // with vote scratch every batch and corrupt results silently.
        if policy.tmr == TmrMode::SemiParallel && old.tmr != TmrMode::SemiParallel {
            let scratch = (self.cfg.rows - 1) as u32;
            for (i, unit) in self.units.iter().enumerate() {
                if let Some(h) = unit.health.as_ref() {
                    ensure!(
                        h.remapped_pairs().iter().all(|&(_, p)| p != scratch),
                        "cannot switch crossbar {i} to semi-parallel TMR: vote scratch row \
                         {scratch} already backs remapped data"
                    );
                }
            }
            for unit in &mut self.units {
                if let Some(h) = unit.health.as_mut() {
                    h.reserve_spare(scratch);
                }
            }
        }
        self.cfg.policy = policy;
        if old.ecc_m != policy.ecc_m {
            let (rows, cols) = (self.cfg.rows, self.cfg.cols);
            for unit in &mut self.units {
                unit.ecc = match policy.ecc_m {
                    Some(m) => {
                        // Freshly installed ECC must start consistent
                        // with the array: verify-before trusts the
                        // parities (see `ecc_verify_before`).
                        let mut ecc = DiagonalEcc::new(rows, cols, m);
                        ecc.encode(unit.xbar.state());
                        Some(ecc)
                    }
                    None => None,
                };
            }
        }
        Ok(())
    }
}

/// Scatter one operand's values into its bit-plane columns: per 64-item
/// block, a 64x64 bit transpose turns item-major values into bit-plane
/// words, each stored with a single word splice. Returns switched bits.
fn scatter_operand(
    state: &mut BitMatrix,
    cols: &[u32],
    col_base: u32,
    row_start: usize,
    vals: &[u64],
    n: usize,
) -> u64 {
    let mut switched = 0u64;
    let n = n.min(cols.len());
    let mut block = 0usize;
    while block * 64 < vals.len() {
        let len = (vals.len() - block * 64).min(64);
        let mut tile = [0u64; 64];
        tile[..len].copy_from_slice(&vals[block * 64..block * 64 + len]);
        transpose64(&mut tile);
        for (k, &col) in cols.iter().enumerate().take(n) {
            switched += state.splice_col_word(
                (col + col_base) as usize,
                row_start + block * 64,
                len,
                tile[k],
            ) as u64;
        }
        block += 1;
    }
    switched
}

/// Word-parallel result readback: gather each output bit-plane word,
/// transpose back to item-major values.
fn gather_results(
    state: &BitMatrix,
    output_cols: &[u32],
    items: usize,
    mask: u64,
) -> Result<Vec<u64>> {
    ensure!(output_cols.len() <= 64, "result wider than 64 bits");
    let mut values = Vec::with_capacity(items);
    let mut block = 0usize;
    while block * 64 < items {
        let len = (items - block * 64).min(64);
        let mut tile = [0u64; 64];
        for (k, &c) in output_cols.iter().enumerate() {
            tile[k] = state.gather_col_word(c as usize, block * 64, len);
        }
        transpose64(&mut tile);
        for row in tile.iter().take(len) {
            values.push(row & mask);
        }
        block += 1;
    }
    Ok(values)
}

/// Convenience: build a spec and run it on crossbar 0 of a fresh
/// single-purpose mMPU (used by examples/tests).
pub fn quick_exec(
    kind: FunctionKind,
    policy: ReliabilityPolicy,
    errors: ErrorModel,
    seed: u64,
    a: &[u64],
    b: &[u64],
) -> Result<VectorResult> {
    let func = FunctionSpec::build(kind);
    let need = match policy.tmr {
        TmrMode::Serial => TmrEngine::serial_layout(&func.prog).width,
        TmrMode::Parallel => 3 * func.prog.width + func.out_bits + 2,
        _ => func.prog.width,
    };
    let mut cols = need.next_power_of_two().max(64) as usize;
    if let Some(m) = policy.ecc_m {
        cols = cols.div_ceil(m) * m;
    }
    let mut rows = a.len().max(4);
    if policy.tmr == TmrMode::SemiParallel {
        rows = 3 * a.len() + 1;
    }
    if let Some(m) = policy.ecc_m {
        rows = rows.div_ceil(m) * m;
    }
    let cfg = MmpuConfig {
        rows,
        cols,
        num_crossbars: 1,
        policy,
        errors,
        seed,
        schedule: ScheduleConfig::off(),
    };
    let mut mmpu = Mmpu::new(cfg);
    mmpu.exec_vector(0, &func, a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_add_clean() {
        let a: Vec<u64> = (0..32).map(|i| i * 31 % 256).collect();
        let b: Vec<u64> = (0..32).map(|i| i * 17 % 256).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy::none(),
            ErrorModel::none(),
            1,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..32 {
            assert_eq!(r.values[i], a[i] + b[i], "{i}");
        }
        assert_eq!(r.ecc_cycles, 0);
    }

    #[test]
    fn vector_mul_clean_all_policies() {
        let a: Vec<u64> = (0..16).map(|i| i * 131 % 65536).collect();
        let b: Vec<u64> = (0..16).map(|i| i * 77 % 65536).collect();
        for tmr in [TmrMode::Off, TmrMode::Serial] {
            let r = quick_exec(
                FunctionKind::Mul(16),
                ReliabilityPolicy { ecc_m: None, tmr },
                ErrorModel::none(),
                2,
                &a,
                &b,
            )
            .unwrap();
            for i in 0..16 {
                assert_eq!(r.values[i], a[i] * b[i], "{tmr:?} {i}");
            }
        }
    }

    #[test]
    fn vector_xor_with_ecc() {
        let a: Vec<u64> = (0..16).collect();
        let b: Vec<u64> = (16..32).collect();
        let r = quick_exec(
            FunctionKind::Xor(8),
            ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Off },
            ErrorModel::none(),
            3,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..16 {
            assert_eq!(r.values[i], a[i] ^ b[i]);
        }
        assert!(r.ecc_cycles > 0, "ECC path must account extension cycles");
    }

    #[test]
    fn semi_parallel_policy_roundtrip() {
        let a: Vec<u64> = (0..10).map(|i| i * 3).collect();
        let b: Vec<u64> = (0..10).map(|i| i * 5).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy { ecc_m: None, tmr: TmrMode::SemiParallel },
            ErrorModel::none(),
            4,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(r.values[i], a[i] + b[i]);
        }
    }

    #[test]
    fn word_marshalling_matches_legacy_reference() {
        // Same config + same seed: the word-parallel path and the
        // per-bit reference must agree on values, cycle accounting and
        // injector consumption — including under write failures.
        let a: Vec<u64> = (0..48).map(|i| i * 37 % 256).collect();
        let b: Vec<u64> = (0..48).map(|i| i * 91 % 256).collect();
        let errors = ErrorModel { p_write: 5e-3, ..ErrorModel::direct_only(1e-3) };
        let cfg = MmpuConfig {
            rows: 64,
            cols: 512,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors,
            seed: 41,
            schedule: ScheduleConfig::off(),
        };
        let func = FunctionSpec::build(FunctionKind::Mul(8));
        let mut fast = Mmpu::new(cfg.clone());
        let rf = fast.exec_vector(0, &func, &a, &b).unwrap();
        let mut slow = Mmpu::new(cfg);
        let rs = slow.exec_vector_legacy(0, &func, &a, &b).unwrap();
        assert_eq!(rf.values, rs.values);
        assert_eq!(rf.compute_cycles, rs.compute_cycles);
        assert_eq!(fast.stats(0), slow.stats(0));
        assert_eq!(fast.injector_counters(0), slow.injector_counters(0));
        assert_eq!(fast.crossbar(0).state(), slow.crossbar(0).state());
    }

    #[test]
    fn aging_corrupts_and_scrub_repairs() {
        let cfg = MmpuConfig {
            rows: 32,
            cols: 32,
            num_crossbars: 1,
            policy: ReliabilityPolicy { ecc_m: Some(8), tmr: TmrMode::Off },
            errors: ErrorModel { lambda_retention: 2e-5, ..ErrorModel::none() },
            seed: 5,
            schedule: ScheduleConfig::off(),
        };
        let mut mmpu = Mmpu::new(cfg);
        // Write a known pattern, encode.
        for r in 0..32 {
            for c in 0..32 {
                let v = (r * c) % 3 == 0;
                mmpu.crossbar_mut(0).state_mut().set(r, c, v);
            }
        }
        let snapshot = mmpu.crossbar(0).state().clone();
        // (encode happens inside exec; here drive the ECC directly)
        mmpu.units[0].ecc.as_mut().unwrap().encode(&snapshot);
        mmpu.age(0, 1000.0); // expect ~ 32*32*2e-2 ~ 20 flips? (2e-5*1000=2e-2/bit)
        let flips = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(flips > 0, "aging must corrupt some bits");
        let corrected = mmpu.scrub(0).unwrap();
        assert!(corrected > 0);
        // Every block with exactly one flip is now clean; with ~20 flips
        // over 16 blocks some blocks may be uncorrectable — just require
        // that scrubbing reduced the damage.
        let remaining = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(remaining < flips, "scrub must repair: {remaining} vs {flips}");
    }

    #[test]
    fn injected_gate_errors_reach_results() {
        let a: Vec<u64> = vec![7; 64];
        let b: Vec<u64> = vec![9; 64];
        let r = quick_exec(
            FunctionKind::Mul(8),
            ReliabilityPolicy::none(),
            ErrorModel::direct_only(1e-3),
            6,
            &a,
            &b,
        )
        .unwrap();
        let wrong = r.values.iter().filter(|&&v| v != 63).count();
        assert!(wrong > 0, "p_gate=1e-3 over ~800 gates must corrupt something");
    }

    #[test]
    fn proximity_disturb_fires_on_serving_path() {
        // Satellite audit: p_proximity must be exercised by exec_vector,
        // not only by the raw injector.
        let cfg = MmpuConfig {
            rows: 32,
            cols: 64,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel { p_proximity: 0.2, ..ErrorModel::none() },
            seed: 77,
            schedule: ScheduleConfig::off(),
        };
        let mut mmpu = Mmpu::new(cfg);
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let a: Vec<u64> = (0..32).collect();
        let b: Vec<u64> = (0..32).map(|i| 255 - i).collect();
        mmpu.exec_vector(0, &func, &a, &b).unwrap();
        let hits = mmpu.injector_counters(0).proximity_flips;
        // 32 items x 16 operand bits x 2 neighbor sites at p=0.2.
        assert!(hits > 60, "proximity must fire on the serving path: {hits}");
    }

    #[test]
    fn retention_and_abrupt_fire_on_serving_path() {
        // Satellite audit: the time-domain classes age the array over the
        // batch's cycles x cycle_ns span during exec_vector.
        let errors = ErrorModel {
            lambda_retention: 1e6, // ~0.26/bit over a ~300-cycle batch
            lambda_abrupt: 1e8,    // ~30 strikes over the same span
            ..ErrorModel::none()
        };
        let cfg = MmpuConfig {
            rows: 32,
            cols: 64,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors,
            seed: 78,
            schedule: ScheduleConfig::off(),
        };
        let mut mmpu = Mmpu::new(cfg);
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let a: Vec<u64> = vec![1; 16];
        let b: Vec<u64> = vec![2; 16];
        mmpu.exec_vector(0, &func, &a, &b).unwrap();
        let c = mmpu.injector_counters(0);
        assert!(c.retention_flips > 0, "retention must fire: {c:?}");
        assert!(c.abrupt_flips > 0, "abrupt must fire: {c:?}");
    }

    #[test]
    fn stuck_cell_corrupts_results_until_remapped() {
        use crate::health::{HealthConfig, WearModel};
        let cfg = MmpuConfig {
            rows: 32,
            cols: 64,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 9,
            schedule: ScheduleConfig::off(),
        };
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let out0 = func.prog.output_cols[0];
        let a: Vec<u64> = (0..16).collect();
        let b: Vec<u64> = (0..16).map(|i| 2 * i).collect();
        let hcfg = HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_rows_per_pass: 32,
            ..Default::default()
        };
        let mut mmpu = Mmpu::new(cfg);
        mmpu.enable_health(hcfg);
        // Freeze item 3's low result bit to the wrong value.
        let want3 = a[3] + b[3];
        mmpu.health_mut(0).unwrap().inject_stuck(3, out0, (want3 & 1) == 0);
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        assert_ne!(r.values[3], want3, "stuck output bit must corrupt");
        // A scrub pass detects the fault and remaps row 3 to a spare.
        let rep = mmpu.health_scrub(0).unwrap();
        assert!(rep.detected >= 1 && rep.remapped >= 1, "{rep:?}");
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        for i in 0..16 {
            assert_eq!(r.values[i], a[i] + b[i], "post-remap item {i}");
        }
        let s = mmpu.health(0).unwrap().stats();
        assert_eq!(s.remapped_rows, 1);
        assert!(s.spares_left < 4);
    }

    #[test]
    fn semi_tmr_stuck_row_remaps_and_frees_the_voting_margin() {
        use crate::health::{HealthConfig, WearModel};
        // 32 rows: semi stride k = 10, vote scratch row 31; 4 spare
        // rows -> 28 data rows, so batches of <= 8 items keep every
        // replica triple {i, i+10, i+20} inside the data rows.
        let cfg = MmpuConfig {
            rows: 32,
            cols: 64,
            num_crossbars: 1,
            policy: ReliabilityPolicy { ecc_m: None, tmr: TmrMode::SemiParallel },
            errors: ErrorModel::none(),
            seed: 11,
            schedule: ScheduleConfig::off(),
        };
        let hcfg = HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: 4,
            scrub_interval: 1,
            scrub_rows_per_pass: 32,
            ..Default::default()
        };
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let a0 = func.a_cols[0];
        let a: Vec<u64> = (0..8).map(|i| i * 11 % 256).collect();
        let b: Vec<u64> = (0..8).map(|i| i * 7 % 256).collect();
        // Stuck value chosen opposite to item 3's a-bit0, so the clamp
        // after operand load corrupts that replica's input.
        let stuck = (a[3] & 1) == 0;

        // Margin consumed: two stuck replica rows in item 3's triple
        // (copies 1 and 2, rows 13 and 23) outvote the healthy copy —
        // the silent failure mode this fix removes.
        let mut worn = Mmpu::new(cfg.clone());
        worn.enable_health(hcfg.clone());
        worn.health_mut(0).unwrap().inject_stuck(13, a0, stuck);
        worn.health_mut(0).unwrap().inject_stuck(23, a0, stuck);
        let r = worn.exec_vector(0, &func, &a, &b).unwrap();
        assert_ne!(r.values[3], a[3] + b[3], "two bad copies must outvote the good one");

        // Margin freed: the first stuck row goes through the spare-row
        // remap at scrub time (like the non-TMR path), so the triple
        // regains its full margin and tolerates a second faulty row.
        let mut mmpu = Mmpu::new(cfg);
        mmpu.enable_health(hcfg);
        mmpu.health_mut(0).unwrap().inject_stuck(13, a0, stuck);
        let rep = mmpu.health_scrub(0).unwrap();
        assert!(rep.detected >= 1 && rep.remapped >= 1, "scrub must remap, not absorb: {rep:?}");
        let pairs = mmpu.health(0).unwrap().remapped_pairs();
        assert!(pairs.iter().any(|&(l, _)| l == 13), "row 13 remapped: {pairs:?}");
        assert!(
            pairs.iter().all(|&(_, p)| p != 31),
            "the vote scratch row is reserved and never backs data: {pairs:?}"
        );
        mmpu.health_mut(0).unwrap().inject_stuck(23, a0, stuck);
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        for i in 0..8 {
            assert_eq!(r.values[i], a[i] + b[i], "post-remap item {i}");
        }
        assert!(mmpu.health(0).unwrap().stats().remapped_rows >= 1);
    }

    #[test]
    fn set_policy_swaps_ecc_and_tmr_at_runtime() {
        let cfg = MmpuConfig {
            rows: 32,
            cols: 512,
            num_crossbars: 1,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 10,
            schedule: ScheduleConfig::off(),
        };
        let mut mmpu = Mmpu::new(cfg);
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let a: Vec<u64> = (0..8).collect();
        let b: Vec<u64> = (0..8).map(|i| i + 1).collect();
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        assert_eq!(r.ecc_cycles, 0);
        mmpu.set_policy(ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Serial }).unwrap();
        let r = mmpu.exec_vector(0, &func, &a, &b).unwrap();
        assert!(r.ecc_cycles > 0, "escalated policy must engage ECC");
        for i in 0..8 {
            assert_eq!(r.values[i], a[i] + b[i]);
        }
        // Invalid block size is rejected and leaves the policy alone.
        assert!(mmpu.set_policy(ReliabilityPolicy { ecc_m: Some(7), tmr: TmrMode::Off }).is_err());
        assert_eq!(mmpu.config().policy.ecc_m, Some(16));
    }

    #[test]
    fn batch_layout_decode_roundtrip() {
        let func = FunctionSpec::build(FunctionKind::Add(8));
        let layout = BatchLayout::resolve(TmrMode::SemiParallel, 64, 15, &func).unwrap();
        assert_eq!(layout.replicas, 3);
        assert_eq!(layout.item_stride, 21);
        // Every canonical index decodes to in-range coordinates, and the
        // encoding is a bijection.
        let mut seen = std::collections::HashSet::new();
        for idx in 0..layout.total_bits() {
            let (copy, item, which, bit) = layout.decode(idx);
            assert!(copy < 3 && item < 15 && which < 2 && bit < 8, "idx {idx}");
            assert!(seen.insert((copy, item, which, bit)), "idx {idx} duplicates");
        }
    }
}
