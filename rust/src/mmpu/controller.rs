//! The mMPU controller: crossbar fleet + reliability policy + data
//! marshalling.

use anyhow::{ensure, Result};

use crate::ecc::DiagonalEcc;
use crate::errs::{ErrorModel, Injector};
use crate::tmr::{TmrEngine, TmrMode};
use crate::xbar::crossbar::Crossbar;

use super::functions::{FunctionKind, FunctionSpec};

/// Reliability policy applied to every function execution.
#[derive(Clone, Copy, Debug)]
pub struct ReliabilityPolicy {
    /// Diagonal ECC block size m (None = unprotected storage).
    pub ecc_m: Option<usize>,
    /// TMR strategy for computation.
    pub tmr: TmrMode,
}

impl ReliabilityPolicy {
    pub fn none() -> Self {
        Self { ecc_m: None, tmr: TmrMode::Off }
    }

    pub fn full() -> Self {
        Self { ecc_m: Some(16), tmr: TmrMode::Serial }
    }
}

/// Fleet configuration.
#[derive(Clone, Debug)]
pub struct MmpuConfig {
    pub rows: usize,
    pub cols: usize,
    pub num_crossbars: usize,
    pub policy: ReliabilityPolicy,
    pub errors: ErrorModel,
    pub seed: u64,
}

impl Default for MmpuConfig {
    fn default() -> Self {
        Self {
            rows: 128,
            cols: 1024,
            num_crossbars: 4,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 0xACE1,
        }
    }
}

/// One crossbar with its private error stream and ECC extension.
struct XbarUnit {
    xbar: Crossbar,
    inj: Injector,
    ecc: Option<DiagonalEcc>,
}

/// Result of a vectored function execution.
#[derive(Clone, Debug)]
pub struct VectorResult {
    pub values: Vec<u64>,
    /// Compute cycles (stateful logic, incl. TMR voting).
    pub compute_cycles: u64,
    /// ECC extension cycles added on the critical path
    /// (verify-before + update-after).
    pub ecc_cycles: u64,
    /// Errors the ECC pre-verification corrected in the input region.
    pub ecc_corrected: u64,
}

/// The memristive Memory Processing Unit.
pub struct Mmpu {
    cfg: MmpuConfig,
    units: Vec<XbarUnit>,
}

impl Mmpu {
    pub fn new(cfg: MmpuConfig) -> Self {
        let mut root = Injector::new(cfg.errors, cfg.seed, 0);
        let units = (0..cfg.num_crossbars)
            .map(|_| XbarUnit {
                xbar: Crossbar::new(cfg.rows, cfg.cols),
                inj: root.split(),
                ecc: cfg.policy.ecc_m.map(|m| DiagonalEcc::new(cfg.rows, cfg.cols, m)),
            })
            .collect();
        Self { cfg, units }
    }

    pub fn config(&self) -> &MmpuConfig {
        &self.cfg
    }

    pub fn num_crossbars(&self) -> usize {
        self.units.len()
    }

    pub fn rows(&self) -> usize {
        self.cfg.rows
    }

    pub fn crossbar(&self, id: usize) -> &Crossbar {
        &self.units[id].xbar
    }

    pub fn crossbar_mut(&mut self, id: usize) -> &mut Crossbar {
        &mut self.units[id].xbar
    }

    pub fn injector_counters(&self, id: usize) -> crate::errs::ErrorCounters {
        self.units[id].inj.counters
    }

    /// Execute a vectored function: element i of `a`/`b` occupies row i
    /// (replicated per the TMR strategy's needs). Returns element
    /// results in order.
    pub fn exec_vector(
        &mut self,
        xbar_id: usize,
        func: &FunctionSpec,
        a: &[u64],
        b: &[u64],
    ) -> Result<VectorResult> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        ensure!(xbar_id < self.units.len(), "bad crossbar id");
        let tmr = self.cfg.policy.tmr;
        let unit = &mut self.units[xbar_id];
        let rows = unit.xbar.rows();
        let n = func.kind.operand_bits();

        // Row mapping per strategy.
        let (items, replicas) = match tmr {
            TmrMode::SemiParallel => {
                let k = (rows - 1) / 3;
                ensure!(a.len() <= k, "too many items for semi-parallel TMR ({k} max)");
                (a.len(), 3usize)
            }
            _ => {
                ensure!(a.len() <= rows, "too many items ({rows} rows)");
                (a.len(), 1usize)
            }
        };

        // --- load operands (memory-interface writes) -----------------
        let item_stride = if replicas == 3 { (rows - 1) / 3 } else { 0 };
        for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
            for rep in 0..replicas {
                let row = i + rep * item_stride;
                Self::write_operand(&mut unit.xbar, &mut unit.inj, row, &func.a_cols, av, n);
                Self::write_operand(&mut unit.xbar, &mut unit.inj, row, &func.b_cols, bv, n);
            }
        }
        // Parallel TMR keeps three column-relocated copies of the inputs.
        if tmr == TmrMode::Parallel {
            for base in TmrEngine::parallel_copy_bases(&func.prog).into_iter().skip(1) {
                for (i, (&av, &bv)) in a.iter().zip(b).enumerate() {
                    let ac: Vec<u32> = func.a_cols.iter().map(|c| c + base).collect();
                    let bc: Vec<u32> = func.b_cols.iter().map(|c| c + base).collect();
                    Self::write_operand(&mut unit.xbar, &mut unit.inj, i, &ac, av, n);
                    Self::write_operand(&mut unit.xbar, &mut unit.inj, i, &bc, bv, n);
                }
            }
        }

        // --- ECC: encode freshly-written inputs, verify before compute -
        let mut ecc_cycles = 0;
        let mut ecc_corrected = 0;
        if let Some(ecc) = unit.ecc.as_mut() {
            ecc.encode(unit.xbar.state());
            let v0 = ecc.stats.verify_cycles + ecc.stats.update_cycles;
            let outcome = ecc.correct(unit.xbar.state_mut());
            ecc_corrected += outcome.corrected_bits.len() as u64;
            ecc_cycles += ecc.stats.verify_cycles + ecc.stats.update_cycles - v0;
        }

        // --- compute under TMR ---------------------------------------
        let engine = TmrEngine::new(tmr);
        let inj = if self.cfg.errors.is_silent() { None } else { Some(&mut unit.inj) };
        let run = engine.execute(&mut unit.xbar, &func.prog, inj)?;

        // --- ECC: update check bits for the produced outputs ----------
        if let Some(ecc) = unit.ecc.as_mut() {
            for &c in &run.output_cols {
                let col = unit.xbar.state().col_bitvec(c as usize);
                // parity' = parity ^ old ^ new; the controller models the
                // old column as it was before compute — the engine tracks
                // only cycle cost here, then re-syncs the block parities.
                ecc.note_col_write(c as usize, &col, &col);
            }
            // Re-sync (outputs & intermediates changed during compute).
            ecc.encode(unit.xbar.state());
            ecc_cycles += ecc.update_cost(run.output_cols.len() as u64);
        }

        // --- read back -------------------------------------------------
        let mask = func.result_mask();
        let values = (0..items)
            .map(|i| {
                run.output_cols.iter().enumerate().fold(0u64, |acc, (k, &c)| {
                    acc | ((unit.xbar.get(i, c as usize) as u64) << k)
                }) & mask
            })
            .collect();
        Ok(VectorResult {
            values,
            compute_cycles: run.cycles,
            ecc_cycles,
            ecc_corrected,
        })
    }

    fn write_operand(
        xbar: &mut Crossbar,
        inj: &mut Injector,
        row: usize,
        cols: &[u32],
        value: u64,
        n: u32,
    ) {
        for (k, &c) in cols.iter().enumerate().take(n as usize) {
            xbar.write_bit(row, c as usize, (value >> k) & 1 == 1, Some(inj));
        }
    }

    /// Periodic ECC scrub of a crossbar (correct accumulated indirect
    /// errors). Returns corrected data-bit count.
    pub fn scrub(&mut self, xbar_id: usize) -> Result<u64> {
        let unit = &mut self.units[xbar_id];
        match unit.ecc.as_mut() {
            Some(ecc) => {
                let out = ecc.correct(unit.xbar.state_mut());
                Ok(out.corrected_bits.len() as u64)
            }
            None => Ok(0),
        }
    }

    /// Expose accumulated crossbar stats (cycles, energy, ...).
    pub fn stats(&self, xbar_id: usize) -> crate::xbar::crossbar::XbarStats {
        self.units[xbar_id].xbar.stats
    }

    /// Age the stored data by `dt` seconds (retention + abrupt events) —
    /// drives the Fig. 5 style degradation experiments.
    pub fn age(&mut self, xbar_id: usize, dt: f64) {
        let unit = &mut self.units[xbar_id];
        let rows = unit.xbar.rows();
        let cols = unit.xbar.cols();
        let bits = rows * cols;
        let state = unit.xbar.state_mut();
        unit.inj.retention(bits, dt, |i| state.flip(i / cols, i % cols));
        unit.inj.abrupt(bits, dt, |i| state.flip(i / cols, i % cols));
    }
}

/// Convenience: build a spec and run it on crossbar 0 of a fresh
/// single-purpose mMPU (used by examples/tests).
pub fn quick_exec(
    kind: FunctionKind,
    policy: ReliabilityPolicy,
    errors: ErrorModel,
    seed: u64,
    a: &[u64],
    b: &[u64],
) -> Result<VectorResult> {
    let func = FunctionSpec::build(kind);
    let need = match policy.tmr {
        TmrMode::Serial => TmrEngine::serial_layout(&func.prog).width,
        TmrMode::Parallel => 3 * func.prog.width + func.out_bits + 2,
        _ => func.prog.width,
    };
    let mut cols = need.next_power_of_two().max(64) as usize;
    if let Some(m) = policy.ecc_m {
        cols = cols.div_ceil(m) * m;
    }
    let mut rows = a.len().max(4);
    if policy.tmr == TmrMode::SemiParallel {
        rows = 3 * a.len() + 1;
    }
    if let Some(m) = policy.ecc_m {
        rows = rows.div_ceil(m) * m;
    }
    let cfg = MmpuConfig {
        rows,
        cols,
        num_crossbars: 1,
        policy,
        errors,
        seed,
    };
    let mut mmpu = Mmpu::new(cfg);
    mmpu.exec_vector(0, &func, a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_add_clean() {
        let a: Vec<u64> = (0..32).map(|i| i * 31 % 256).collect();
        let b: Vec<u64> = (0..32).map(|i| i * 17 % 256).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy::none(),
            ErrorModel::none(),
            1,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..32 {
            assert_eq!(r.values[i], a[i] + b[i], "{i}");
        }
        assert_eq!(r.ecc_cycles, 0);
    }

    #[test]
    fn vector_mul_clean_all_policies() {
        let a: Vec<u64> = (0..16).map(|i| i * 131 % 65536).collect();
        let b: Vec<u64> = (0..16).map(|i| i * 77 % 65536).collect();
        for tmr in [TmrMode::Off, TmrMode::Serial] {
            let r = quick_exec(
                FunctionKind::Mul(16),
                ReliabilityPolicy { ecc_m: None, tmr },
                ErrorModel::none(),
                2,
                &a,
                &b,
            )
            .unwrap();
            for i in 0..16 {
                assert_eq!(r.values[i], a[i] * b[i], "{tmr:?} {i}");
            }
        }
    }

    #[test]
    fn vector_xor_with_ecc() {
        let a: Vec<u64> = (0..16).collect();
        let b: Vec<u64> = (16..32).collect();
        let r = quick_exec(
            FunctionKind::Xor(8),
            ReliabilityPolicy { ecc_m: Some(16), tmr: TmrMode::Off },
            ErrorModel::none(),
            3,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..16 {
            assert_eq!(r.values[i], a[i] ^ b[i]);
        }
        assert!(r.ecc_cycles > 0, "ECC path must account extension cycles");
    }

    #[test]
    fn semi_parallel_policy_roundtrip() {
        let a: Vec<u64> = (0..10).map(|i| i * 3).collect();
        let b: Vec<u64> = (0..10).map(|i| i * 5).collect();
        let r = quick_exec(
            FunctionKind::Add(8),
            ReliabilityPolicy { ecc_m: None, tmr: TmrMode::SemiParallel },
            ErrorModel::none(),
            4,
            &a,
            &b,
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(r.values[i], a[i] + b[i]);
        }
    }

    #[test]
    fn aging_corrupts_and_scrub_repairs() {
        let cfg = MmpuConfig {
            rows: 32,
            cols: 32,
            num_crossbars: 1,
            policy: ReliabilityPolicy { ecc_m: Some(8), tmr: TmrMode::Off },
            errors: ErrorModel { lambda_retention: 2e-5, ..ErrorModel::none() },
            seed: 5,
        };
        let mut mmpu = Mmpu::new(cfg);
        // Write a known pattern, encode.
        for r in 0..32 {
            for c in 0..32 {
                let v = (r * c) % 3 == 0;
                mmpu.crossbar_mut(0).state_mut().set(r, c, v);
            }
        }
        let snapshot = mmpu.crossbar(0).state().clone();
        // (encode happens inside exec; here drive the ECC directly)
        mmpu.units[0].ecc.as_mut().unwrap().encode(&snapshot);
        mmpu.age(0, 1000.0); // expect ~ 32*32*2e-2 ~ 20 flips? (2e-5*1000=2e-2/bit)
        let flips = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(flips > 0, "aging must corrupt some bits");
        let corrected = mmpu.scrub(0).unwrap();
        assert!(corrected > 0);
        // Every block with exactly one flip is now clean; with ~20 flips
        // over 16 blocks some blocks may be uncorrectable — just require
        // that scrubbing reduced the damage.
        let remaining = {
            let now = mmpu.crossbar(0).state();
            (0..32)
                .flat_map(|r| (0..32).map(move |c| (r, c)))
                .filter(|&(r, c)| now.get(r, c) != snapshot.get(r, c))
                .count()
        };
        assert!(remaining < flips, "scrub must repair: {remaining} vs {flips}");
    }

    #[test]
    fn injected_gate_errors_reach_results() {
        let a: Vec<u64> = vec![7; 64];
        let b: Vec<u64> = vec![9; 64];
        let r = quick_exec(
            FunctionKind::Mul(8),
            ReliabilityPolicy::none(),
            ErrorModel::direct_only(1e-3),
            6,
            &a,
            &b,
        )
        .unwrap();
        let wrong = r.values.iter().filter(|&&v| v != 63).count();
        assert!(wrong > 0, "p_gate=1e-3 over ~800 gates must corrupt something");
    }
}
