//! The memristive Memory Processing Unit (paper §III) — substrate S8.
//!
//! The controller owns a fleet of crossbars, converts function-level
//! instructions (vector add / multiply / xor) into micro-op programs via
//! `arith`, executes them under the configured reliability policy
//! (ECC verify-before / update-after + TMR strategy), and marshals data
//! in and out of the bit-plane layout.

pub mod compiled;
pub mod controller;
pub mod functions;

pub use compiled::{CompiledFunction, PlanCache, PlanKey};
pub use controller::{Mmpu, MmpuConfig, ReliabilityPolicy, VectorResult};
pub use functions::{FunctionKind, FunctionSpec};
