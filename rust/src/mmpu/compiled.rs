//! Compiled function instances and the shared plan cache (§Perf).
//!
//! A [`CompiledFunction`] freezes one `FunctionSpec` + TMR strategy for
//! one crossbar shape: the program's concurrency is validated once, all
//! TMR copies are retargeted/relocated once, and every micro-op is
//! resolved (see `isa::CompiledPlan`). The [`PlanCache`] shares these
//! behind `Arc` keyed by `(FunctionKind, rows, cols, TmrMode,
//! ScheduleConfig)` — the coordinator hands one cache to all workers,
//! replacing the per-worker `FunctionSpec::build` + per-execution
//! program interpretation that previously dominated the request path.
//! Keying on the [`ScheduleConfig`] lets serial and list-scheduled
//! compilations of the same function coexist (§Perf).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::isa::ScheduleConfig;
use crate::tmr::{CompiledTmr, TmrEngine, TmrMode};

use super::functions::{FunctionKind, FunctionSpec};

/// A function compiled for a crossbar shape under a TMR strategy.
#[derive(Clone, Debug)]
pub struct CompiledFunction {
    pub spec: FunctionSpec,
    pub tmr: CompiledTmr,
    /// The schedule this compilation was requested under (part of the
    /// cache key; `off` = the serial program-order reference).
    sched: ScheduleConfig,
}

impl CompiledFunction {
    /// Synthesize the spec and compile it in one step.
    pub fn build(
        kind: FunctionKind,
        rows: usize,
        cols: usize,
        tmr: TmrMode,
        sched: ScheduleConfig,
    ) -> Result<Self> {
        Self::from_spec(FunctionSpec::build(kind), rows, cols, tmr, sched)
    }

    /// Compile an already-synthesized spec.
    pub fn from_spec(
        spec: FunctionSpec,
        rows: usize,
        cols: usize,
        tmr: TmrMode,
        sched: ScheduleConfig,
    ) -> Result<Self> {
        let compiled = TmrEngine::new(tmr).compile_with(&spec.prog, rows, cols, sched)?;
        Ok(Self { spec, tmr: compiled, sched })
    }

    pub fn kind(&self) -> FunctionKind {
        self.spec.kind
    }

    pub fn mode(&self) -> TmrMode {
        self.tmr.mode
    }

    /// The schedule this compilation was requested under.
    pub fn schedule(&self) -> ScheduleConfig {
        self.sched
    }

    pub fn rows(&self) -> usize {
        self.tmr.rows()
    }

    pub fn cols(&self) -> usize {
        self.tmr.cols()
    }
}

/// Cache key: function + crossbar shape + reliability strategy +
/// schedule.
pub type PlanKey = (FunctionKind, usize, usize, TmrMode, ScheduleConfig);

/// Thread-safe cache of compiled functions, shared across coordinator
/// workers (and used internally by `Mmpu`). Compilation happens at most
/// once per key; lookups are a mutex-guarded hash probe returning a
/// cheap `Arc` clone.
#[derive(Debug, Default)]
pub struct PlanCache {
    inner: Mutex<HashMap<PlanKey, Arc<CompiledFunction>>>,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch or build the compiled function for `kind` on `rows x cols`
    /// under `tmr` + `sched` (spec synthesized via `FunctionSpec::build`).
    pub fn get(
        &self,
        kind: FunctionKind,
        rows: usize,
        cols: usize,
        tmr: TmrMode,
        sched: ScheduleConfig,
    ) -> Result<Arc<CompiledFunction>> {
        self.get_or_compile(kind, rows, cols, tmr, sched, || {
            CompiledFunction::build(kind, rows, cols, tmr, sched)
        })
    }

    /// Fetch or build with a caller-provided builder (used when the
    /// caller already holds a synthesized `FunctionSpec`).
    pub fn get_or_compile(
        &self,
        kind: FunctionKind,
        rows: usize,
        cols: usize,
        tmr: TmrMode,
        sched: ScheduleConfig,
        build: impl FnOnce() -> Result<CompiledFunction>,
    ) -> Result<Arc<CompiledFunction>> {
        let key: PlanKey = (kind, rows, cols, tmr, sched);
        let mut map = self.inner.lock().expect("plan cache poisoned");
        if let Some(cf) = map.get(&key) {
            return Ok(cf.clone());
        }
        let cf = Arc::new(build()?);
        map.insert(key, cf.clone());
        Ok(cf)
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_compiles_once_and_shares() {
        let off = ScheduleConfig::off();
        let cache = PlanCache::new();
        let a = cache.get(FunctionKind::Add(8), 16, 256, TmrMode::Off, off).unwrap();
        let b = cache.get(FunctionKind::Add(8), 16, 256, TmrMode::Off, off).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert_eq!(cache.len(), 1);
        // Different shape, mode, or schedule -> different entry.
        cache.get(FunctionKind::Add(8), 32, 256, TmrMode::Off, off).unwrap();
        cache.get(FunctionKind::Add(8), 16, 256, TmrMode::Serial, off).unwrap();
        cache
            .get(FunctionKind::Add(8), 16, 256, TmrMode::Off, ScheduleConfig::packed(8))
            .unwrap();
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn compile_errors_surface() {
        // 8 columns cannot hold an 8-bit adder.
        let cache = PlanCache::new();
        assert!(cache
            .get(FunctionKind::Add(8), 16, 8, TmrMode::Off, ScheduleConfig::off())
            .is_err());
        assert_eq!(cache.len(), 0, "failed compiles are not cached");
    }

    #[test]
    fn compiled_function_accessors() {
        let cf = CompiledFunction::build(
            FunctionKind::Xor(4),
            8,
            64,
            TmrMode::Off,
            ScheduleConfig::off(),
        )
        .unwrap();
        assert_eq!(cf.kind(), FunctionKind::Xor(4));
        assert_eq!(cf.mode(), TmrMode::Off);
        assert_eq!(cf.schedule(), ScheduleConfig::off());
        assert_eq!((cf.rows(), cf.cols()), (8, 64));
    }

    #[test]
    fn scheduled_entry_coexists_with_serial() {
        let cache = PlanCache::new();
        let serial =
            cache.get(FunctionKind::Mul(8), 32, 640, TmrMode::Off, ScheduleConfig::off()).unwrap();
        let sched = cache
            .get(FunctionKind::Mul(8), 32, 640, TmrMode::Off, ScheduleConfig::packed(8))
            .unwrap();
        assert!(!Arc::ptr_eq(&serial, &sched));
        assert_eq!(cache.len(), 2);
        assert_eq!(serial.tmr.num_ops(), sched.tmr.num_ops(), "packing drops no ops");
        assert!(sched.tmr.num_bundles() <= serial.tmr.num_bundles());
    }
}
