//! Bitlet-style analytical throughput model (paper §IV cites ~100 TB/s
//! for 8192 crossbars of 1024x1024 at 1 GB total — after the bitlet
//! model [35]). Used by the tab_throughput bench (E11) and to translate
//! simulator cycle counts into wall-clock/bandwidth estimates.

/// mMPU fleet parameters for the throughput model.
#[derive(Clone, Copy, Debug)]
pub struct BitletModel {
    pub crossbars: u64,
    pub rows: u64,
    pub cols: u64,
    /// Crossbar clock, MHz (1 GHz typical for 1 ns gate pulses).
    pub freq_mhz: f64,
}

impl BitletModel {
    /// The paper's configuration: 8192 crossbars x 1024^2 = 1 GiB at the
    /// bitlet model's conservative 100 MHz memristive clock (10 ns gate
    /// pulses) — this is the configuration behind the "~100 TB/s" quote.
    pub fn paper() -> Self {
        Self { crossbars: 8192, rows: 1024, cols: 1024, freq_mhz: 100.0 }
    }

    /// Total memory, bytes.
    pub fn total_bytes(&self) -> u64 {
        self.crossbars * self.rows * self.cols / 8
    }

    /// Peak processed bits per second: every crossbar applies one
    /// row-parallel gate per cycle touching all rows.
    pub fn peak_bits_per_sec(&self) -> f64 {
        self.crossbars as f64 * self.rows as f64 * self.freq_mhz * 1e6
    }

    /// Peak throughput in TB/s (the paper's "~100 TB/s" claim).
    pub fn peak_tb_per_sec(&self) -> f64 {
        self.peak_bits_per_sec() / 8.0 / 1e12
    }

    /// Function-level throughput: items/s for a function of `cycles`
    /// latency processing `items_per_xbar` rows per invocation.
    pub fn function_throughput(&self, cycles: u64, items_per_xbar: u64) -> f64 {
        let execs_per_sec = self.freq_mhz * 1e6 / cycles as f64;
        execs_per_sec * items_per_xbar as f64 * self.crossbars as f64
    }

    /// Effective throughput multiplier of a reliability mode.
    pub fn with_overhead(&self, base_cycles: u64, overhead_cycles: u64) -> f64 {
        base_cycles as f64 / (base_cycles + overhead_cycles) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configuration_hits_100tbs() {
        let m = BitletModel::paper();
        assert_eq!(m.total_bytes(), 1 << 30, "1 GiB");
        let tbs = m.peak_tb_per_sec();
        assert!((90.0..130.0).contains(&tbs), "{tbs} TB/s ~ paper's ~100 TB/s");
    }

    #[test]
    fn function_throughput_scales() {
        let m = BitletModel::paper();
        let t1 = m.function_throughput(448, 1024);
        let t2 = m.function_throughput(896, 1024);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
        // 32-bit MultPIM-ish: ~1.4k cycles, 1024 rows, 8192 xbars
        let t = m.function_throughput(1400, 1024);
        assert!(t > 1e11, "{t} mult/s regime");
    }

    #[test]
    fn overhead_multiplier() {
        let m = BitletModel::paper();
        assert!((m.with_overhead(100, 26) - 100.0 / 126.0).abs() < 1e-12);
    }
}
