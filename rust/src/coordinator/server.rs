//! The coordinator front end: submit scalar requests, get results back
//! through per-request channels; a batcher thread groups them and routes
//! batches to worker threads (one crossbar each, least-loaded first).
//!
//! §Perf: workers share one [`PlanCache`] — each `(function, shape,
//! TMR mode)` is synthesized, TMR-expanded and plan-compiled exactly
//! once process-wide (`Arc`-shared), and batch execution goes through
//! the word-parallel `Mmpu::exec_vector_compiled` path. Failed batches
//! deliver an explicit error result per item (clients never observe a
//! silently closed channel) and are counted in `metrics.failed`.
//!
//! §Health: with `CoordinatorConfig::health` set, each worker runs an
//! online fault manager on its crossbar — scrubbing between batches,
//! adaptive policy escalation (None -> ECC -> ECC+TMR), and crossbar
//! **retirement**: a retired worker drops out of routing and sends its
//! queued batches back through the front channel for redistribution to
//! healthy workers. When no healthy worker remains (or during shutdown
//! drain), requests receive explicit error results — never a hang.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::errs::ErrorModel;
use crate::health::HealthConfig;
use crate::isa::ScheduleConfig;
use crate::mmpu::{
    CompiledFunction, FunctionKind, Mmpu, MmpuConfig, PlanCache, ReliabilityPolicy, VectorResult,
};
use crate::telemetry::{
    EventJournal, EventKind, Stage, Tracer, DEFAULT_JOURNAL_CAPACITY, DEFAULT_SPAN_CAPACITY,
};
use crate::tmr::TmrMode;

use super::batcher::{Batch, Batcher, Pending};
use super::metrics::{Metrics, MetricsSnapshot, WorkerHealth};
use super::Submitter;

/// Outcome delivered to the submitting client.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// The function result (0 when `error` is set).
    pub value: u64,
    pub latency: Duration,
    /// Present when the batch failed to compile or execute: the per-item
    /// error delivered instead of silently dropping the reply channel.
    pub error: Option<String>,
}

impl RequestResult {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub rows: usize,
    pub cols: usize,
    pub policy: ReliabilityPolicy,
    pub errors: ErrorModel,
    pub seed: u64,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bounded per-worker queue (backpressure).
    pub worker_queue: usize,
    /// Cold hot-spare crossbars (§Health follow-on): spare workers are
    /// spawned up front but excluded from routing; when a worker retires
    /// its crossbar, it activates one spare so fleet capacity is
    /// restored instead of shrinking.
    pub spare_workers: usize,
    /// Per-crossbar online fault management (§Health). `None` preserves
    /// the pre-health behavior exactly.
    pub health: Option<HealthConfig>,
    /// §Telemetry: sample 1 in `trace_sample` requests for stage-span
    /// tracing (0 disables tracing; the disabled path is one branch).
    pub trace_sample: u64,
    /// §Perf: list-scheduling configuration for every compiled plan
    /// (`off` = the serial program-order reference). Threaded into the
    /// shared [`PlanCache`] key, so fleets with different schedules can
    /// share a cache without mixing plans.
    pub schedule: ScheduleConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            rows: 64,
            cols: 1024,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 0xC0,
            max_batch: 64,
            max_wait: Duration::from_micros(500),
            worker_queue: 8,
            spare_workers: 0,
            health: None,
            trace_sample: 0,
            schedule: ScheduleConfig::off(),
        }
    }
}

enum FrontMsg {
    Submit { kind: FunctionKind, pending: Pending },
    Shutdown,
}

/// The running coordinator.
pub struct Coordinator {
    front_tx: Sender<FrontMsg>,
    metrics: Arc<Metrics>,
    /// Routability per worker slot (shared with batcher + workers):
    /// active workers start true, cold spares start false, retirement
    /// flips the retiree off and one spare on.
    healthy: Arc<Vec<AtomicBool>>,
    batcher_handle: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
    /// §Telemetry: mints trace ids and holds the sampled stage spans
    /// recorded by this coordinator's workers.
    tracer: Arc<Tracer>,
    /// §Telemetry: this process's reliability event journal (scrubs,
    /// policy moves, retirements — workers record into it directly).
    journal: Arc<EventJournal>,
}

/// Logical rows available to batches (§Health reserves spare rows).
fn data_rows(cfg: &CoordinatorConfig) -> usize {
    cfg.rows.saturating_sub(cfg.health.as_ref().map_or(0, |h| h.spare_rows)).max(1)
}

/// Items per batch under SemiParallel TMR (`None` for other modes):
/// the row-triple stride is (rows-1)/3, and with health on, every
/// triple {i, i+k, i+2k} must fit inside the data rows so the reserved
/// spares (and the vote scratch row) are never part of a triple.
fn semi_fit(cfg: &CoordinatorConfig) -> Option<usize> {
    if cfg.policy.tmr != TmrMode::SemiParallel {
        return None;
    }
    let stride = cfg.rows.saturating_sub(1) / 3;
    Some(if cfg.health.is_some() {
        stride.min(data_rows(cfg).saturating_sub(2 * stride))
    } else {
        stride
    })
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        // An impossible SemiParallel geometry must fail here, loudly —
        // not start cleanly and then answer every request with a
        // batch-shape error.
        if let Some(fit) = semi_fit(&cfg) {
            ensure!(
                fit >= 1,
                "semi-parallel TMR cannot fit one replica triple: rows={}, spare_rows={}",
                cfg.rows,
                cfg.health.as_ref().map_or(0, |h| h.spare_rows)
            );
        }
        // Worker slots cfg.workers.. are cold spares: spawned (so their
        // crossbars and channels exist) but unroutable until a
        // retirement activates them.
        let total_workers = cfg.workers + cfg.spare_workers;
        let metrics = Arc::new(Metrics::new());
        metrics.init_workers(total_workers);
        let tracer = Arc::new(Tracer::new(cfg.trace_sample, DEFAULT_SPAN_CAPACITY));
        let journal = Arc::new(EventJournal::new(DEFAULT_JOURNAL_CAPACITY));
        // One compiled-plan cache shared by every worker: each
        // (kind, shape, tmr) compiles once process-wide (§Perf).
        let plans = Arc::new(PlanCache::new());
        // Front channel first: retiring workers send their queued
        // batches back through it for redistribution (§Health).
        let (front_tx, front_rx) = channel::<FrontMsg>();
        // Workers.
        let mut worker_txs: Vec<SyncSender<Batch>> = vec![];
        let mut worker_handles = vec![];
        let depths: Arc<Vec<AtomicU64>> =
            Arc::new((0..total_workers).map(|_| AtomicU64::new(0)).collect());
        let healthy: Arc<Vec<AtomicBool>> =
            Arc::new((0..total_workers).map(|w| AtomicBool::new(w < cfg.workers)).collect());
        // LIFO pool of cold spare slots, popped on retirement.
        let spares: Arc<Mutex<Vec<usize>>> =
            Arc::new(Mutex::new((cfg.workers..total_workers).collect()));
        for w in 0..total_workers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<Batch>(cfg.worker_queue);
            worker_txs.push(tx);
            let m = metrics.clone();
            let d = depths.clone();
            let h = healthy.clone();
            let s = spares.clone();
            let cfg2 = cfg.clone();
            let p = plans.clone();
            let f = front_tx.clone();
            let tr = tracer.clone();
            let j = journal.clone();
            worker_handles
                .push(std::thread::spawn(move || worker_loop(w, cfg2, rx, m, d, p, f, h, s, tr, j)));
        }
        // Batcher / router.
        let m = metrics.clone();
        let cfg2 = cfg.clone();
        let d = depths.clone();
        let h = healthy.clone();
        let batcher_handle =
            std::thread::spawn(move || batcher_loop(cfg2, front_rx, worker_txs, m, d, h));
        Ok(Self {
            front_tx,
            metrics,
            healthy,
            batcher_handle: Some(batcher_handle),
            worker_handles,
            tracer,
            journal,
        })
    }

    /// Submit one scalar request; the receiver yields the result. A
    /// trace id is minted here (0 / untraced unless `trace_sample` is
    /// configured).
    pub fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let trace = self.tracer.mint();
        self.submit_traced(kind, a, b, trace)
    }

    /// Submit with a caller-supplied trace id (0 = untraced): the
    /// fabric shard path, where the id was minted at the router so
    /// router- and shard-side spans share one trace.
    pub fn submit_traced(
        &self,
        kind: FunctionKind,
        a: u64,
        b: u64,
        trace: u64,
    ) -> Receiver<RequestResult> {
        let (tx, rx) = channel();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_kind_submitted(kind);
        let _ = self.front_tx.send(FrontMsg::Submit {
            kind,
            pending: Pending { a, b, reply: tx, submitted: Instant::now(), trace },
        });
        rx
    }

    /// §Telemetry: the span tracer shared with this coordinator's
    /// workers (sampled stage spans live here).
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// §Telemetry: this process's reliability event journal.
    pub fn journal(&self) -> &Arc<EventJournal> {
        &self.journal
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Routable (healthy, activated) workers right now.
    pub fn healthy_workers(&self) -> usize {
        self.healthy.iter().filter(|h| h.load(Ordering::Relaxed)).count()
    }

    /// Non-blocking capacity probe: true while at least one routable
    /// worker exists. After retire-all this turns false, so the fabric
    /// router (or any front end) can mark this coordinator down without
    /// burning a request on an explicit error result.
    pub fn is_serving(&self) -> bool {
        self.healthy.iter().any(|h| h.load(Ordering::Relaxed))
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        let _ = self.front_tx.send(FrontMsg::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Submitter for Coordinator {
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        Coordinator::submit(self, kind, a, b)
    }

    fn metrics(&self) -> MetricsSnapshot {
        Coordinator::metrics(self)
    }

    fn is_serving(&self) -> bool {
        Coordinator::is_serving(self)
    }
}

/// Error-result text for requests that found no routable worker (all
/// crossbars retired / zero workers). The fabric router keys shard
/// failover off this exact text (`fabric::router`), so treat it as part
/// of the coordinator's API, not freely rewordable prose.
pub const NO_CAPACITY_ERROR: &str = "no healthy workers (all crossbars retired)";

/// Deliver an explicit error result to every item of a batch.
fn fail_batch(batch: Batch, metrics: &Metrics, why: &str) {
    metrics.record_kind_failed(batch.kind, batch.items.len() as u64);
    for item in batch.items {
        let latency = item.submitted.elapsed();
        metrics.failed.fetch_add(1, Ordering::Relaxed);
        let _ = item.reply.send(RequestResult { value: 0, latency, error: Some(why.to_string()) });
    }
}

fn batcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<FrontMsg>,
    worker_txs: Vec<SyncSender<Batch>>,
    metrics: Arc<Metrics>,
    depths: Arc<Vec<AtomicU64>>,
    healthy: Arc<Vec<AtomicBool>>,
) {
    // §Health: spare rows are reserved out of the batchable row space;
    // SemiParallel TMR caps batches at its triple fit (validated >= 1
    // at Coordinator::start, see `semi_fit`).
    let max_items = semi_fit(&cfg).unwrap_or_else(|| data_rows(&cfg));
    let mut batcher = Batcher::new(cfg.max_batch.min(max_items).max(1), cfg.max_wait);
    let dispatch = |batch: Batch, depths: &Arc<Vec<AtomicU64>>, metrics: &Arc<Metrics>| {
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(batch.items.len() as u64, Ordering::Relaxed);
        // Route to the least-loaded *healthy* worker; spin while all
        // healthy queues are full (backpressure propagates to the
        // batcher, then to clients). With no healthy worker left the
        // batch fails explicitly — clients must never hang.
        let mut batch = batch;
        loop {
            let pick = depths
                .iter()
                .enumerate()
                .filter(|(i, _)| healthy[*i].load(Ordering::Relaxed))
                .min_by_key(|(_, d)| d.load(Ordering::Relaxed));
            let Some((widx, _)) = pick else {
                fail_batch(batch, metrics, NO_CAPACITY_ERROR);
                return;
            };
            depths[widx].fetch_add(1, Ordering::Relaxed);
            match worker_txs[widx].try_send(batch) {
                Ok(()) => return,
                Err(TrySendError::Full(b)) => {
                    depths[widx].fetch_sub(1, Ordering::Relaxed);
                    batch = b;
                    std::thread::yield_now();
                }
                Err(TrySendError::Disconnected(b)) => {
                    depths[widx].fetch_sub(1, Ordering::Relaxed);
                    fail_batch(b, metrics, "worker queue disconnected");
                    return;
                }
            }
        }
    };
    let mut stop = false;
    while !stop {
        let timeout =
            batcher.next_deadline(Instant::now()).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(FrontMsg::Submit { kind, pending }) => {
                if let Some(batch) = batcher.push(kind, pending) {
                    dispatch(batch, &depths, &metrics);
                }
            }
            Ok(FrontMsg::Shutdown) => break,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Drain the backlog BEFORE the expiry check: when producers are
        // faster than this loop, popped requests carry stale timestamps
        // and would each "expire" alone — batching them first is exactly
        // the dynamic-batching win (found by the perf_hotpath bench; see
        // EXPERIMENTS.md §Perf).
        loop {
            match rx.try_recv() {
                Ok(FrontMsg::Submit { kind, pending }) => {
                    if let Some(batch) = batcher.push(kind, pending) {
                        dispatch(batch, &depths, &metrics);
                    }
                }
                Ok(FrontMsg::Shutdown) => {
                    stop = true;
                    break;
                }
                Err(_) => break,
            }
        }
        for batch in batcher.flush_expired(Instant::now()) {
            dispatch(batch, &depths, &metrics);
        }
        metrics.queue_depth.store(batcher.pending() as u64, Ordering::Relaxed);
    }
    for batch in batcher.flush_all() {
        dispatch(batch, &depths, &metrics);
    }
    // Quiesce: close the worker queues, then wait until every in-flight
    // batch has been fully processed — a retiring worker decrements its
    // depth only AFTER requeueing, so depth 0 everywhere means no more
    // sends can arrive on the front channel (shutdown consumes the
    // Coordinator, so no client can be submitting concurrently either).
    // Bounded: a crashed worker never decrements, and must not turn
    // shutdown into a hang — after the deadline we drain what we have.
    drop(worker_txs);
    let quiesce_deadline = Instant::now() + Duration::from_secs(5);
    while depths.iter().any(|d| d.load(Ordering::Acquire) > 0) {
        if Instant::now() >= quiesce_deadline {
            eprintln!("coordinator: quiesce timed out with in-flight batches; draining anyway");
            break;
        }
        std::thread::sleep(Duration::from_micros(50));
    }
    // Final drain: requeued / raced-in submissions get an explicit error
    // result instead of a silently dropped reply channel.
    while let Ok(FrontMsg::Submit { kind, pending }) = rx.try_recv() {
        let batch = Batch { kind, items: vec![pending] };
        fail_batch(batch, &metrics, "coordinator shutting down");
    }
}

/// Send a retired worker's batch back for redistribution; if the batcher
/// is gone (shutdown), deliver explicit error results instead.
fn requeue_batch(batch: Batch, front: &Sender<FrontMsg>, metrics: &Metrics) {
    let kind = batch.kind;
    let mut undeliverable = Vec::new();
    for p in batch.items {
        if let Err(err) = front.send(FrontMsg::Submit { kind, pending: p }) {
            if let FrontMsg::Submit { pending, .. } = err.0 {
                undeliverable.push(pending);
            }
        }
    }
    if !undeliverable.is_empty() {
        let batch = Batch { kind, items: undeliverable };
        fail_batch(batch, metrics, "worker retired during shutdown");
    }
}

/// Worker-local memo over the shared [`PlanCache`].
type PlanMemo = std::collections::HashMap<(FunctionKind, TmrMode), Arc<CompiledFunction>>;

/// Resolve the compiled plan for `(kind, tmr)` through the worker-local
/// memo, filling it from the process-wide cache on a miss.
fn resolve_plan(
    local: &mut PlanMemo,
    plans: &PlanCache,
    kind: FunctionKind,
    rows: usize,
    cols: usize,
    tmr: TmrMode,
    sched: ScheduleConfig,
) -> Result<Arc<CompiledFunction>> {
    // The memo key omits `sched`: it is coordinator-config-constant for
    // the life of the worker, unlike the TMR mode (escalation switches
    // that at runtime).
    if let Some(cf) = local.get(&(kind, tmr)) {
        return Ok(cf.clone());
    }
    let cf = plans.get(kind, rows, cols, tmr, sched)?;
    local.insert((kind, tmr), cf.clone());
    Ok(cf)
}

/// Record the worker-side stage spans for one sampled request: the
/// batcher wait, then the execution window split into its disjoint
/// reliability stages (ECC verify, the possibly-TMR-replicated
/// compute, readback) with marshalling as the [`Stage::WorkerExec`]
/// remainder — laid end to end, so the request's stage durations sum
/// to at most its end-to-end latency.
fn record_exec_spans(
    tracer: &Tracer,
    item: &Pending,
    exec_start: Instant,
    exec_ns: u64,
    res: &VectorResult,
) {
    let wait_start = tracer.ns_of(item.submitted);
    let exec_start_ns = tracer.ns_of(exec_start);
    let wait = exec_start_ns.saturating_sub(wait_start);
    tracer.record(item.trace, Stage::BatcherWait, wait_start, wait);
    let reliability = res.ecc_ns + res.compute_ns + res.readback_ns;
    let mut at = exec_start_ns;
    for (stage, dur) in [
        (Stage::WorkerExec, exec_ns.saturating_sub(reliability)),
        (Stage::EccVerify, res.ecc_ns),
        (Stage::TmrVote, res.compute_ns),
        (Stage::Readback, res.readback_ns),
    ] {
        tracer.record(item.trace, stage, at, dur);
        at += dur;
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    worker_id: usize,
    cfg: CoordinatorConfig,
    rx: Receiver<Batch>,
    metrics: Arc<Metrics>,
    depths: Arc<Vec<AtomicU64>>,
    plans: Arc<PlanCache>,
    front_tx: Sender<FrontMsg>,
    healthy: Arc<Vec<AtomicBool>>,
    spares: Arc<Mutex<Vec<usize>>>,
    tracer: Arc<Tracer>,
    journal: Arc<EventJournal>,
) {
    let mmpu_cfg = MmpuConfig {
        rows: cfg.rows,
        cols: cfg.cols,
        num_crossbars: 1,
        policy: cfg.policy,
        errors: cfg.errors,
        seed: cfg.seed.wrapping_add(worker_id as u64),
        schedule: cfg.schedule,
    };
    let mut mmpu = Mmpu::new(mmpu_cfg);
    if let Some(h) = &cfg.health {
        let mut hcfg = h.clone();
        // Independent fault streams per worker.
        hcfg.seed = hcfg.seed.wrapping_add(worker_id as u64).wrapping_mul(0x9E37_79B9);
        mmpu.enable_health(hcfg);
    }
    // The live policy: starts at the configured base, escalated by the
    // health manager as telemetry accumulates and stepped back when a
    // configured `deescalate_after` clean streak elapses. (When an
    // escalated TMR mode turns out not to fit a served function on this
    // crossbar shape, TMR escalation is blocked and the worker keeps
    // its ECC escalation only.)
    let mut policy = cfg.policy;
    let mut tmr_escalation_blocked = false;
    let mut escalation_err_logged = false;
    let mut retired = false;
    // Per-worker memo over the shared cache: the shared PlanCache mutex
    // is touched once per (worker, kind, mode); steady-state batches
    // resolve their plan from this local map with no cross-worker
    // synchronization. (Keyed by TMR mode too: escalation switches it.)
    let mut local = PlanMemo::new();
    while let Ok(batch) = rx.recv() {
        if retired {
            // §Health: redistribute — this crossbar no longer executes.
            // The depth decrement comes AFTER the requeue sends: the
            // batcher's shutdown quiesce loop waits for all depths to
            // hit zero before its final front-channel drain, so every
            // requeued item is guaranteed to be drained, not dropped.
            requeue_batch(batch, &front_tx, &metrics);
            depths[worker_id].fetch_sub(1, Ordering::Release);
            continue;
        }
        let t0 = Instant::now();
        let a: Vec<u64> = batch.items.iter().map(|p| p.a).collect();
        let b: Vec<u64> = batch.items.iter().map(|p| p.b).collect();
        // Shared compiled plan: synthesized + validated once per
        // (kind, shape, tmr) process-wide, memoized per worker.
        let mut plan = resolve_plan(
            &mut local,
            &plans,
            batch.kind,
            cfg.rows,
            cfg.cols,
            policy.tmr,
            cfg.schedule,
        );
        // §Health: an escalated TMR mode may not fit every function on
        // this crossbar shape (e.g. serial TMR's extra output copies on
        // narrow arrays). Rather than bricking a previously working
        // worker, drop the TMR escalation (keep ECC) and retry.
        if plan.is_err() && policy.tmr != cfg.policy.tmr {
            eprintln!(
                "worker {worker_id}: escalated {:?} does not fit {:?}; \
                 blocking TMR escalation",
                policy.tmr, batch.kind
            );
            tmr_escalation_blocked = true;
            let fallback = ReliabilityPolicy { ecc_m: policy.ecc_m, tmr: cfg.policy.tmr };
            if mmpu.set_policy(fallback).is_ok() {
                policy = fallback;
                plan = resolve_plan(
                    &mut local,
                    &plans,
                    batch.kind,
                    cfg.rows,
                    cfg.cols,
                    policy.tmr,
                    cfg.schedule,
                );
            }
        }
        let result = plan.and_then(|cf| {
            let res = mmpu.exec_vector_compiled(0, &cf, &a, &b)?;
            Ok((cf, res))
        });
        match result {
            Ok((cf, res)) => {
                // §Perf packing telemetry: micro-ops vs. cycles actually
                // issued for this batch's plan (ratio = packing factor).
                metrics.record_plan(cf.tmr.num_ops() as u64, cf.tmr.num_bundles() as u64);
                let exec_ns = t0.elapsed().as_nanos() as u64;
                let tracing = tracer.sample_n() != 0;
                for (item, &value) in batch.items.iter().zip(&res.values) {
                    let latency = item.submitted.elapsed();
                    metrics.record_latency(latency);
                    metrics.record_kind_completed(batch.kind);
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    if tracing && tracer.sampled(item.trace) {
                        record_exec_spans(&tracer, item, t0, exec_ns, &res);
                    }
                    let _ = item.reply.send(RequestResult { value, latency, error: None });
                }
            }
            Err(e) => {
                // Deliver an explicit error result per item — clients
                // must never hang on a silently closed channel.
                let msg = format!("{e:#}");
                eprintln!(
                    "worker {worker_id}: batch of {} {:?} failed: {msg}",
                    batch.items.len(),
                    batch.kind
                );
                metrics.record_kind_failed(batch.kind, batch.items.len() as u64);
                for item in &batch.items {
                    let latency = item.submitted.elapsed();
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = item.reply.send(RequestResult {
                        value: 0,
                        latency,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
        // §Health maintenance between batches: scrub on schedule,
        // escalate the policy when telemetry warrants, publish the
        // per-worker report, and retire when the manager says so.
        if cfg.health.is_some() {
            if mmpu.scrub_due(0) {
                if let Some(rep) = mmpu.health_scrub(0) {
                    let w = worker_id as u32;
                    let eventful =
                        rep.corrected + rep.uncorrectable + rep.detected + rep.remapped > 0;
                    if eventful {
                        journal.record(EventKind::Scrub {
                            worker: w,
                            corrected: rep.corrected,
                            detected: rep.detected.min(u32::MAX as u64) as u32,
                            remapped: rep.remapped.min(u32::MAX as u64) as u32,
                        });
                    }
                    if rep.detected > 0 {
                        journal.record(EventKind::StuckCell { worker: w, cells: rep.detected });
                    }
                    if rep.remapped > 0 {
                        journal.record(EventKind::RowRemap { worker: w, rows: rep.remapped });
                    }
                }
            }
            // Recommendations build on the *configured base* policy:
            // escalation adds to it, and a de-escalation streak walks
            // back toward it (passing the live escalated policy instead
            // would make every escalation permanent).
            let decision = mmpu.health(0).map(|h| {
                (h.recommended_policy(cfg.policy), h.stats(), h.should_retire())
            });
            if let Some((mut rec, hstats, retire)) = decision {
                if tmr_escalation_blocked {
                    rec.tmr = policy.tmr;
                }
                if rec.ecc_m != policy.ecc_m || rec.tmr != policy.tmr {
                    match mmpu.set_policy(rec) {
                        Ok(()) => {
                            eprintln!("worker {worker_id}: policy change {policy:?} -> {rec:?}");
                            let level = |p: &ReliabilityPolicy| {
                                (p.ecc_m.is_some() as u8) + (p.tmr != TmrMode::Off) as u8
                            };
                            let (old, new) = (level(&policy), level(&rec));
                            let w = worker_id as u32;
                            if new > old {
                                journal.record(EventKind::PolicyEscalate { worker: w, level: new });
                            } else if new < old {
                                journal
                                    .record(EventKind::PolicyDeescalate { worker: w, level: new });
                            }
                            policy = rec;
                        }
                        Err(e) if !escalation_err_logged => {
                            escalation_err_logged = true;
                            eprintln!("worker {worker_id}: cannot escalate to {rec:?}: {e:#}");
                        }
                        Err(_) => {}
                    }
                }
                if retire && !retired {
                    retired = true;
                    // Activate a cold spare (if any) BEFORE dropping out
                    // of routing, so fleet capacity never transiently
                    // hits zero while spares remain; this worker's
                    // queued batches then requeue onto the spare.
                    let activated = spares.lock().unwrap().pop();
                    if let Some(spare) = activated {
                        healthy[spare].store(true, Ordering::Release);
                    }
                    healthy[worker_id].store(false, Ordering::Relaxed);
                    journal.record(EventKind::WorkerRetire { worker: worker_id as u32 });
                    if let Some(spare) = activated {
                        journal.record(EventKind::SparePromote { unit: spare as u32 });
                    }
                    eprintln!(
                        "worker {worker_id}: crossbar retired \
                         ({} stuck cells detected, {} spares left){}",
                        hstats.stuck_detected,
                        hstats.spares_left,
                        match activated {
                            Some(s) => format!("; hot-spare worker {s} activated"),
                            None => String::new(),
                        }
                    );
                }
                metrics.set_worker_health(
                    worker_id,
                    WorkerHealth {
                        batches: hstats.batches,
                        scrubs: hstats.scrub_passes,
                        corrected: hstats.drift_corrected + hstats.scrub_corrected,
                        uncorrectable: hstats.scrub_uncorrectable,
                        stuck_detected: hstats.stuck_detected,
                        remapped_rows: hstats.remapped_rows,
                        spares_left: hstats.spares_left,
                        policy_level: (policy.ecc_m.is_some() as u8)
                            + (policy.tmr != TmrMode::Off) as u8,
                        retired,
                    },
                );
            }
        }
        metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        depths[worker_id].fetch_sub(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small_batch() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 16,
            cols: 256,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        })
        .unwrap();
        let rxs: Vec<_> =
            (0..32u64).map(|i| (i, coord.submit(FunctionKind::Add(8), i, 2 * i))).collect();
        for (i, rx) in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("result");
            assert_eq!(r.value, 3 * i, "request {i}");
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 32);
        assert!(m.mean_batch_size() >= 1.0);
        coord.shutdown();
    }

    #[test]
    fn mixed_kinds_route_correctly() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 8,
            cols: 512,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let adds: Vec<_> = (0..8u64).map(|i| coord.submit(FunctionKind::Add(8), i, 1)).collect();
        let muls: Vec<_> =
            (0..8u64).map(|i| coord.submit(FunctionKind::Mul(8), i, 3)).collect();
        for (i, rx) in adds.into_iter().enumerate() {
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, i as u64 + 1);
        }
        for (i, rx) in muls.into_iter().enumerate() {
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, i as u64 * 3);
        }
        coord.shutdown();
    }

    #[test]
    fn failed_batches_deliver_error_results() {
        // 64 columns cannot hold a 16-bit MultPIM (needs ~256): every
        // request must come back with an explicit error result instead
        // of a dropped channel, and be counted in metrics.failed.
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 16,
            cols: 64,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let rxs: Vec<_> =
            (0..24u64).map(|i| coord.submit(FunctionKind::Mul(16), i, i + 1)).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("error result, not a hang");
            assert!(!r.is_ok(), "expected an error result");
            let msg = r.error.as_deref().unwrap();
            assert!(
                msg.contains("out of range") || msg.contains("too narrow") || msg.contains("beyond"),
                "unexpected error: {msg:?}"
            );
        }
        let m = coord.metrics();
        assert_eq!(m.failed, 24);
        assert_eq!(m.completed, 0);
        coord.shutdown();
        // Small functions still work on the same shape (Add(8) fits).
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            rows: 16,
            cols: 64,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let rx = coord.submit(FunctionKind::Add(8), 2, 3);
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok());
        assert_eq!(r.value, 5);
        coord.shutdown();
    }

    #[test]
    fn is_serving_tracks_routable_capacity() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            rows: 16,
            cols: 256,
            ..Default::default()
        })
        .unwrap();
        assert!(coord.is_serving());
        assert_eq!(coord.healthy_workers(), 1);
        coord.shutdown();
        // Zero workers (and no spares): nothing routable from the start.
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 0,
            rows: 16,
            cols: 256,
            ..Default::default()
        })
        .unwrap();
        assert!(!coord.is_serving());
        assert_eq!(coord.healthy_workers(), 0);
        coord.shutdown();
        // Cold spares are not routable capacity until a retirement
        // activates them.
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 0,
            spare_workers: 2,
            rows: 16,
            cols: 256,
            ..Default::default()
        })
        .unwrap();
        assert!(!coord.is_serving());
        assert_eq!(coord.metrics().worker_health.len(), 2, "spares visible in health table");
        coord.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            rows: 64,
            cols: 256,
            max_batch: 64,             // never fills
            max_wait: Duration::from_secs(60), // never expires
            ..Default::default()
        })
        .unwrap();
        let rx = coord.submit(FunctionKind::Add(8), 20, 22);
        coord.shutdown(); // must flush the partial batch
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, 42);
    }
}
