//! The coordinator front end: submit scalar requests, get results back
//! through per-request channels; a batcher thread groups them and routes
//! batches to worker threads (one crossbar each, least-loaded first).
//!
//! §Perf: workers share one [`PlanCache`] — each `(function, shape,
//! TMR mode)` is synthesized, TMR-expanded and plan-compiled exactly
//! once process-wide (`Arc`-shared), and batch execution goes through
//! the word-parallel `Mmpu::exec_vector_compiled` path. Failed batches
//! deliver an explicit error result per item (clients never observe a
//! silently closed channel) and are counted in `metrics.failed`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::errs::ErrorModel;
use crate::mmpu::{FunctionKind, Mmpu, MmpuConfig, PlanCache, ReliabilityPolicy};

use super::batcher::{Batch, Batcher, Pending};
use super::metrics::{Metrics, MetricsSnapshot};

/// Outcome delivered to the submitting client.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// The function result (0 when `error` is set).
    pub value: u64,
    pub latency: Duration,
    /// Present when the batch failed to compile or execute: the per-item
    /// error delivered instead of silently dropping the reply channel.
    pub error: Option<String>,
}

impl RequestResult {
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub rows: usize,
    pub cols: usize,
    pub policy: ReliabilityPolicy,
    pub errors: ErrorModel,
    pub seed: u64,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bounded per-worker queue (backpressure).
    pub worker_queue: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            rows: 64,
            cols: 1024,
            policy: ReliabilityPolicy::none(),
            errors: ErrorModel::none(),
            seed: 0xC0,
            max_batch: 64,
            max_wait: Duration::from_micros(500),
            worker_queue: 8,
        }
    }
}

enum FrontMsg {
    Submit { kind: FunctionKind, pending: Pending },
    Shutdown,
}

/// The running coordinator.
pub struct Coordinator {
    front_tx: Sender<FrontMsg>,
    metrics: Arc<Metrics>,
    batcher_handle: Option<JoinHandle<()>>,
    worker_handles: Vec<JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let metrics = Arc::new(Metrics::new());
        // One compiled-plan cache shared by every worker: each
        // (kind, shape, tmr) compiles once process-wide (§Perf).
        let plans = Arc::new(PlanCache::new());
        // Workers.
        let mut worker_txs: Vec<SyncSender<Batch>> = vec![];
        let mut worker_handles = vec![];
        let depths: Arc<Vec<AtomicU64>> =
            Arc::new((0..cfg.workers).map(|_| AtomicU64::new(0)).collect());
        for w in 0..cfg.workers {
            let (tx, rx) = std::sync::mpsc::sync_channel::<Batch>(cfg.worker_queue);
            worker_txs.push(tx);
            let m = metrics.clone();
            let d = depths.clone();
            let cfg2 = cfg.clone();
            let p = plans.clone();
            worker_handles.push(std::thread::spawn(move || worker_loop(w, cfg2, rx, m, d, p)));
        }
        // Batcher / router.
        let (front_tx, front_rx) = channel::<FrontMsg>();
        let m = metrics.clone();
        let cfg2 = cfg.clone();
        let batcher_handle =
            std::thread::spawn(move || batcher_loop(cfg2, front_rx, worker_txs, m, depths));
        Ok(Self { front_tx, metrics, batcher_handle: Some(batcher_handle), worker_handles })
    }

    /// Submit one scalar request; the receiver yields the result.
    pub fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult> {
        let (tx, rx) = channel();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let _ = self.front_tx.send(FrontMsg::Submit {
            kind,
            pending: Pending { a, b, reply: tx, submitted: Instant::now() },
        });
        rx
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) {
        let _ = self.front_tx.send(FrontMsg::Shutdown);
        if let Some(h) = self.batcher_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn batcher_loop(
    cfg: CoordinatorConfig,
    rx: Receiver<FrontMsg>,
    worker_txs: Vec<SyncSender<Batch>>,
    metrics: Arc<Metrics>,
    depths: Arc<Vec<AtomicU64>>,
) {
    let mut batcher = Batcher::new(cfg.max_batch.min(cfg.rows), cfg.max_wait);
    let dispatch = |batch: Batch, depths: &Arc<Vec<AtomicU64>>, metrics: &Arc<Metrics>| {
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_items.fetch_add(batch.items.len() as u64, Ordering::Relaxed);
        // Route to the least-loaded worker; block if all queues are full
        // (backpressure propagates to the batcher, then to clients).
        let mut batch = batch;
        loop {
            let (widx, _) = depths
                .iter()
                .enumerate()
                .min_by_key(|(_, d)| d.load(Ordering::Relaxed))
                .expect("workers");
            depths[widx].fetch_add(1, Ordering::Relaxed);
            match worker_txs[widx].try_send(batch) {
                Ok(()) => return,
                Err(TrySendError::Full(b)) => {
                    depths[widx].fetch_sub(1, Ordering::Relaxed);
                    batch = b;
                    std::thread::yield_now();
                }
                Err(TrySendError::Disconnected(_)) => return,
            }
        }
    };
    let mut stop = false;
    while !stop {
        let timeout =
            batcher.next_deadline(Instant::now()).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(FrontMsg::Submit { kind, pending }) => {
                if let Some(batch) = batcher.push(kind, pending) {
                    dispatch(batch, &depths, &metrics);
                }
            }
            Ok(FrontMsg::Shutdown) => break,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
        // Drain the backlog BEFORE the expiry check: when producers are
        // faster than this loop, popped requests carry stale timestamps
        // and would each "expire" alone — batching them first is exactly
        // the dynamic-batching win (found by the perf_hotpath bench; see
        // EXPERIMENTS.md §Perf).
        loop {
            match rx.try_recv() {
                Ok(FrontMsg::Submit { kind, pending }) => {
                    if let Some(batch) = batcher.push(kind, pending) {
                        dispatch(batch, &depths, &metrics);
                    }
                }
                Ok(FrontMsg::Shutdown) => {
                    stop = true;
                    break;
                }
                Err(_) => break,
            }
        }
        for batch in batcher.flush_expired(Instant::now()) {
            dispatch(batch, &depths, &metrics);
        }
        metrics.queue_depth.store(batcher.pending() as u64, Ordering::Relaxed);
    }
    for batch in batcher.flush_all() {
        dispatch(batch, &depths, &metrics);
    }
    // Dropping worker_txs closes worker queues.
}

fn worker_loop(
    worker_id: usize,
    cfg: CoordinatorConfig,
    rx: Receiver<Batch>,
    metrics: Arc<Metrics>,
    depths: Arc<Vec<AtomicU64>>,
    plans: Arc<PlanCache>,
) {
    let mmpu_cfg = MmpuConfig {
        rows: cfg.rows,
        cols: cfg.cols,
        num_crossbars: 1,
        policy: cfg.policy,
        errors: cfg.errors,
        seed: cfg.seed.wrapping_add(worker_id as u64),
    };
    let mut mmpu = Mmpu::new(mmpu_cfg);
    // Per-worker memo over the shared cache: the shared PlanCache mutex
    // is touched once per (worker, kind); steady-state batches resolve
    // their plan from this local map with no cross-worker
    // synchronization. (Shape and TMR mode are fixed per coordinator,
    // so the local key is just the function kind.)
    let mut local: std::collections::HashMap<FunctionKind, Arc<crate::mmpu::CompiledFunction>> =
        std::collections::HashMap::new();
    while let Ok(batch) = rx.recv() {
        let t0 = Instant::now();
        let a: Vec<u64> = batch.items.iter().map(|p| p.a).collect();
        let b: Vec<u64> = batch.items.iter().map(|p| p.b).collect();
        // Shared compiled plan: synthesized + validated once per
        // (kind, shape, tmr) process-wide, memoized per worker.
        let plan = match local.get(&batch.kind) {
            Some(cf) => Ok(cf.clone()),
            None => plans.get(batch.kind, cfg.rows, cfg.cols, cfg.policy.tmr).map(|cf| {
                local.insert(batch.kind, cf.clone());
                cf
            }),
        };
        let result = plan.and_then(|cf| mmpu.exec_vector_compiled(0, &cf, &a, &b));
        match result {
            Ok(res) => {
                for (item, &value) in batch.items.iter().zip(&res.values) {
                    let latency = item.submitted.elapsed();
                    metrics.record_latency(latency);
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    let _ = item.reply.send(RequestResult { value, latency, error: None });
                }
            }
            Err(e) => {
                // Deliver an explicit error result per item — clients
                // must never hang on a silently closed channel.
                let msg = format!("{e:#}");
                eprintln!(
                    "worker {worker_id}: batch of {} {:?} failed: {msg}",
                    batch.items.len(),
                    batch.kind
                );
                for item in &batch.items {
                    let latency = item.submitted.elapsed();
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = item.reply.send(RequestResult {
                        value: 0,
                        latency,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
        metrics.busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        depths[worker_id].fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_small_batch() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 16,
            cols: 256,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            ..Default::default()
        })
        .unwrap();
        let rxs: Vec<_> =
            (0..32u64).map(|i| (i, coord.submit(FunctionKind::Add(8), i, 2 * i))).collect();
        for (i, rx) in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("result");
            assert_eq!(r.value, 3 * i, "request {i}");
        }
        let m = coord.metrics();
        assert_eq!(m.completed, 32);
        assert!(m.mean_batch_size() >= 1.0);
        coord.shutdown();
    }

    #[test]
    fn mixed_kinds_route_correctly() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 8,
            cols: 512,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let adds: Vec<_> = (0..8u64).map(|i| coord.submit(FunctionKind::Add(8), i, 1)).collect();
        let muls: Vec<_> =
            (0..8u64).map(|i| coord.submit(FunctionKind::Mul(8), i, 3)).collect();
        for (i, rx) in adds.into_iter().enumerate() {
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, i as u64 + 1);
        }
        for (i, rx) in muls.into_iter().enumerate() {
            assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, i as u64 * 3);
        }
        coord.shutdown();
    }

    #[test]
    fn failed_batches_deliver_error_results() {
        // 64 columns cannot hold a 16-bit MultPIM (needs ~256): every
        // request must come back with an explicit error result instead
        // of a dropped channel, and be counted in metrics.failed.
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 2,
            rows: 16,
            cols: 64,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let rxs: Vec<_> =
            (0..24u64).map(|i| coord.submit(FunctionKind::Mul(16), i, i + 1)).collect();
        for rx in rxs {
            let r = rx.recv_timeout(Duration::from_secs(5)).expect("error result, not a hang");
            assert!(!r.is_ok(), "expected an error result");
            let msg = r.error.as_deref().unwrap();
            assert!(
                msg.contains("out of range") || msg.contains("too narrow") || msg.contains("beyond"),
                "unexpected error: {msg:?}"
            );
        }
        let m = coord.metrics();
        assert_eq!(m.failed, 24);
        assert_eq!(m.completed, 0);
        coord.shutdown();
        // Small functions still work on the same shape (Add(8) fits).
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            rows: 16,
            cols: 64,
            max_batch: 8,
            max_wait: Duration::from_micros(100),
            ..Default::default()
        })
        .unwrap();
        let rx = coord.submit(FunctionKind::Add(8), 2, 3);
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.is_ok());
        assert_eq!(r.value, 5);
        coord.shutdown();
    }

    #[test]
    fn shutdown_flushes_pending() {
        let coord = Coordinator::start(CoordinatorConfig {
            workers: 1,
            rows: 64,
            cols: 256,
            max_batch: 64,             // never fills
            max_wait: Duration::from_secs(60), // never expires
            ..Default::default()
        })
        .unwrap();
        let rx = coord.submit(FunctionKind::Add(8), 20, 22);
        coord.shutdown(); // must flush the partial batch
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)).unwrap().value, 42);
    }
}
