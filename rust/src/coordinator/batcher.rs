//! Dynamic batching: group same-function scalar requests into
//! row-parallel crossbar batches.
//!
//! Policy: flush a function's pending queue when it reaches
//! `max_batch` (a full crossbar) or when its oldest request has waited
//! `max_wait` (tail-latency bound) — the classic dynamic-batching
//! trade-off, applied to crossbar rows instead of GPU sequences.

use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::time::{Duration, Instant};

use crate::mmpu::FunctionKind;

/// One pending scalar request.
pub struct Pending {
    pub a: u64,
    pub b: u64,
    pub reply: Sender<super::server::RequestResult>,
    pub submitted: Instant,
    /// Trace id minted at the submitter (0 = untraced). Carried through
    /// the batch so the worker can attribute stage spans to the request.
    pub trace: u64,
}

/// A flushed batch ready for a worker.
pub struct Batch {
    pub kind: FunctionKind,
    pub items: Vec<Pending>,
}

/// Accumulates pending requests per function kind.
pub struct Batcher {
    queues: HashMap<FunctionKind, Vec<Pending>>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self { queues: HashMap::new(), max_batch, max_wait }
    }

    /// Add a request; returns a full batch if one is ready.
    pub fn push(&mut self, kind: FunctionKind, p: Pending) -> Option<Batch> {
        let q = self.queues.entry(kind).or_default();
        q.push(p);
        if q.len() >= self.max_batch {
            let items = std::mem::take(q);
            Some(Batch { kind, items })
        } else {
            None
        }
    }

    /// Flush queues whose oldest request exceeded max_wait.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let mut out = vec![];
        for (&kind, q) in self.queues.iter_mut() {
            if let Some(first) = q.first() {
                if now.duration_since(first.submitted) >= self.max_wait {
                    out.push(Batch { kind, items: std::mem::take(q) });
                }
            }
        }
        out
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        self.queues
            .iter_mut()
            .filter(|(_, q)| !q.is_empty())
            .map(|(&kind, q)| Batch { kind, items: std::mem::take(q) })
            .collect()
    }

    /// Time until the next deadline (for the event-loop timeout).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| {
                let age = now.duration_since(p.submitted);
                self.max_wait.saturating_sub(age)
            })
            .min()
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn pending(at: Instant) -> Pending {
        let (tx, _rx) = channel();
        Pending { a: 1, b: 2, reply: tx, submitted: at, trace: 0 }
    }

    #[test]
    fn full_batch_flushes() {
        let mut b = Batcher::new(3, Duration::from_millis(10));
        let now = Instant::now();
        assert!(b.push(FunctionKind::Add(8), pending(now)).is_none());
        assert!(b.push(FunctionKind::Add(8), pending(now)).is_none());
        let batch = b.push(FunctionKind::Add(8), pending(now)).expect("full");
        assert_eq!(batch.items.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn kinds_batch_separately() {
        let mut b = Batcher::new(2, Duration::from_millis(10));
        let now = Instant::now();
        assert!(b.push(FunctionKind::Add(8), pending(now)).is_none());
        assert!(b.push(FunctionKind::Mul(8), pending(now)).is_none());
        assert!(b.push(FunctionKind::Mul(8), pending(now)).is_some());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn expiry_flushes_partial() {
        let mut b = Batcher::new(100, Duration::from_millis(5));
        let past = Instant::now() - Duration::from_millis(50);
        b.push(FunctionKind::Xor(8), pending(past));
        let flushed = b.flush_expired(Instant::now());
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].items.len(), 1);
    }

    #[test]
    fn expiry_boundary_exactly_at_deadline() {
        // The comparison is `>=`, not `>`: the batcher event loop wakes
        // at now == submitted + max_wait (next_deadline returns zero
        // remaining), and that wakeup must flush rather than spin.
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(100, wait);
        let at = Instant::now();
        b.push(FunctionKind::Add(8), pending(at));
        let just_before = at + (wait - Duration::from_nanos(1));
        assert!(b.flush_expired(just_before).is_empty(), "one ns early must not flush");
        assert_eq!(b.pending(), 1);
        assert_eq!(b.next_deadline(at + wait), Some(Duration::ZERO));
        let flushed = b.flush_expired(at + wait);
        assert_eq!(flushed.len(), 1, "deadline exactly at now flushes");
        assert_eq!(flushed[0].items.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_tracking() {
        let mut b = Batcher::new(100, Duration::from_millis(100));
        assert!(b.next_deadline(Instant::now()).is_none());
        let now = Instant::now();
        b.push(FunctionKind::Add(8), pending(now));
        let d = b.next_deadline(now).unwrap();
        assert!(d <= Duration::from_millis(100));
    }

    #[test]
    fn flush_all_drains() {
        let mut b = Batcher::new(100, Duration::from_secs(1));
        let now = Instant::now();
        b.push(FunctionKind::Add(8), pending(now));
        b.push(FunctionKind::Mul(8), pending(now));
        assert_eq!(b.flush_all().len(), 2);
        assert_eq!(b.pending(), 0);
    }
}
