//! The request-path coordinator — Layer 3 proper.
//!
//! A vLLM-router-style front end for the mMPU: clients submit scalar
//! arithmetic requests; the **batcher** groups same-function requests
//! into row-parallel batches (the mMPU's throughput comes from filling
//! crossbar rows); the **router** dispatches batches to the least-loaded
//! worker; each **worker** thread owns one crossbar (its own error
//! stream and ECC extension) and executes batches under the configured
//! reliability policy. Bounded queues give natural backpressure.
//! With `CoordinatorConfig::health` set, workers additionally run the
//! §Health fault manager: background scrubbing, adaptive policy
//! escalation, and crossbar retirement with request redistribution
//! (per-worker health lands in [`MetricsSnapshot`]).
//!
//! tokio is not in the offline vendor set (DESIGN.md substitutions):
//! the implementation uses std threads + mpsc channels; the
//! batching/routing logic is runtime-agnostic.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use metrics::{MetricsSnapshot, WorkerHealth};
pub use server::{Coordinator, CoordinatorConfig, RequestResult};
