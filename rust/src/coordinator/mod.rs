//! The request-path coordinator — Layer 3 proper.
//!
//! A vLLM-router-style front end for the mMPU: clients submit scalar
//! arithmetic requests; the **batcher** groups same-function requests
//! into row-parallel batches (the mMPU's throughput comes from filling
//! crossbar rows); the **router** dispatches batches to the least-loaded
//! worker; each **worker** thread owns one crossbar (its own error
//! stream and ECC extension) and executes batches under the configured
//! reliability policy. Bounded queues give natural backpressure.
//! With `CoordinatorConfig::health` set, workers additionally run the
//! §Health fault manager: background scrubbing, adaptive policy
//! escalation, and crossbar retirement with request redistribution
//! (per-worker health lands in [`MetricsSnapshot`]).
//!
//! tokio is not in the offline vendor set (DESIGN.md substitutions):
//! the implementation uses std threads + mpsc channels; the
//! batching/routing logic is runtime-agnostic.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use metrics::{render_prometheus, KindStats, MetricsSnapshot, WorkerHealth};
pub use server::{Coordinator, CoordinatorConfig, NO_CAPACITY_ERROR, RequestResult};

use std::sync::mpsc::Receiver;

use crate::mmpu::FunctionKind;

/// Transport-agnostic request submission (§Scale).
///
/// Implemented by the in-process [`Coordinator`] and by the remote
/// [`crate::fabric::Router`], so load generators — `examples/serve.rs`,
/// `remus soak`, benches — run unchanged against a local fleet or a
/// sharded multi-process fabric.
pub trait Submitter {
    /// Submit one scalar request; the receiver yields exactly one
    /// [`RequestResult`] (a value or an explicit error — never a hang).
    fn submit(&self, kind: FunctionKind, a: u64, b: u64) -> Receiver<RequestResult>;

    /// Point-in-time metrics. For a sharded implementation this is the
    /// merged fleet view (see [`MetricsSnapshot::merge`]).
    fn metrics(&self) -> MetricsSnapshot;

    /// Non-blocking capacity probe: false once no healthy executor
    /// remains (all crossbars retired / all shards down), so callers can
    /// mark the target down without burning a request.
    fn is_serving(&self) -> bool;
}
