//! Lock-free coordinator metrics (atomics + log-scale latency histogram)
//! plus per-worker health reports (§Health; mutex-guarded, updated once
//! per batch by the owning worker only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::mmpu::functions::{FunctionKind, KIND_FAMILIES};

/// Number of log2 latency bins (1us ... ~1s).
const BINS: usize = 24;

/// Log2 bin index for a microsecond latency: bin i counts latencies in
/// `[2^i, 2^(i+1))`, clamped to `nbins`. Shared by the coordinator
/// metrics and `fabric::loadgen`'s histograms so their bin edges can
/// never drift apart. The clamp silently folds latencies ≥ the top bin
/// edge into the top bin — callers that care (both histogram owners)
/// check [`log2_bin_overflows`] and keep an explicit overflow count
/// plus the exact observed max alongside the bins.
pub fn log2_bin_us(us: u64, nbins: usize) -> usize {
    let us = us.max(1);
    (63 - us.leading_zeros() as usize).min(nbins - 1)
}

/// True when `us` lands past the top bin edge (`2^nbins` µs) and
/// [`log2_bin_us`] would clamp it — i.e. the histogram under-reports.
pub fn log2_bin_overflows(us: u64, nbins: usize) -> bool {
    nbins < 64 && us >= 1u64 << nbins
}

/// Percentile estimate over log2 latency bins (upper bin edge,
/// microseconds; 0 when empty) — the single estimator behind
/// [`MetricsSnapshot::latency_percentile_us`] and
/// `fabric::loadgen::LatencyHisto::percentile_us`.
pub fn log2_percentile_us(bins: &[u64], pct: f64) -> u64 {
    let total: u64 = bins.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * pct / 100.0).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in bins.iter().enumerate() {
        seen += c;
        if seen >= target {
            return 1u64 << (i + 1);
        }
    }
    1u64 << bins.len()
}

/// [`log2_percentile_us`] made honest about the histogram's edges
/// using the exact observed max and the top-bin overflow count kept
/// alongside the bins (by both `Metrics` and `fabric::loadgen`):
/// a percentile rank that falls among the `overflow` clamped samples
/// reports the exact max (the bins genuinely don't know better), and
/// any estimate is capped at the exact max (an upper bin edge can
/// never beat the true extreme). With `max_us == 0` (pre-v5 peers)
/// this degrades to the raw estimate.
pub fn log2_percentile_exact_us(bins: &[u64], pct: f64, overflow: u64, max_us: u64) -> u64 {
    let total: u64 = bins.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * pct / 100.0).ceil() as u64;
    if max_us > 0 && overflow > 0 && target > total - overflow.min(total) {
        return max_us;
    }
    let est = log2_percentile_us(bins, pct);
    if max_us > 0 {
        est.min(max_us)
    } else {
        est
    }
}

/// Per-worker health summary exported through [`MetricsSnapshot`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerHealth {
    pub batches: u64,
    pub scrubs: u64,
    /// Drift bits corrected (serving-path ECC + scrub ECC).
    pub corrected: u64,
    /// Uncorrectable ECC blocks observed by scrubbing.
    pub uncorrectable: u64,
    pub stuck_detected: u64,
    pub remapped_rows: u64,
    pub spares_left: u64,
    /// Protection mechanisms active in the worker's *live* policy
    /// (ECC counts 1, TMR counts 1) — base protections included.
    pub policy_level: u8,
    pub retired: bool,
}

pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that received an explicit error result (failed batch
    /// execution/compilation, retirement, shutdown) instead of a value.
    pub failed: AtomicU64,
    /// Batches *dispatched* by the router. A batch redistributed after a
    /// worker retirement is dispatched again and counts again, so
    /// `batched_items` can exceed `submitted` during retirement storms.
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub busy_ns: AtomicU64,
    pub queue_depth: AtomicU64,
    /// Latencies that overflowed the top histogram bin (would have been
    /// silently clamped before this counter existed).
    pub lat_overflow: AtomicU64,
    /// Exact maximum latency observed, microseconds.
    pub lat_max_us: AtomicU64,
    /// §Perf list scheduling: micro-ops in the plans of executed
    /// batches (one increment per batch, by the plan's op count).
    pub plan_ops: AtomicU64,
    /// Cycle bundles those same plans issued; `plan_ops / plan_bundles`
    /// is the traffic-weighted packing factor (1.0 = serial plans).
    pub plan_bundles: AtomicU64,
    lat_bins: [AtomicU64; BINS],
    kind_submitted: [AtomicU64; KIND_FAMILIES],
    kind_completed: [AtomicU64; KIND_FAMILIES],
    kind_failed: [AtomicU64; KIND_FAMILIES],
    /// When this process started serving; snapshots stamp the elapsed
    /// time so readers can compute honest rates over a real interval.
    epoch: Instant,
    worker_health: Mutex<Vec<WorkerHealth>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            busy_ns: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            lat_overflow: AtomicU64::new(0),
            lat_max_us: AtomicU64::new(0),
            plan_ops: AtomicU64::new(0),
            plan_bundles: AtomicU64::new(0),
            lat_bins: std::array::from_fn(|_| AtomicU64::new(0)),
            kind_submitted: std::array::from_fn(|_| AtomicU64::new(0)),
            kind_completed: std::array::from_fn(|_| AtomicU64::new(0)),
            kind_failed: std::array::from_fn(|_| AtomicU64::new(0)),
            epoch: Instant::now(),
            worker_health: Mutex::new(Vec::new()),
        }
    }

    /// Size the per-worker health table (done once at coordinator start).
    pub fn init_workers(&self, n: usize) {
        *self.worker_health.lock().unwrap() = vec![WorkerHealth::default(); n];
    }

    pub fn set_worker_health(&self, worker: usize, h: WorkerHealth) {
        if let Some(slot) = self.worker_health.lock().unwrap().get_mut(worker) {
            *slot = h;
        }
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        if log2_bin_overflows(us, BINS) {
            self.lat_overflow.fetch_add(1, Ordering::Relaxed);
        }
        self.lat_max_us.fetch_max(us, Ordering::Relaxed);
        let bin = log2_bin_us(us, BINS);
        self.lat_bins[bin].fetch_add(1, Ordering::Relaxed);
    }

    /// Per-kind load attribution (indexed by [`FunctionKind::index`]).
    pub fn record_kind_submitted(&self, kind: FunctionKind) {
        self.kind_submitted[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_kind_completed(&self, kind: FunctionKind) {
        self.kind_completed[kind.index()].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_kind_failed(&self, kind: FunctionKind, n: u64) {
        self.kind_failed[kind.index()].fetch_add(n, Ordering::Relaxed);
    }

    /// §Perf: account one executed batch's plan — its micro-op count
    /// and the cycle bundles the scheduler issued them in.
    pub fn record_plan(&self, ops: u64, bundles: u64) {
        self.plan_ops.fetch_add(ops, Ordering::Relaxed);
        self.plan_bundles.fetch_add(bundles, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let bins: Vec<u64> = self.lat_bins.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let mut kind_stats = [KindStats::default(); KIND_FAMILIES];
        for (i, ks) in kind_stats.iter_mut().enumerate() {
            ks.submitted = self.kind_submitted[i].load(Ordering::Relaxed);
            ks.completed = self.kind_completed[i].load(Ordering::Relaxed);
            ks.failed = self.kind_failed[i].load(Ordering::Relaxed);
        }
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            lat_overflow: self.lat_overflow.load(Ordering::Relaxed),
            lat_max_us: self.lat_max_us.load(Ordering::Relaxed),
            plan_ops: self.plan_ops.load(Ordering::Relaxed),
            plan_bundles: self.plan_bundles.load(Ordering::Relaxed),
            lat_bins: bins,
            kind_stats,
            uptime_ns: self.epoch.elapsed().as_nanos() as u64,
            worker_health: self.worker_health.lock().unwrap().clone(),
            shards_total: 0,
            shards_down: 0,
            hb_pings: 0,
            hb_pongs: 0,
            hb_timeouts: 0,
            auth_rejects: 0,
        }
    }
}

/// Per-[`FunctionKind`]-family request counters (indexed by
/// [`FunctionKind::index`]; merge-additive across shards).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KindStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
}

/// Point-in-time copy for reporting. Public fields (including the raw
/// latency histogram) so the fabric wire codec can carry snapshots
/// across processes and the router can merge per-shard copies.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub busy_ns: u64,
    pub queue_depth: u64,
    /// Per-worker health (§Health; empty when no health manager is on).
    pub worker_health: Vec<WorkerHealth>,
    /// Log2-scale latency histogram (bin i counts latencies in
    /// `[2^i, 2^(i+1))` microseconds; see [`Metrics::record_latency`]).
    pub lat_bins: Vec<u64>,
    /// Latencies ≥ the top bin edge (clamped into the top bin above);
    /// nonzero means the histogram tail under-reports — read
    /// [`MetricsSnapshot::lat_max_us`] for the true extreme.
    pub lat_overflow: u64,
    /// Exact maximum observed latency, microseconds (max-merged).
    pub lat_max_us: u64,
    /// Time this process had been serving when the snapshot was taken
    /// (max-merged across shards, so a fleet view carries the oldest
    /// member's interval — honest QPS is `completed / uptime`).
    pub uptime_ns: u64,
    /// Per-kind-family submitted/completed/failed (merge-additive).
    pub kind_stats: [KindStats; KIND_FAMILIES],
    /// Fabric fleet membership (§Scale): shards known to the router
    /// that produced this view. A single coordinator reports 0 — the
    /// router stamps the merged snapshot, so a degraded fleet is
    /// distinguishable from a healthy smaller one.
    pub shards_total: u64,
    /// Shards currently out of ring routing (marked down, awaiting
    /// revival).
    pub shards_down: u64,
    /// Data-path heartbeats sent by the router that produced this view
    /// (§Scale, wire v3). A single coordinator reports 0.
    pub hb_pings: u64,
    /// `Pong` echoes received back on shard data connections.
    pub hb_pongs: u64,
    /// Shards marked down because a heartbeat deadline expired — the
    /// half-open-connection detector firing (distinct from disconnect
    /// or capacity failovers, which close the socket visibly).
    pub hb_timeouts: u64,
    /// Peers rejected by the fabric's authentication layer (§Security,
    /// wire v4): failed PSK handshakes, tampered/replayed sealed frames,
    /// plaintext traffic on an authenticated port. Counted by both the
    /// shard server and the router; a single coordinator reports 0.
    pub auth_rejects: u64,
    /// §Perf list scheduling (wire v7): micro-ops in the plans of
    /// executed batches (merge-additive; 0 from pre-v7 peers).
    pub plan_ops: u64,
    /// Cycle bundles those plans issued (merge-additive); see
    /// [`MetricsSnapshot::packing_factor`].
    pub plan_bundles: u64,
}

impl MetricsSnapshot {
    /// Fold another snapshot into this one (fabric router: aggregate the
    /// per-shard snapshots into one fleet view). Counters and latency
    /// bins add; worker health concatenates, so `worker_health[i]` is no
    /// longer a process-local worker index but the fleet-wide listing —
    /// `retired_workers()` et al. keep working on the merged view.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.batched_items += other.batched_items;
        self.busy_ns += other.busy_ns;
        self.queue_depth += other.queue_depth;
        if self.lat_bins.len() < other.lat_bins.len() {
            self.lat_bins.resize(other.lat_bins.len(), 0);
        }
        for (i, &b) in other.lat_bins.iter().enumerate() {
            self.lat_bins[i] += b;
        }
        self.lat_overflow += other.lat_overflow;
        self.lat_max_us = self.lat_max_us.max(other.lat_max_us);
        self.uptime_ns = self.uptime_ns.max(other.uptime_ns);
        for (s, o) in self.kind_stats.iter_mut().zip(other.kind_stats.iter()) {
            s.submitted += o.submitted;
            s.completed += o.completed;
            s.failed += o.failed;
        }
        self.worker_health.extend(other.worker_health.iter().cloned());
        // Membership and heartbeat counters add so nested merges
        // compose; per-shard snapshots carry 0 and the router stamps
        // the final view.
        self.shards_total += other.shards_total;
        self.shards_down += other.shards_down;
        self.hb_pings += other.hb_pings;
        self.hb_pongs += other.hb_pongs;
        self.hb_timeouts += other.hb_timeouts;
        self.auth_rejects += other.auth_rejects;
        self.plan_ops += other.plan_ops;
        self.plan_bundles += other.plan_bundles;
    }
    /// Workers that retired their crossbar.
    pub fn retired_workers(&self) -> usize {
        self.worker_health.iter().filter(|w| w.retired).count()
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Approximate latency percentile from the log histogram (upper bin
    /// edge, microseconds), made honest at the edges by the overflow
    /// count and exact observed max (see [`log2_percentile_exact_us`]).
    pub fn latency_percentile_us(&self, pct: f64) -> u64 {
        log2_percentile_exact_us(&self.lat_bins, pct, self.lat_overflow, self.lat_max_us)
    }

    /// Traffic-weighted packing factor: micro-ops executed per cycle
    /// bundle across all served batches (1.0 with serial plans or no
    /// traffic; > 1.0 means list scheduling packed independent ops).
    pub fn packing_factor(&self) -> f64 {
        if self.plan_bundles == 0 {
            1.0
        } else {
            self.plan_ops as f64 / self.plan_bundles as f64
        }
    }

    /// Completed-requests rate over the snapshot's serving interval
    /// (0.0 when the snapshot carries no uptime, e.g. a pre-v5 peer).
    pub fn qps_over_uptime(&self) -> f64 {
        if self.uptime_ns == 0 {
            0.0
        } else {
            self.completed as f64 / (self.uptime_ns as f64 / 1e9)
        }
    }
}

/// Render a snapshot in the Prometheus text exposition format
/// (version 0.0.4 — what `GET /metrics` serves and any standard
/// scraper or `curl` understands). Counter/gauge naming follows
/// Prometheus conventions (`_total` suffix on monotonic counters);
/// the log2 latency histogram is exported with cumulative `le`
/// bucket edges in microseconds (`_count` is the true sample total —
/// overflowed samples are clamped into the top bin at record time,
/// with `remus_latency_overflow_total` counting the clamps).
/// `boot_epoch` is the
/// serving process's random per-boot identity (0 when the WAL /
/// epoch machinery is off) — a scraper seeing it change knows the
/// process restarted, the same signal `Router::fleet_events` uses.
pub fn render_prometheus(s: &MetricsSnapshot, boot_epoch: u64) -> String {
    let mut out = String::with_capacity(2048);
    let mut counter = |name: &str, help: &str, value: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n"
        ));
    };
    counter("remus_requests_submitted_total", "Requests submitted", s.submitted);
    counter("remus_requests_completed_total", "Requests completed", s.completed);
    counter("remus_requests_failed_total", "Requests with explicit error results", s.failed);
    counter("remus_batches_total", "Batches dispatched to workers", s.batches);
    counter("remus_batched_items_total", "Requests dispatched inside batches", s.batched_items);
    counter("remus_hb_pings_total", "Data-path heartbeat pings sent", s.hb_pings);
    counter("remus_hb_pongs_total", "Data-path heartbeat pongs received", s.hb_pongs);
    counter("remus_hb_timeouts_total", "Heartbeat deadlines missed", s.hb_timeouts);
    counter("remus_auth_rejects_total", "Peers rejected by authentication", s.auth_rejects);
    counter("remus_plan_ops_total", "Micro-ops in executed batches' plans", s.plan_ops);
    counter("remus_plan_bundles_total", "Cycle bundles issued for those plans", s.plan_bundles);
    counter(
        "remus_latency_overflow_total",
        "Latency samples past the top histogram bin",
        s.lat_overflow,
    );
    let mut gauge = |name: &str, help: &str, value: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {value}\n"
        ));
    };
    gauge("remus_queue_depth", "Requests queued, not yet dispatched", s.queue_depth);
    gauge("remus_shards_total", "Shards known to this router", s.shards_total);
    gauge("remus_shards_down", "Shards currently out of ring routing", s.shards_down);
    gauge("remus_workers_retired", "Workers retired from serving", s.retired_workers() as u64);
    gauge("remus_latency_max_us", "Exact maximum latency observed (us)", s.lat_max_us);
    gauge("remus_boot_epoch", "Random per-boot process identity (0 = off)", boot_epoch);
    out.push_str(&format!(
        "# HELP remus_uptime_seconds Serving uptime\n\
         # TYPE remus_uptime_seconds gauge\n\
         remus_uptime_seconds {:.3}\n",
        s.uptime_ns as f64 / 1e9
    ));
    // Per-kind-family request attribution.
    out.push_str(
        "# HELP remus_kind_requests_total Per-kind-family request counters\n\
         # TYPE remus_kind_requests_total counter\n",
    );
    for (family, ks) in s.kind_stats.iter().enumerate() {
        let name = FunctionKind::family_name(family);
        for (state, v) in
            [("submitted", ks.submitted), ("completed", ks.completed), ("failed", ks.failed)]
        {
            out.push_str(&format!(
                "remus_kind_requests_total{{kind=\"{name}\",state=\"{state}\"}} {v}\n"
            ));
        }
    }
    // The log2 latency histogram, Prometheus-style: cumulative counts
    // at each upper bin edge (us). Overflowed samples are already
    // clamped into the top bin, so the final cumulative count is the
    // true sample total; remus_latency_overflow_total says how many
    // of the top-bin samples were clamps.
    out.push_str(
        "# HELP remus_latency_us Request latency histogram (microseconds)\n\
         # TYPE remus_latency_us histogram\n",
    );
    let mut cumulative = 0u64;
    for (i, &b) in s.lat_bins.iter().enumerate() {
        cumulative += b;
        out.push_str(&format!(
            "remus_latency_us_bucket{{le=\"{}\"}} {cumulative}\n",
            1u64 << (i + 1)
        ));
    }
    out.push_str(&format!("remus_latency_us_bucket{{le=\"+Inf\"}} {cumulative}\n"));
    out.push_str(&format!("remus_latency_us_count {cumulative}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(5000));
        }
        let s = m.snapshot();
        assert!(s.latency_percentile_us(50.0) <= 32);
        assert!(s.latency_percentile_us(99.0) >= 4096);
    }

    #[test]
    fn batch_size_mean() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_items.store(100, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 25.0);
    }

    #[test]
    fn merge_aggregates_counters_bins_and_health() {
        let m1 = Metrics::new();
        m1.init_workers(2);
        m1.completed.store(10, Ordering::Relaxed);
        m1.batches.store(2, Ordering::Relaxed);
        m1.batched_items.store(10, Ordering::Relaxed);
        m1.record_latency(Duration::from_micros(10));
        let m2 = Metrics::new();
        m2.init_workers(1);
        m2.completed.store(5, Ordering::Relaxed);
        m2.batches.store(1, Ordering::Relaxed);
        m2.batched_items.store(20, Ordering::Relaxed);
        m2.record_latency(Duration::from_micros(10));
        m2.record_latency(Duration::from_micros(5000));
        m2.set_worker_health(0, WorkerHealth { retired: true, ..Default::default() });

        let mut merged = MetricsSnapshot::default();
        merged.merge(&m1.snapshot());
        merged.merge(&m2.snapshot());
        assert_eq!(merged.completed, 15);
        assert_eq!(merged.mean_batch_size(), 10.0);
        assert_eq!(merged.worker_health.len(), 3);
        assert_eq!(merged.retired_workers(), 1);
        assert_eq!(merged.lat_bins.iter().sum::<u64>(), 3);
        assert!(merged.latency_percentile_us(99.0) >= 4096);
        // Per-coordinator snapshots report no fleet membership or
        // heartbeat traffic; the router stamps the merged view (and
        // nested merges add).
        assert_eq!((merged.shards_total, merged.shards_down), (0, 0));
        assert_eq!((merged.hb_pings, merged.hb_pongs, merged.hb_timeouts), (0, 0, 0));
        assert_eq!(merged.auth_rejects, 0);
        merged.merge(&MetricsSnapshot {
            shards_total: 3,
            shards_down: 1,
            hb_pings: 8,
            hb_pongs: 7,
            hb_timeouts: 1,
            auth_rejects: 2,
            ..Default::default()
        });
        assert_eq!((merged.shards_total, merged.shards_down), (3, 1));
        assert_eq!((merged.hb_pings, merged.hb_pongs, merged.hb_timeouts), (8, 7, 1));
        assert_eq!(merged.auth_rejects, 2);
    }

    #[test]
    fn top_bin_overflow_is_counted_and_percentiles_use_the_exact_max() {
        let m = Metrics::new();
        m.record_latency(Duration::from_micros(100));
        // 40s = 40e6 us, past the 2^24 us (~16.8s) top bin edge.
        m.record_latency(Duration::from_secs(40));
        let s = m.snapshot();
        assert_eq!(s.lat_overflow, 1);
        assert_eq!(s.lat_max_us, 40_000_000);
        // p100 falls among the overflowed samples: the exact max, not
        // the fictitious 2^BINS edge.
        assert_eq!(s.latency_percentile_us(100.0), 40_000_000);
        // p50 is the 100us sample: plain upper bin edge.
        assert_eq!(s.latency_percentile_us(50.0), 128);
        assert!(s.uptime_ns > 0, "snapshot stamps serving uptime");
    }

    #[test]
    fn percentile_estimate_never_exceeds_the_exact_max() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(700));
        }
        let s = m.snapshot();
        // Raw upper bin edge would be 1024; the observed max is 700.
        assert_eq!(s.latency_percentile_us(99.0), 700);
    }

    #[test]
    fn kind_stats_count_and_merge_additively() {
        use crate::mmpu::functions::FunctionKind;
        let m1 = Metrics::new();
        m1.record_kind_submitted(FunctionKind::Add(8));
        m1.record_kind_submitted(FunctionKind::Add(8));
        m1.record_kind_completed(FunctionKind::Add(8));
        m1.record_kind_failed(FunctionKind::Xor(16), 3);
        let m2 = Metrics::new();
        m2.record_kind_submitted(FunctionKind::Mul(4));
        m2.record_kind_completed(FunctionKind::Mul(4));

        let mut merged = m1.snapshot();
        merged.merge(&m2.snapshot());
        let add = merged.kind_stats[FunctionKind::Add(8).index()];
        assert_eq!((add.submitted, add.completed, add.failed), (2, 1, 0));
        let mul = merged.kind_stats[FunctionKind::Mul(4).index()];
        assert_eq!((mul.submitted, mul.completed), (1, 1));
        let xor = merged.kind_stats[FunctionKind::Xor(16).index()];
        assert_eq!(xor.failed, 3);
        // Uptime is max-merged (both nonzero here), never summed.
        let a = m1.snapshot().uptime_ns;
        let b = m2.snapshot().uptime_ns;
        assert!(merged.uptime_ns <= a.max(b) + 1_000_000_000);
    }

    #[test]
    fn prometheus_exposition_is_well_formed_and_exact() {
        let m = Metrics::new();
        m.submitted.store(42, Ordering::Relaxed);
        m.completed.store(40, Ordering::Relaxed);
        m.failed.store(2, Ordering::Relaxed);
        m.record_latency(Duration::from_micros(10));
        m.record_latency(Duration::from_micros(5000));
        m.record_kind_submitted(crate::mmpu::functions::FunctionKind::Add(8));
        m.record_plan(120, 40);
        let mut s = m.snapshot();
        s.shards_total = 2;
        s.shards_down = 1;
        let text = render_prometheus(&s, 0xBEEF);
        assert!(text.contains("remus_requests_submitted_total 42\n"));
        assert!(text.contains("remus_plan_ops_total 120\n"));
        assert!(text.contains("remus_plan_bundles_total 40\n"));
        assert!(text.contains("remus_requests_completed_total 40\n"));
        assert!(text.contains("remus_requests_failed_total 2\n"));
        assert!(text.contains("remus_shards_total 2\n"));
        assert!(text.contains("remus_shards_down 1\n"));
        assert!(text.contains(&format!("remus_boot_epoch {}\n", 0xBEEFu64)));
        assert!(text.contains("remus_kind_requests_total{kind=\"add\",state=\"submitted\"} 1\n"));
        assert!(text.contains("remus_latency_us_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("remus_latency_us_count 2\n"));
        // Every non-comment line is `name[{labels}] value` — the
        // well-formedness contract the CI scrape smoke re-checks via
        // curl against a live endpoint.
        for line in text.lines() {
            if line.starts_with('#') {
                assert!(line.starts_with("# HELP ") || line.starts_with("# TYPE "));
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
        // Cumulative buckets are monotonic.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("remus_latency_us_bucket")) {
            let v: u64 = line.rsplit_once(' ').unwrap().1.parse().unwrap();
            assert!(v >= last, "bucket counts must be cumulative: {line}");
            last = v;
        }
    }

    #[test]
    fn plan_packing_counters_merge_and_ratio() {
        let m1 = Metrics::new();
        m1.record_plan(300, 100); // 3.0 packing on this shard
        let m2 = Metrics::new();
        m2.record_plan(100, 100); // serial shard
        let mut merged = m1.snapshot();
        assert_eq!(merged.packing_factor(), 3.0);
        merged.merge(&m2.snapshot());
        assert_eq!((merged.plan_ops, merged.plan_bundles), (400, 200));
        assert_eq!(merged.packing_factor(), 2.0, "traffic-weighted across shards");
        // No traffic (or a pre-v7 peer's zeros) reads as serial.
        assert_eq!(MetricsSnapshot::default().packing_factor(), 1.0);
    }

    #[test]
    fn worker_health_roundtrip() {
        let m = Metrics::new();
        m.init_workers(2);
        assert_eq!(m.snapshot().retired_workers(), 0);
        let h = WorkerHealth { retired: true, stuck_detected: 3, ..Default::default() };
        m.set_worker_health(1, h.clone());
        m.set_worker_health(9, WorkerHealth::default()); // out of range: ignored
        let s = m.snapshot();
        assert_eq!(s.worker_health.len(), 2);
        assert_eq!(s.worker_health[1], h);
        assert_eq!(s.retired_workers(), 1);
    }
}
