//! Lock-free coordinator metrics (atomics + log-scale latency histogram)
//! plus per-worker health reports (§Health; mutex-guarded, updated once
//! per batch by the owning worker only).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Number of log2 latency bins (1us ... ~1s).
const BINS: usize = 24;

/// Log2 bin index for a microsecond latency: bin i counts latencies in
/// `[2^i, 2^(i+1))`, clamped to `nbins`. Shared by the coordinator
/// metrics and `fabric::loadgen`'s histograms so their bin edges can
/// never drift apart.
pub fn log2_bin_us(us: u64, nbins: usize) -> usize {
    let us = us.max(1);
    (63 - us.leading_zeros() as usize).min(nbins - 1)
}

/// Percentile estimate over log2 latency bins (upper bin edge,
/// microseconds; 0 when empty) — the single estimator behind
/// [`MetricsSnapshot::latency_percentile_us`] and
/// `fabric::loadgen::LatencyHisto::percentile_us`.
pub fn log2_percentile_us(bins: &[u64], pct: f64) -> u64 {
    let total: u64 = bins.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * pct / 100.0).ceil() as u64;
    let mut seen = 0;
    for (i, &c) in bins.iter().enumerate() {
        seen += c;
        if seen >= target {
            return 1u64 << (i + 1);
        }
    }
    1u64 << bins.len()
}

/// Per-worker health summary exported through [`MetricsSnapshot`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkerHealth {
    pub batches: u64,
    pub scrubs: u64,
    /// Drift bits corrected (serving-path ECC + scrub ECC).
    pub corrected: u64,
    /// Uncorrectable ECC blocks observed by scrubbing.
    pub uncorrectable: u64,
    pub stuck_detected: u64,
    pub remapped_rows: u64,
    pub spares_left: u64,
    /// Protection mechanisms active in the worker's *live* policy
    /// (ECC counts 1, TMR counts 1) — base protections included.
    pub policy_level: u8,
    pub retired: bool,
}

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that received an explicit error result (failed batch
    /// execution/compilation, retirement, shutdown) instead of a value.
    pub failed: AtomicU64,
    /// Batches *dispatched* by the router. A batch redistributed after a
    /// worker retirement is dispatched again and counts again, so
    /// `batched_items` can exceed `submitted` during retirement storms.
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub busy_ns: AtomicU64,
    pub queue_depth: AtomicU64,
    lat_bins: [AtomicU64; BINS],
    worker_health: Mutex<Vec<WorkerHealth>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the per-worker health table (done once at coordinator start).
    pub fn init_workers(&self, n: usize) {
        *self.worker_health.lock().unwrap() = vec![WorkerHealth::default(); n];
    }

    pub fn set_worker_health(&self, worker: usize, h: WorkerHealth) {
        if let Some(slot) = self.worker_health.lock().unwrap().get_mut(worker) {
            *slot = h;
        }
    }

    pub fn record_latency(&self, d: Duration) {
        let bin = log2_bin_us(d.as_micros() as u64, BINS);
        self.lat_bins[bin].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let bins: Vec<u64> = self.lat_bins.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            lat_bins: bins,
            worker_health: self.worker_health.lock().unwrap().clone(),
            shards_total: 0,
            shards_down: 0,
            hb_pings: 0,
            hb_pongs: 0,
            hb_timeouts: 0,
            auth_rejects: 0,
        }
    }
}

/// Point-in-time copy for reporting. Public fields (including the raw
/// latency histogram) so the fabric wire codec can carry snapshots
/// across processes and the router can merge per-shard copies.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub busy_ns: u64,
    pub queue_depth: u64,
    /// Per-worker health (§Health; empty when no health manager is on).
    pub worker_health: Vec<WorkerHealth>,
    /// Log2-scale latency histogram (bin i counts latencies in
    /// `[2^i, 2^(i+1))` microseconds; see [`Metrics::record_latency`]).
    pub lat_bins: Vec<u64>,
    /// Fabric fleet membership (§Scale): shards known to the router
    /// that produced this view. A single coordinator reports 0 — the
    /// router stamps the merged snapshot, so a degraded fleet is
    /// distinguishable from a healthy smaller one.
    pub shards_total: u64,
    /// Shards currently out of ring routing (marked down, awaiting
    /// revival).
    pub shards_down: u64,
    /// Data-path heartbeats sent by the router that produced this view
    /// (§Scale, wire v3). A single coordinator reports 0.
    pub hb_pings: u64,
    /// `Pong` echoes received back on shard data connections.
    pub hb_pongs: u64,
    /// Shards marked down because a heartbeat deadline expired — the
    /// half-open-connection detector firing (distinct from disconnect
    /// or capacity failovers, which close the socket visibly).
    pub hb_timeouts: u64,
    /// Peers rejected by the fabric's authentication layer (§Security,
    /// wire v4): failed PSK handshakes, tampered/replayed sealed frames,
    /// plaintext traffic on an authenticated port. Counted by both the
    /// shard server and the router; a single coordinator reports 0.
    pub auth_rejects: u64,
}

impl MetricsSnapshot {
    /// Fold another snapshot into this one (fabric router: aggregate the
    /// per-shard snapshots into one fleet view). Counters and latency
    /// bins add; worker health concatenates, so `worker_health[i]` is no
    /// longer a process-local worker index but the fleet-wide listing —
    /// `retired_workers()` et al. keep working on the merged view.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.failed += other.failed;
        self.batches += other.batches;
        self.batched_items += other.batched_items;
        self.busy_ns += other.busy_ns;
        self.queue_depth += other.queue_depth;
        if self.lat_bins.len() < other.lat_bins.len() {
            self.lat_bins.resize(other.lat_bins.len(), 0);
        }
        for (i, &b) in other.lat_bins.iter().enumerate() {
            self.lat_bins[i] += b;
        }
        self.worker_health.extend(other.worker_health.iter().cloned());
        // Membership and heartbeat counters add so nested merges
        // compose; per-shard snapshots carry 0 and the router stamps
        // the final view.
        self.shards_total += other.shards_total;
        self.shards_down += other.shards_down;
        self.hb_pings += other.hb_pings;
        self.hb_pongs += other.hb_pongs;
        self.hb_timeouts += other.hb_timeouts;
        self.auth_rejects += other.auth_rejects;
    }
    /// Workers that retired their crossbar.
    pub fn retired_workers(&self) -> usize {
        self.worker_health.iter().filter(|w| w.retired).count()
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Approximate latency percentile from the log histogram (upper bin
    /// edge, microseconds).
    pub fn latency_percentile_us(&self, pct: f64) -> u64 {
        log2_percentile_us(&self.lat_bins, pct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(5000));
        }
        let s = m.snapshot();
        assert!(s.latency_percentile_us(50.0) <= 32);
        assert!(s.latency_percentile_us(99.0) >= 4096);
    }

    #[test]
    fn batch_size_mean() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_items.store(100, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 25.0);
    }

    #[test]
    fn merge_aggregates_counters_bins_and_health() {
        let m1 = Metrics::new();
        m1.init_workers(2);
        m1.completed.store(10, Ordering::Relaxed);
        m1.batches.store(2, Ordering::Relaxed);
        m1.batched_items.store(10, Ordering::Relaxed);
        m1.record_latency(Duration::from_micros(10));
        let m2 = Metrics::new();
        m2.init_workers(1);
        m2.completed.store(5, Ordering::Relaxed);
        m2.batches.store(1, Ordering::Relaxed);
        m2.batched_items.store(20, Ordering::Relaxed);
        m2.record_latency(Duration::from_micros(10));
        m2.record_latency(Duration::from_micros(5000));
        m2.set_worker_health(0, WorkerHealth { retired: true, ..Default::default() });

        let mut merged = MetricsSnapshot::default();
        merged.merge(&m1.snapshot());
        merged.merge(&m2.snapshot());
        assert_eq!(merged.completed, 15);
        assert_eq!(merged.mean_batch_size(), 10.0);
        assert_eq!(merged.worker_health.len(), 3);
        assert_eq!(merged.retired_workers(), 1);
        assert_eq!(merged.lat_bins.iter().sum::<u64>(), 3);
        assert!(merged.latency_percentile_us(99.0) >= 4096);
        // Per-coordinator snapshots report no fleet membership or
        // heartbeat traffic; the router stamps the merged view (and
        // nested merges add).
        assert_eq!((merged.shards_total, merged.shards_down), (0, 0));
        assert_eq!((merged.hb_pings, merged.hb_pongs, merged.hb_timeouts), (0, 0, 0));
        assert_eq!(merged.auth_rejects, 0);
        merged.merge(&MetricsSnapshot {
            shards_total: 3,
            shards_down: 1,
            hb_pings: 8,
            hb_pongs: 7,
            hb_timeouts: 1,
            auth_rejects: 2,
            ..Default::default()
        });
        assert_eq!((merged.shards_total, merged.shards_down), (3, 1));
        assert_eq!((merged.hb_pings, merged.hb_pongs, merged.hb_timeouts), (8, 7, 1));
        assert_eq!(merged.auth_rejects, 2);
    }

    #[test]
    fn worker_health_roundtrip() {
        let m = Metrics::new();
        m.init_workers(2);
        assert_eq!(m.snapshot().retired_workers(), 0);
        let h = WorkerHealth { retired: true, stuck_detected: 3, ..Default::default() };
        m.set_worker_health(1, h.clone());
        m.set_worker_health(9, WorkerHealth::default()); // out of range: ignored
        let s = m.snapshot();
        assert_eq!(s.worker_health.len(), 2);
        assert_eq!(s.worker_health[1], h);
        assert_eq!(s.retired_workers(), 1);
    }
}
