//! Lock-free coordinator metrics (atomics + log-scale latency histogram).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log2 latency bins (1us ... ~1s).
const BINS: usize = 24;

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that received an explicit error result (failed batch
    /// execution/compilation) instead of a value.
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    pub busy_ns: AtomicU64,
    pub queue_depth: AtomicU64,
    lat_bins: [AtomicU64; BINS],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bin = (63 - us.leading_zeros() as usize).min(BINS - 1);
        self.lat_bins[bin].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let bins: Vec<u64> = self.lat_bins.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            lat_bins: bins,
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub busy_ns: u64,
    pub queue_depth: u64,
    lat_bins: Vec<u64>,
}

impl MetricsSnapshot {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Approximate latency percentile from the log histogram (upper bin
    /// edge, microseconds).
    pub fn latency_percentile_us(&self, pct: f64) -> u64 {
        let total: u64 = self.lat_bins.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * pct / 100.0).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.lat_bins.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BINS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.record_latency(Duration::from_micros(10));
        }
        for _ in 0..10 {
            m.record_latency(Duration::from_micros(5000));
        }
        let s = m.snapshot();
        assert!(s.latency_percentile_us(50.0) <= 32);
        assert!(s.latency_percentile_us(99.0) >= 4096);
    }

    #[test]
    fn batch_size_mean() {
        let m = Metrics::new();
        m.batches.store(4, Ordering::Relaxed);
        m.batched_items.store(100, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 25.0);
    }
}
