//! Spare-row remapping: transparent logical-to-physical row translation.
//!
//! The top `spare_rows` physical rows of a crossbar are reserved as
//! spares; batch items address the *logical* row space `0..data_rows`.
//! When scrubbing detects a persistent fault in a physical row, the
//! logical row currently mapped there is redirected to a spare — future
//! operand loads and readbacks follow the map, and the in-row compute is
//! untouched because stateful in-row micro-ops already execute in every
//! physical lane (paper Fig. 1a): a remapped item's row participates in
//! the same cycles as every other row.
//!
//! Column faults need no separate spare-column machinery on this path: a
//! stuck cell at `(r, c)` only corrupts the item occupying row `r`, so
//! row retirement covers arbitrary single-cell faults. Whole-column
//! (driver) failures are modeled as crossbar retirement (ROADMAP).

use std::collections::HashSet;

/// Result of reporting one bad physical row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BadRowOutcome {
    /// An active logical row was moved to a spare.
    Remapped { logical: u32, spare: u32 },
    /// The bad row was an unused spare; it is taken out of the pool.
    SparePoisoned,
    /// This physical row was already known bad.
    AlreadyKnown,
    /// An active row is bad and no spare is left — retire the crossbar.
    Exhausted,
}

/// Logical-to-physical row map with a spare pool.
#[derive(Clone, Debug)]
pub struct RowRemap {
    /// `map[logical] = physical`.
    map: Vec<u32>,
    free_spares: Vec<u32>,
    bad: HashSet<u32>,
}

impl RowRemap {
    pub fn new(rows: usize, spare_rows: usize) -> Self {
        let spare_rows = spare_rows.min(rows.saturating_sub(1));
        let data_rows = rows - spare_rows;
        Self {
            map: (0..data_rows as u32).collect(),
            free_spares: (data_rows as u32..rows as u32).collect(),
            bad: HashSet::new(),
        }
    }

    /// Logical row capacity (physical rows minus reserved spares).
    pub fn data_rows(&self) -> usize {
        self.map.len()
    }

    pub fn spares_left(&self) -> usize {
        self.free_spares.len()
    }

    /// Physical row backing a logical row.
    pub fn physical(&self, logical: u32) -> u32 {
        self.map[logical as usize]
    }

    pub fn is_identity(&self) -> bool {
        self.map.iter().enumerate().all(|(l, &p)| l as u32 == p)
    }

    /// Non-identity `(logical, physical)` pairs.
    pub fn pairs(&self) -> Vec<(u32, u32)> {
        self.map
            .iter()
            .enumerate()
            .filter(|&(l, &p)| l as u32 != p)
            .map(|(l, &p)| (l as u32, p))
            .collect()
    }

    pub fn remapped_count(&self) -> usize {
        self.map.iter().enumerate().filter(|&(l, &p)| l as u32 != p).count()
    }

    /// Take a physical row out of the spare pool without marking it bad
    /// — e.g. the semi-parallel TMR vote scratch row, which the engine
    /// overwrites every batch and must never back remapped data.
    /// Returns whether the row was in the pool.
    pub fn reserve(&mut self, physical: u32) -> bool {
        let before = self.free_spares.len();
        self.free_spares.retain(|&s| s != physical);
        self.free_spares.len() != before
    }

    /// Record that a physical row holds a persistent fault; remap the
    /// logical row served by it (if any) onto a healthy spare.
    pub fn notice_bad_row(&mut self, physical: u32) -> BadRowOutcome {
        if !self.bad.insert(physical) {
            return BadRowOutcome::AlreadyKnown;
        }
        if let Some(logical) = self.map.iter().position(|&p| p == physical) {
            loop {
                match self.free_spares.pop() {
                    Some(s) if self.bad.contains(&s) => continue,
                    Some(s) => {
                        self.map[logical] = s;
                        return BadRowOutcome::Remapped { logical: logical as u32, spare: s };
                    }
                    None => return BadRowOutcome::Exhausted,
                }
            }
        }
        self.free_spares.retain(|&s| s != physical);
        BadRowOutcome::SparePoisoned
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_until_faults() {
        let r = RowRemap::new(32, 4);
        assert_eq!(r.data_rows(), 28);
        assert_eq!(r.spares_left(), 4);
        assert!(r.is_identity());
        assert!(r.pairs().is_empty());
        assert_eq!(r.physical(10), 10);
    }

    #[test]
    fn remap_chain_and_exhaustion() {
        let mut r = RowRemap::new(8, 2); // data rows 0..6, spares {6, 7}
        let o = r.notice_bad_row(3);
        assert_eq!(o, BadRowOutcome::Remapped { logical: 3, spare: 7 });
        assert_eq!(r.physical(3), 7);
        assert_eq!(r.notice_bad_row(3), BadRowOutcome::AlreadyKnown);
        // The spare serving logical 3 dies too: remap again.
        let o = r.notice_bad_row(7);
        assert_eq!(o, BadRowOutcome::Remapped { logical: 3, spare: 6 });
        assert_eq!(r.pairs(), vec![(3, 6)]);
        assert_eq!(r.remapped_count(), 1);
        assert_eq!(r.spares_left(), 0);
        // No spare left for the next active-row fault.
        assert_eq!(r.notice_bad_row(0), BadRowOutcome::Exhausted);
    }

    #[test]
    fn reserved_spare_is_never_handed_out() {
        let mut r = RowRemap::new(8, 2); // spares {6, 7}
        assert!(r.reserve(7), "7 was in the pool");
        assert!(!r.reserve(7), "already reserved");
        assert_eq!(r.spares_left(), 1);
        let o = r.notice_bad_row(2);
        assert_eq!(o, BadRowOutcome::Remapped { logical: 2, spare: 6 });
        assert_eq!(r.notice_bad_row(3), BadRowOutcome::Exhausted, "7 stays reserved");
    }

    #[test]
    fn poisoned_spare_is_skipped() {
        let mut r = RowRemap::new(8, 2);
        assert_eq!(r.notice_bad_row(7), BadRowOutcome::SparePoisoned);
        assert_eq!(r.spares_left(), 1);
        let o = r.notice_bad_row(1);
        assert_eq!(o, BadRowOutcome::Remapped { logical: 1, spare: 6 });
    }
}
