//! # health — online fault management for the mMPU.
//!
//! The paper's reliability mechanisms (diagonal ECC, TMR) protect a
//! single execution; a long-running server additionally needs *ongoing*
//! management of faults that do not go away: stuck-at cells from
//! manufacturing defects and endurance wear-out (arXiv:2602.04035), and
//! drift that accumulates between accesses unless scrubbed
//! (arXiv:2105.04212). This module provides, per crossbar:
//!
//! * [`FaultMap`] — ground-truth persistent faults plus the lognormal
//!   endurance wear-out process fed by the crossbar's `switched_bits`
//!   energy/wear accounting;
//! * [`RowRemap`] — spare-row remapping with transparent address
//!   translation on the operand marshalling path;
//! * [`CrossbarHealth`] — the manager: a background **scrub** pass
//!   (ECC correction of accumulated drift + a march test that detects
//!   stuck-at cells and triggers remapping), telemetry, **adaptive
//!   policy escalation** (None -> ECC -> ECC+TMR) and the retirement
//!   decision once spares are exhausted or the fault population passes
//!   the configured bound.
//!
//! The manager is deliberately *detection-based*: it never reads the
//! ground-truth [`FaultMap`] to decide anything — stuck cells are found
//! the way real hardware finds them, by writing test patterns and
//! reading them back. `FaultMap` only simulates the physics (writes to a
//! dead cell do not take).
//!
//! Integration points: `mmpu::Mmpu` owns an optional `CrossbarHealth`
//! per crossbar (`Mmpu::enable_health`) and consults it on the
//! word-parallel serving path; `coordinator` workers drive scrubbing,
//! escalation and retirement between batches and export per-worker
//! health in `MetricsSnapshot`; `analysis::lifetime` validates the
//! simulated degradation against the closed-form `nn::degradation`
//! model.

pub mod fault_map;
pub mod remap;

pub use fault_map::{FaultMap, StuckCell, WearModel};
pub use remap::{BadRowOutcome, RowRemap};

use std::collections::{BTreeSet, HashSet};

use crate::ecc::DiagonalEcc;
use crate::mmpu::ReliabilityPolicy;
use crate::tmr::TmrMode;
use crate::util::bitmat::{BitMatrix, BitVec};

/// Configuration of one crossbar's health manager.
#[derive(Clone, Debug)]
pub struct HealthConfig {
    pub wear: WearModel,
    /// Physical rows reserved as remap spares (top of the array).
    pub spare_rows: usize,
    /// Batches between scrub passes.
    pub scrub_interval: u64,
    /// Physical rows march-tested per scrub pass.
    pub scrub_rows_per_pass: usize,
    /// ECC block size installed when escalation enables ECC.
    pub ecc_m: usize,
    /// ECC-corrected drift count that escalates to TMR: corrections are
    /// only observable once ECC is installed (base policy or a level-1
    /// escalation), and a high corrected rate means drift pressure that
    /// single-error correction will eventually lose to.
    pub escalate_corrected: u64,
    /// Uncorrectable-event count that escalates to TMR.
    pub escalate_uncorrected: u64,
    /// Detected stuck cells beyond which the crossbar is retired.
    pub retire_stuck_cells: u64,
    /// De-escalation: after this many consecutive *clean* scrub passes
    /// (no drift corrected on either path since the previous pass, no
    /// uncorrectable blocks, no new stuck cells), the escalation steps
    /// back one level (ECC+TMR -> ECC -> base). The telemetry counters
    /// are floored at each step so only events *after* the step-down
    /// re-escalate. 0 disables (escalation is then one-way, the
    /// pre-de-escalation behavior). Spare exhaustion never de-escalates.
    pub deescalate_after: u64,
    pub seed: u64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            wear: WearModel::rram(),
            spare_rows: 4,
            scrub_interval: 64,
            scrub_rows_per_pass: 8,
            ecc_m: 16,
            escalate_corrected: 64,
            escalate_uncorrected: 4,
            retire_stuck_cells: 256,
            deescalate_after: 0,
            seed: 0x4EA1,
        }
    }
}

/// Point-in-time health counters (exported into coordinator metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    pub batches: u64,
    pub scrub_passes: u64,
    /// Drift bits corrected by scrub-time ECC passes.
    pub scrub_corrected: u64,
    /// Uncorrectable (>= 2 error) blocks seen by scrub-time ECC passes.
    pub scrub_uncorrectable: u64,
    /// Drift bits corrected on the serving path (ECC verify-before).
    pub drift_corrected: u64,
    /// Distinct stuck cells found by the march test.
    pub stuck_detected: u64,
    /// Ground-truth stuck cells (wear + injected) — simulation-side.
    pub stuck_cells_true: u64,
    pub remapped_rows: u64,
    pub spares_left: u64,
    /// Modeled extension cycles spent scrubbing (not crossbar cycles).
    pub scrub_cycles: u64,
    /// Escalation level: 0 = base policy, 1 = +ECC, 2 = +ECC+TMR.
    pub level: u8,
}

/// What one scrub pass found and did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Drift bits repaired by the ECC pass.
    pub corrected: u64,
    /// Uncorrectable blocks flagged by the ECC pass.
    pub uncorrectable: u64,
    /// Newly detected stuck cells (march test).
    pub detected: u64,
    /// Rows remapped onto spares.
    pub remapped: u64,
    /// An active row is bad and the spare pool is empty.
    pub exhausted: bool,
}

/// Online reliability manager for one crossbar.
#[derive(Clone, Debug)]
pub struct CrossbarHealth {
    cfg: HealthConfig,
    fault_map: FaultMap,
    remap: RowRemap,
    /// Stuck cells already counted by detection (march re-finds them).
    known: HashSet<(u32, u32)>,
    scrub_cursor: usize,
    last_scrub_batch: u64,
    exhausted: bool,
    /// Sticky escalation level: raised whenever telemetry warrants,
    /// lowered only by the de-escalation path in [`Self::scrub`].
    esc_level: u8,
    /// Consecutive clean scrub passes (de-escalation streak).
    clean_scrubs: u64,
    /// Telemetry floors, rebased at each de-escalation so only events
    /// newer than the last step-down count toward re-escalation.
    floor_corrected: u64,
    floor_uncorrectable: u64,
    floor_stuck: u64,
    /// `drift_corrected` as of the previous scrub pass (a clean interval
    /// requires zero serving-path corrections too, not just clean scrub
    /// findings).
    drift_at_last_scrub: u64,
    stats: HealthStats,
}

impl CrossbarHealth {
    pub fn new(rows: usize, cols: usize, cfg: HealthConfig, seed: u64) -> Self {
        let fault_map = FaultMap::new(rows, cols, cfg.wear, seed);
        let remap = RowRemap::new(rows, cfg.spare_rows);
        Self {
            cfg,
            fault_map,
            remap,
            known: HashSet::new(),
            scrub_cursor: 0,
            last_scrub_batch: 0,
            exhausted: false,
            esc_level: 0,
            clean_scrubs: 0,
            floor_corrected: 0,
            floor_uncorrectable: 0,
            floor_stuck: 0,
            drift_at_last_scrub: 0,
            stats: HealthStats::default(),
        }
    }

    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    /// Logical row capacity available to batches.
    pub fn data_rows(&self) -> usize {
        self.remap.data_rows()
    }

    /// Non-identity `(logical, physical)` row translations.
    pub fn remapped_pairs(&self) -> Vec<(u32, u32)> {
        self.remap.pairs()
    }

    pub fn fault_map(&self) -> &FaultMap {
        &self.fault_map
    }

    /// Inject a ground-truth stuck cell (tests / fault campaigns).
    pub fn inject_stuck(&mut self, row: u32, col: u32, value: bool) -> bool {
        self.fault_map.inject(row, col, value)
    }

    /// Withdraw a physical row from the spare pool (without marking it
    /// bad): the mMPU reserves the semi-parallel TMR vote scratch row
    /// this way, since the engine overwrites it every batch.
    pub fn reserve_spare(&mut self, physical: u32) -> bool {
        self.remap.reserve(physical)
    }

    /// Force stuck cells onto the array state; returns bits changed.
    pub fn clamp(&self, state: &mut BitMatrix) -> u64 {
        self.fault_map.clamp(state)
    }

    /// Per-batch bookkeeping: wear advance + serving telemetry.
    pub fn on_batch(&mut self, total_switched: u64, ecc_corrected: u64) {
        self.stats.batches += 1;
        self.stats.drift_corrected += ecc_corrected;
        self.fault_map.advance_wear(total_switched);
    }

    pub fn scrub_due(&self) -> bool {
        self.stats.batches - self.last_scrub_batch >= self.cfg.scrub_interval
    }

    /// One scrub pass: ECC-correct accumulated drift (when ECC is
    /// installed), march-test the next window of physical rows for
    /// stuck-at faults, and remap rows with persistent faults onto
    /// spares (migrating their contents).
    pub fn scrub(
        &mut self,
        state: &mut BitMatrix,
        mut ecc: Option<&mut DiagonalEcc>,
    ) -> ScrubReport {
        let mut rep = ScrubReport::default();
        self.stats.scrub_passes += 1;
        self.last_scrub_batch = self.stats.batches;

        if let Some(ecc) = ecc.as_deref_mut() {
            let out = ecc.correct(state);
            rep.corrected = out.corrected_bits.len() as u64;
            rep.uncorrectable = out.uncorrectable_blocks.len() as u64;
            self.stats.scrub_corrected += rep.corrected;
            self.stats.scrub_uncorrectable += rep.uncorrectable;
            self.stats.scrub_cycles += ecc.verify_cost();
        }

        // March test: write all-ones then all-zeros to each row of the
        // window, reading back after each pattern; a cell that cannot
        // store one of the patterns is stuck. Data is saved/restored, so
        // the pass is transparent (and ECC parities stay valid: a stuck
        // cell reads back its stuck value before and after).
        let rows = state.rows();
        let cols = state.cols();
        let window = self.cfg.scrub_rows_per_pass.clamp(1, rows);
        let ones = BitVec::ones(cols);
        let zeros = BitVec::zeros(cols);
        let mut newly: Vec<(u32, u32)> = Vec::new();
        for k in 0..window {
            let r = (self.scrub_cursor + k) % rows;
            let saved = state.row_bitvec(r);
            state.set_row(r, &ones);
            self.fault_map.clamp_row(state, r);
            let after_ones = state.row_bitvec(r);
            state.set_row(r, &zeros);
            self.fault_map.clamp_row(state, r);
            let after_zeros = state.row_bitvec(r);
            for c in 0..cols {
                if !after_ones.get(c) || after_zeros.get(c) {
                    newly.push((r as u32, c as u32));
                }
            }
            state.set_row(r, &saved);
            self.fault_map.clamp_row(state, r);
            // Modeled cost: two pattern writes, two reads, one restore.
            self.stats.scrub_cycles += 5;
        }
        self.scrub_cursor = (self.scrub_cursor + window) % rows;

        let mut bad_rows: BTreeSet<u32> = BTreeSet::new();
        for &(r, c) in &newly {
            if self.known.insert((r, c)) {
                self.stats.stuck_detected += 1;
                rep.detected += 1;
            }
            bad_rows.insert(r);
        }
        let mut migrated = false;
        for r in bad_rows {
            match self.remap.notice_bad_row(r) {
                BadRowOutcome::Remapped { spare, .. } => {
                    // Migrate the row's contents to its spare.
                    for c in 0..cols {
                        let v = state.get(r as usize, c);
                        state.set(spare as usize, c, v);
                    }
                    self.fault_map.clamp_row(state, spare as usize);
                    // (cumulative remapped_rows is derived from the map
                    // in `stats()` — re-remapping a row counts once)
                    self.stats.scrub_cycles += cols as u64;
                    rep.remapped += 1;
                    migrated = true;
                }
                BadRowOutcome::Exhausted => {
                    self.exhausted = true;
                    rep.exhausted = true;
                }
                BadRowOutcome::SparePoisoned | BadRowOutcome::AlreadyKnown => {}
            }
        }
        // Migration rewrote spare rows outside the ECC's incremental
        // bookkeeping: re-sync the parities.
        if migrated {
            if let Some(ecc) = ecc {
                ecc.encode(state);
            }
        }

        // De-escalation (§Health follow-on): a fully clean pass — no
        // drift corrected by scrub OR the serving path since the last
        // pass, no uncorrectable blocks, no new stuck cells — extends
        // the streak; once it reaches `deescalate_after`, step the
        // escalation back one level and rebase the telemetry floors so
        // only fresh events re-escalate. Any event resets the streak.
        self.esc_level = self.esc_level.max(self.telemetry_level());
        let drift_delta = self.stats.drift_corrected - self.drift_at_last_scrub;
        self.drift_at_last_scrub = self.stats.drift_corrected;
        let clean = rep.corrected == 0
            && rep.uncorrectable == 0
            && rep.detected == 0
            && drift_delta == 0
            && !self.exhausted;
        if !clean {
            self.clean_scrubs = 0;
        } else if self.cfg.deescalate_after > 0 {
            self.clean_scrubs += 1;
            if self.clean_scrubs >= self.cfg.deescalate_after && self.level() > 0 {
                self.esc_level = self.level() - 1;
                self.floor_corrected = self.stats.scrub_corrected + self.stats.drift_corrected;
                self.floor_uncorrectable = self.stats.scrub_uncorrectable;
                self.floor_stuck = self.stats.stuck_detected;
                self.clean_scrubs = 0;
            }
        }
        rep
    }

    /// Escalation level warranted by telemetry accumulated since the
    /// last de-escalation floor.
    ///
    /// Level 1 (+ECC) fires on the first detected persistent fault —
    /// the march test needs no ECC, so this is the only drift-blind
    /// signal available under an unprotected base policy. Level 2
    /// (+TMR) fires on signals that single-error correction is losing:
    /// uncorrectable blocks, spare exhaustion, or a corrected-drift
    /// count past `escalate_corrected` (observable once ECC is on).
    fn telemetry_level(&self) -> u8 {
        let corrected = (self.stats.scrub_corrected + self.stats.drift_corrected)
            .saturating_sub(self.floor_corrected);
        let uncorrectable =
            self.stats.scrub_uncorrectable.saturating_sub(self.floor_uncorrectable);
        let stuck = self.stats.stuck_detected.saturating_sub(self.floor_stuck);
        if uncorrectable >= self.cfg.escalate_uncorrected
            || corrected >= self.cfg.escalate_corrected
            || self.exhausted
        {
            2
        } else if stuck > 0 {
            1
        } else {
            0
        }
    }

    /// The live escalation level: sticky across clean intervals, stepped
    /// down only by the de-escalation path (spare exhaustion pins it at
    /// 2 through `telemetry_level`).
    fn level(&self) -> u8 {
        self.esc_level.max(self.telemetry_level())
    }

    /// The reliability policy this crossbar should run, given the
    /// configured base policy: escalation only ever adds protection on
    /// top of `base`, and de-escalation (when `deescalate_after` is
    /// set) only removes what escalation added — never base protection.
    pub fn recommended_policy(&self, base: ReliabilityPolicy) -> ReliabilityPolicy {
        let mut p = base;
        let level = self.level();
        if level >= 1 && p.ecc_m.is_none() {
            p.ecc_m = Some(self.cfg.ecc_m);
        }
        if level >= 2 && p.tmr == TmrMode::Off {
            p.tmr = TmrMode::Serial;
        }
        p
    }

    /// Retire when an unfixable active-row fault exists or the detected
    /// fault population passed the configured bound.
    pub fn should_retire(&self) -> bool {
        self.exhausted || self.stats.stuck_detected >= self.cfg.retire_stuck_cells
    }

    pub fn stats(&self) -> HealthStats {
        HealthStats {
            stuck_cells_true: self.fault_map.len() as u64,
            remapped_rows: self.remap.remapped_count() as u64,
            spares_left: self.remap.spares_left() as u64,
            level: self.level(),
            ..self.stats
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn immortal_cfg(spares: usize) -> HealthConfig {
        HealthConfig {
            wear: WearModel::immortal(),
            spare_rows: spares,
            scrub_interval: 1,
            scrub_rows_per_pass: 64,
            ..Default::default()
        }
    }

    fn random_state(rows: usize, cols: usize, seed: u64) -> BitMatrix {
        let mut r = Pcg64::new(seed, 0);
        BitMatrix::from_fn(rows, cols, |_, _| r.bernoulli(0.5))
    }

    #[test]
    fn march_detects_and_remaps_without_disturbing_data() {
        let mut state = random_state(32, 64, 3);
        let mut h = CrossbarHealth::new(32, 64, immortal_cfg(4), 11);
        h.inject_stuck(5, 9, true);
        h.inject_stuck(5, 40, false);
        h.inject_stuck(17, 2, false);
        h.clamp(&mut state);
        let before = state.clone();
        let rep = h.scrub(&mut state, None);
        assert_eq!(rep.detected, 3);
        assert_eq!(rep.remapped, 2, "rows 5 and 17");
        assert!(!rep.exhausted);
        // Everything outside the migrated spare rows is untouched.
        for r in 0..28 {
            for c in 0..64 {
                assert_eq!(state.get(r, c), before.get(r, c), "({r},{c})");
            }
        }
        // Spares now mirror the bad rows' data.
        let pairs = h.remapped_pairs();
        assert_eq!(pairs.len(), 2);
        for &(l, p) in &pairs {
            for c in 0..64 {
                assert_eq!(
                    state.get(p as usize, c),
                    before.get(l as usize, c),
                    "migrated ({l}->{p},{c})"
                );
            }
        }
        // A second scrub detects nothing new and remaps nothing.
        let rep2 = h.scrub(&mut state, None);
        assert_eq!(rep2.detected, 0);
        assert_eq!(rep2.remapped, 0);
        let s = h.stats();
        assert_eq!(s.stuck_detected, 3);
        assert_eq!(s.remapped_rows, 2);
        assert_eq!(s.spares_left, 2);
    }

    #[test]
    fn escalation_levels_follow_telemetry() {
        let mut h = CrossbarHealth::new(32, 64, immortal_cfg(4), 1);
        let base = ReliabilityPolicy::none();
        assert_eq!(h.recommended_policy(base).ecc_m, None);
        // A detected stuck cell turns ECC on.
        let mut state = BitMatrix::zeros(32, 64);
        h.inject_stuck(2, 2, true);
        h.scrub(&mut state, None);
        let p1 = h.recommended_policy(base);
        assert_eq!(p1.ecc_m, Some(16));
        assert_eq!(p1.tmr, TmrMode::Off);
        // Uncorrectable pressure turns TMR on.
        h.stats.scrub_uncorrectable = h.cfg.escalate_uncorrected;
        let p2 = h.recommended_policy(base);
        assert_eq!(p2.tmr, TmrMode::Serial);
        assert_eq!(h.stats().level, 2);
        // Sustained corrected drift (observable once ECC runs) also
        // escalates to TMR, independent of stuck-cell detection.
        let mut hd = CrossbarHealth::new(32, 64, immortal_cfg(4), 2);
        hd.stats.drift_corrected = hd.cfg.escalate_corrected;
        let pd = hd.recommended_policy(base);
        assert_eq!(pd.ecc_m, Some(16));
        assert_eq!(pd.tmr, TmrMode::Serial);
        // Escalation never removes protection the base already has.
        let strong = ReliabilityPolicy { ecc_m: Some(8), tmr: TmrMode::Parallel };
        let p3 = h.recommended_policy(strong);
        assert_eq!(p3.ecc_m, Some(8));
        assert_eq!(p3.tmr, TmrMode::Parallel);
    }

    #[test]
    fn deescalation_steps_back_through_the_full_cycle() {
        // Escalate base(None) -> +ECC -> +ECC+TMR from telemetry, then
        // watch clean scrub intervals walk it back one level at a time,
        // and a fresh fault re-escalate from the rebased floors.
        let mut cfg = immortal_cfg(4);
        cfg.deescalate_after = 2;
        let mut h = CrossbarHealth::new(32, 64, cfg, 7);
        let base = ReliabilityPolicy::none();
        let mut state = BitMatrix::zeros(32, 64);

        // A detected stuck cell: level 1 (+ECC).
        h.inject_stuck(3, 3, true);
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 1);
        assert_eq!(h.recommended_policy(base).ecc_m, Some(16));
        assert_eq!(h.recommended_policy(base).tmr, TmrMode::Off);

        // Uncorrectable pressure: level 2 (+TMR). The dirty pass that
        // found the stuck cell has already reset the clean streak.
        h.stats.scrub_uncorrectable = h.cfg.escalate_uncorrected;
        assert_eq!(h.recommended_policy(base).tmr, TmrMode::Serial);

        // Two clean passes (the stuck cell is known + remapped, so the
        // march finds nothing new): step back to level 1.
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 2, "one clean pass is not enough");
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 1, "ECC+TMR -> ECC after the clean streak");
        let p = h.recommended_policy(base);
        assert_eq!((p.ecc_m, p.tmr), (Some(16), TmrMode::Off));

        // Two more clean passes: fully back to the base policy.
        h.scrub(&mut state, None);
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 0, "ECC -> base after a second streak");
        assert_eq!(h.recommended_policy(base), base);

        // A fresh fault re-escalates: the floors were rebased, so one
        // *new* stuck cell suffices even though old telemetry is larger.
        h.inject_stuck(9, 20, false);
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 1);
        assert_eq!(h.recommended_policy(base).ecc_m, Some(16));

        // deescalate_after = 0 disables the path entirely.
        let mut h1 = CrossbarHealth::new(32, 64, immortal_cfg(4), 9);
        h1.inject_stuck(2, 2, true);
        h1.scrub(&mut state, None);
        for _ in 0..16 {
            h1.scrub(&mut state, None);
        }
        assert_eq!(h1.stats().level, 1, "escalation stays one-way by default");
    }

    #[test]
    fn exhaustion_never_deescalates() {
        // One spare, two bad active rows: the pool exhausts; the level
        // is pinned at 2 no matter how many clean passes follow.
        let mut cfg = immortal_cfg(1);
        cfg.deescalate_after = 1;
        cfg.retire_stuck_cells = 1000;
        let mut h = CrossbarHealth::new(16, 32, cfg, 3);
        let mut state = BitMatrix::zeros(16, 32);
        h.inject_stuck(1, 1, true);
        h.inject_stuck(2, 1, true);
        h.scrub(&mut state, None);
        assert_eq!(h.stats().level, 2);
        for _ in 0..4 {
            h.scrub(&mut state, None);
        }
        assert_eq!(h.stats().level, 2, "spare exhaustion is permanent");
    }

    #[test]
    fn retirement_on_exhaustion_and_fault_bound() {
        let mut state = BitMatrix::zeros(16, 32);
        let mut cfg = immortal_cfg(1);
        cfg.retire_stuck_cells = 1000;
        let mut h = CrossbarHealth::new(16, 32, cfg, 2);
        h.inject_stuck(1, 1, true);
        h.inject_stuck(2, 1, true);
        h.scrub(&mut state, None);
        assert!(h.should_retire(), "two bad active rows, one spare");
        let mut cfg = immortal_cfg(8);
        cfg.retire_stuck_cells = 2;
        let mut h = CrossbarHealth::new(16, 32, cfg, 2);
        h.inject_stuck(1, 1, true);
        h.inject_stuck(1, 5, false);
        h.scrub(&mut state, None);
        assert!(h.should_retire(), "fault population bound");
    }

    #[test]
    fn scrub_due_follows_interval() {
        let mut cfg = immortal_cfg(2);
        cfg.scrub_interval = 3;
        let mut h = CrossbarHealth::new(16, 32, cfg, 4);
        assert!(!h.scrub_due());
        h.on_batch(0, 0);
        h.on_batch(0, 0);
        assert!(!h.scrub_due());
        h.on_batch(0, 0);
        assert!(h.scrub_due());
        let mut state = BitMatrix::zeros(16, 32);
        h.scrub(&mut state, None);
        assert!(!h.scrub_due());
    }
}
