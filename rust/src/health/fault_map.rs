//! Ground-truth persistent-fault state of one crossbar: stuck-at cells
//! and the endurance wear-out process that creates them.
//!
//! Endurance model: each cell has a switches-to-failure budget drawn from
//! a lognormal distribution (the standard RRAM endurance fit). Rather
//! than carrying a per-cell switch counter on the hot path, the map
//! consumes the crossbar's aggregate `switched_bits` accounting: with
//! `S` total switches over `N` cells the mean per-cell wear is `S / N`,
//! and the expected dead-cell count is `N * Phi((ln(S/N) - ln mu) /
//! sigma)`. [`FaultMap::advance_wear`] tops the population up to that
//! expectation, sampling each new dead cell's position and stuck polarity
//! from its own deterministic stream — the marginal distribution matches
//! per-cell sampled budgets under uniform switching, at O(new faults)
//! cost instead of O(cells) per batch.
//!
//! A stuck cell ignores writes: the simulation realizes this by
//! *clamping* — after any phase that wrote the array, [`FaultMap::clamp`]
//! forces every stuck cell back to its stuck value.

use std::collections::HashSet;

use crate::util::bitmat::BitMatrix;
use crate::util::rng::Pcg64;
use crate::util::stats::normal_cdf;

/// Lognormal per-cell endurance (switches-to-failure) distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WearModel {
    /// Median switches-to-failure per cell (RRAM literature: 1e6..1e12).
    pub endurance_mean: f64,
    /// Lognormal sigma (log-space spread of per-cell budgets).
    pub endurance_sigma: f64,
}

impl WearModel {
    /// A realistic RRAM endurance point.
    pub fn rram() -> Self {
        Self { endurance_mean: 1e8, endurance_sigma: 0.6 }
    }

    /// Accelerated-aging variant for soak tests and demos.
    pub fn accelerated(endurance_mean: f64) -> Self {
        Self { endurance_mean, endurance_sigma: 0.5 }
    }

    /// No wear-out ever (isolates other fault mechanisms in tests).
    pub fn immortal() -> Self {
        Self { endurance_mean: f64::INFINITY, endurance_sigma: 1.0 }
    }

    /// Fraction of cells dead after `mean_switches` switches per cell.
    pub fn dead_fraction(&self, mean_switches: f64) -> f64 {
        if !self.endurance_mean.is_finite() || mean_switches <= 0.0 {
            return 0.0;
        }
        normal_cdf((mean_switches.ln() - self.endurance_mean.ln()) / self.endurance_sigma)
    }
}

impl Default for WearModel {
    fn default() -> Self {
        Self::rram()
    }
}

/// One permanently stuck cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StuckCell {
    pub row: u32,
    pub col: u32,
    /// The value the cell is frozen at.
    pub value: bool,
}

/// Sparse ground-truth map of a crossbar's permanent faults, grouped by
/// physical row so the per-row operations the march scrub leans on stay
/// O(faults in that row) rather than O(total faults).
#[derive(Clone, Debug)]
pub struct FaultMap {
    rows: usize,
    cols: usize,
    wear: WearModel,
    rng: Pcg64,
    /// `row -> stuck cells in that row`.
    by_row: std::collections::HashMap<u32, Vec<StuckCell>>,
    count: usize,
    occupied: HashSet<u64>,
    /// Cells killed by the wear process (excludes manual injections).
    wear_dead: usize,
}

impl FaultMap {
    pub fn new(rows: usize, cols: usize, wear: WearModel, seed: u64) -> Self {
        Self {
            rows,
            cols,
            wear,
            rng: Pcg64::new(seed, 0xFA17),
            by_row: std::collections::HashMap::new(),
            count: 0,
            occupied: HashSet::new(),
            wear_dead: 0,
        }
    }

    fn key(&self, row: u32, col: u32) -> u64 {
        row as u64 * self.cols as u64 + col as u64
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn wear(&self) -> &WearModel {
        &self.wear
    }

    /// Ground-truth stuck value of a cell, if it is stuck.
    pub fn stuck_at(&self, row: usize, col: usize) -> Option<bool> {
        self.by_row
            .get(&(row as u32))?
            .iter()
            .find(|s| s.col as usize == col)
            .map(|s| s.value)
    }

    /// Add a stuck cell (manual injection / wear). False if already stuck.
    pub fn inject(&mut self, row: u32, col: u32, value: bool) -> bool {
        assert!((row as usize) < self.rows && (col as usize) < self.cols);
        if !self.occupied.insert(self.key(row, col)) {
            return false;
        }
        self.by_row.entry(row).or_default().push(StuckCell { row, col, value });
        self.count += 1;
        true
    }

    /// Advance endurance wear-out given the crossbar's cumulative
    /// `switched_bits`. Returns the number of newly dead cells.
    pub fn advance_wear(&mut self, total_switched: u64) -> usize {
        let cells_total = self.rows * self.cols;
        let mean = total_switched as f64 / cells_total as f64;
        let want = (cells_total as f64 * self.wear.dead_fraction(mean)).floor() as usize;
        let want = want.min(cells_total);
        let mut added = 0;
        while self.wear_dead < want && self.occupied.len() < cells_total {
            let row = self.rng.below(self.rows as u64) as u32;
            let col = self.rng.below(self.cols as u64) as u32;
            let value = self.rng.bernoulli(0.5);
            if self.inject(row, col, value) {
                self.wear_dead += 1;
                added += 1;
            }
        }
        added
    }

    /// Force every stuck cell to its stuck value; returns bits changed.
    pub fn clamp(&self, state: &mut BitMatrix) -> u64 {
        let mut changed = 0;
        for cells in self.by_row.values() {
            for s in cells {
                let (r, c) = (s.row as usize, s.col as usize);
                if state.get(r, c) != s.value {
                    state.set(r, c, s.value);
                    changed += 1;
                }
            }
        }
        changed
    }

    /// Clamp only the stuck cells of one physical row
    /// (O(faults in that row) — the march scrub's inner loop).
    pub fn clamp_row(&self, state: &mut BitMatrix, row: usize) -> u64 {
        let mut changed = 0;
        if let Some(cells) = self.by_row.get(&(row as u32)) {
            for s in cells {
                if state.get(row, s.col as usize) != s.value {
                    state.set(row, s.col as usize, s.value);
                    changed += 1;
                }
            }
        }
        changed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wear_fraction_monotone_and_bounded() {
        let w = WearModel::accelerated(1e4);
        assert_eq!(w.dead_fraction(0.0), 0.0);
        let mut last = 0.0;
        for s in [1e2, 1e3, 1e4, 1e5, 1e6] {
            let f = w.dead_fraction(s);
            assert!((0.0..=1.0).contains(&f));
            assert!(f >= last, "monotone at {s}");
            last = f;
        }
        assert!((w.dead_fraction(1e4) - 0.5).abs() < 1e-6, "median budget");
        assert_eq!(WearModel::immortal().dead_fraction(1e30), 0.0);
    }

    #[test]
    fn advance_wear_tracks_expectation() {
        let mut fm = FaultMap::new(64, 64, WearModel::accelerated(100.0), 9);
        assert_eq!(fm.advance_wear(0), 0);
        // mean 100 switches/cell = the median budget: ~half the cells die.
        let cells = 64 * 64;
        fm.advance_wear(100 * cells as u64);
        let frac = fm.len() as f64 / cells as f64;
        assert!((frac - 0.5).abs() < 0.01, "dead fraction {frac}");
        // Monotone: never removes faults.
        let before = fm.len();
        fm.advance_wear(100 * cells as u64);
        assert_eq!(fm.len(), before);
    }

    #[test]
    fn clamp_forces_stuck_values() {
        let mut fm = FaultMap::new(8, 8, WearModel::immortal(), 1);
        assert!(fm.inject(2, 3, true));
        assert!(!fm.inject(2, 3, false), "double inject rejected");
        assert!(fm.inject(5, 1, false));
        let mut state = BitMatrix::zeros(8, 8);
        state.set(5, 1, true);
        let changed = fm.clamp(&mut state);
        assert_eq!(changed, 2);
        assert!(state.get(2, 3));
        assert!(!state.get(5, 1));
        assert_eq!(fm.clamp(&mut state), 0, "idempotent");
        assert_eq!(fm.stuck_at(2, 3), Some(true));
        assert_eq!(fm.stuck_at(0, 0), None);
        // Row-scoped clamp touches only that row.
        state.set(2, 3, false);
        state.set(5, 1, true);
        assert_eq!(fm.clamp_row(&mut state, 2), 1);
        assert!(state.get(5, 1), "other rows untouched");
    }
}
