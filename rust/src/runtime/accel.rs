//! The XLA-backed crossbar backend: runs whole micro-op programs on the
//! AOT gate-scan executor in ONE PJRT call (the Layer-2 `lax.scan` over
//! the Layer-1 Pallas gate kernel).
//!
//! Used as a cross-validation oracle for the native simulator and as the
//! demonstration that the three-layer architecture composes: the same
//! `EncodedProgram` bytes drive both backends to identical final states.

use anyhow::{ensure, Result};

use crate::errs::Injector;
use crate::isa::encode::{encode, EncodedProgram};
use crate::isa::program::Program;
use crate::util::bitmat::BitMatrix;

use super::executor::Runtime;

/// A crossbar whose program execution happens on the PJRT executor.
pub struct XlaCrossbar {
    state: BitMatrix,
}

impl XlaCrossbar {
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { state: BitMatrix::zeros(rows, cols) }
    }

    pub fn state(&self) -> &BitMatrix {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut BitMatrix {
        &mut self.state
    }

    /// Encode `prog` for the smallest fitting artifact.
    pub fn encode_for(&self, rt: &Runtime, prog: &Program) -> Result<EncodedProgram> {
        let flat_len = prog.flatten().len();
        let shape = rt.gate_scan_shape(self.state.rows(), self.state.cols(), flat_len)?;
        encode(prog, shape.s)
    }

    /// Run a program cleanly (no injected errors).
    pub fn run_program(&mut self, rt: &mut Runtime, prog: &Program) -> Result<()> {
        let enc = self.encode_for(rt, prog)?;
        let masks = vec![0f32; enc.steps * self.state.rows()];
        self.state = rt.run_gate_scan(&self.state, &enc, &masks)?;
        Ok(())
    }

    /// Run with direct soft errors sampled from `inj` (same model as the
    /// native path: p_gate on logic gates, p_write on init writes).
    pub fn run_program_with_errors(
        &mut self,
        rt: &mut Runtime,
        prog: &Program,
        inj: &mut Injector,
    ) -> Result<()> {
        let enc = self.encode_for(rt, prog)?;
        let masks = Runtime::sample_err_masks(&enc, self.state.rows(), inj);
        self.state = rt.run_gate_scan(&self.state, &enc, &masks)?;
        Ok(())
    }

    /// Run with explicit (steps x rows) masks — used by the
    /// cross-validation tests to drive both backends identically.
    pub fn run_program_with_masks(
        &mut self,
        rt: &mut Runtime,
        prog: &Program,
        masks: &[f32],
    ) -> Result<()> {
        let enc = self.encode_for(rt, prog)?;
        ensure!(masks.len() == enc.steps * self.state.rows(), "mask shape");
        self.state = rt.run_gate_scan(&self.state, &enc, masks)?;
        Ok(())
    }
}
