//! PJRT execution of the AOT-lowered JAX/Pallas artifacts.
//!
//! One `Runtime` holds the PJRT CPU client plus lazily-compiled
//! executables (HLO text -> XlaComputation -> PjRtLoadedExecutable, the
//! /opt/xla-example/load_hlo pattern). Python never runs here: the HLO
//! text was produced once by `make artifacts`.

use std::collections::HashMap;

use anyhow::{bail, ensure, Context, Result};

use crate::errs::Injector;
use crate::isa::encode::EncodedProgram;
use crate::isa::microop::Gate;
use crate::util::bitmat::BitMatrix;

use super::artifacts::Manifest;

/// Key for the executable cache.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct ExeKey(String);

/// The PJRT runtime: client + compiled executables + manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<ExeKey, xla::PjRtLoadedExecutable>,
}

/// Shape of a gate-scan executor artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GateScanShape {
    pub r: usize,
    pub c: usize,
    pub s: usize,
}

impl Runtime {
    /// Create against the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, name: &str, file: &std::path::Path) -> Result<()> {
        let key = ExeKey(name.to_string());
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {file:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(key, exe);
        Ok(())
    }

    fn exe(&self, name: &str) -> &xla::PjRtLoadedExecutable {
        &self.cache[&ExeKey(name.to_string())]
    }

    /// Pick the smallest gate-scan artifact that fits (r, c, >= steps).
    pub fn gate_scan_shape(&self, r: usize, c: usize, min_steps: usize) -> Result<GateScanShape> {
        self.gate_scan_pick(r, c, min_steps).map(|(shape, _, _)| shape)
    }

    /// Single-scan artifact selection: shape + compile name + file path
    /// in one pass over the manifest (run_gate_scan previously scanned
    /// twice — once in `gate_scan_shape`, once in `artifact_entry`).
    fn gate_scan_pick(
        &self,
        r: usize,
        c: usize,
        min_steps: usize,
    ) -> Result<(GateScanShape, String, std::path::PathBuf)> {
        let mut best: Option<(GateScanShape, String, std::path::PathBuf)> = None;
        for e in self.manifest.artifacts_of_kind("gate_scan") {
            let (ar, ac, as_) = (e.get_usize("r")?, e.get_usize("c")?, e.get_usize("s")?);
            let better = best.as_ref().map(|(b, _, _)| as_ < b.s).unwrap_or(true);
            if ar == r && ac == c && as_ >= min_steps && better {
                let name = e.get("name").context("artifact without name")?.to_string();
                let path = self.manifest.file_path(e)?;
                best = Some((GateScanShape { r: ar, c: ac, s: as_ }, name, path));
            }
        }
        best.with_context(|| {
            format!("no gate_scan artifact for r={r} c={c} steps>={min_steps}; see manifest")
        })
    }

    fn artifact_entry(&self, kind: &str, matcher: impl Fn(&super::artifacts::Entry) -> bool) -> Result<(String, std::path::PathBuf)> {
        for e in self.manifest.artifacts_of_kind(kind) {
            if matcher(e) {
                let name = e.get("name").context("artifact without name")?.to_string();
                let path = self.manifest.file_path(e)?;
                return Ok((name, path));
            }
        }
        bail!("no matching {kind} artifact")
    }

    /// Execute an encoded micro-op program on a crossbar state through
    /// the AOT gate-scan executor. `err_masks` is (steps x rows) f32
    /// {0,1} — per-step output flip masks (the direct soft-error model);
    /// pass an all-zero slice for a clean run.
    pub fn run_gate_scan(
        &mut self,
        state: &BitMatrix,
        enc: &EncodedProgram,
        err_masks: &[f32],
    ) -> Result<BitMatrix> {
        let (r, c) = (state.rows(), state.cols());
        let s = enc.steps;
        ensure!(err_masks.len() == s * r, "err mask shape mismatch");
        let (shape, name, path) = self.gate_scan_pick(r, c, s)?;
        ensure!(shape.s == s, "encoded program capacity {s} != artifact {}", shape.s);
        self.compile(&name, &path)?;

        let state_lit =
            xla::Literal::vec1(&state.to_f32_row_major()).reshape(&[r as i64, c as i64])?;
        let ops_lit = xla::Literal::vec1(&enc.ops).reshape(&[s as i64])?;
        let idx_lit = xla::Literal::vec1(&enc.idxs).reshape(&[s as i64, 4])?;
        let err_lit = xla::Literal::vec1(err_masks).reshape(&[s as i64, r as i64])?;

        let result = self
            .exe(&name)
            .execute::<xla::Literal>(&[state_lit, ops_lit, idx_lit, err_lit])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        ensure!(values.len() == r * c, "result shape mismatch");
        Ok(BitMatrix::from_f32_row_major(r, c, &values))
    }

    /// Build the (steps x rows) error-mask matrix for an encoded program
    /// from an injector — logic gates flip with p_gate, init writes with
    /// p_write, NOP never (mirrors the native simulator's model).
    pub fn sample_err_masks(enc: &EncodedProgram, rows: usize, inj: &mut Injector) -> Vec<f32> {
        let mut masks = vec![0f32; enc.steps * rows];
        for step in 0..enc.real_steps {
            let gate = Gate::from_opcode(enc.ops[step] as u8).expect("valid opcode");
            let base = step * rows;
            if gate.is_logic() {
                inj.gate_flips(rows, |i| masks[base + i] = 1.0);
            } else if gate.is_init() {
                inj.write_fails(rows, |i| masks[base + i] = 1.0);
            }
        }
        masks
    }

    /// Per-bit TMR vote of three (r x c) planes with faulty-gate masks.
    pub fn run_vote3(
        &mut self,
        a: &BitMatrix,
        b: &BitMatrix,
        c: &BitMatrix,
        err_min: &[f32],
        err_not: &[f32],
    ) -> Result<BitMatrix> {
        let (r, cc) = (a.rows(), a.cols());
        let (name, path) = self.artifact_entry("vote3", |e| {
            e.get_usize("r").ok() == Some(r) && e.get_usize("c").ok() == Some(cc)
        })?;
        self.compile(&name, &path)?;
        let lit = |m: &BitMatrix| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(&m.to_f32_row_major()).reshape(&[r as i64, cc as i64])?)
        };
        let err = |e: &[f32]| -> Result<xla::Literal> {
            ensure!(e.len() == r * cc, "err shape");
            Ok(xla::Literal::vec1(e).reshape(&[r as i64, cc as i64])?)
        };
        let result = self
            .exe(&name)
            .execute::<xla::Literal>(&[lit(a)?, lit(b)?, lit(c)?, err(err_min)?, err(err_not)?])?
            [0][0]
            .to_literal_sync()?;
        let values = result.to_tuple1()?.to_vec::<f32>()?;
        Ok(BitMatrix::from_f32_row_major(r, cc, &values))
    }

    /// Diagonal-parity extraction for a batch of m x m blocks:
    /// input (bsz x m x m) {0,1} floats, output (bsz x 2m).
    pub fn run_diag_parity(&mut self, blocks: &[f32], bsz: usize, m: usize) -> Result<Vec<f32>> {
        ensure!(blocks.len() == bsz * m * m, "block shape");
        let (name, path) = self.artifact_entry("diag_parity", |e| {
            e.get_usize("b").ok() == Some(bsz) && e.get_usize("m").ok() == Some(m)
        })?;
        self.compile(&name, &path)?;
        let lit = xla::Literal::vec1(blocks).reshape(&[bsz as i64, m as i64, m as i64])?;
        let result = self.exe(&name).execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// MicroNet forward pass with per-layer weight fault masks.
    /// Shapes follow the manifest (b, indim, h, classes).
    #[allow(clippy::too_many_arguments)]
    pub fn run_micronet(
        &mut self,
        batch: usize,
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        w2: &[f32],
        b2: &[f32],
        m1: &[f32],
        a1: &[f32],
        m2: &[f32],
        a2: &[f32],
    ) -> Result<Vec<f32>> {
        let (name, path) =
            self.artifact_entry("micronet", |e| e.get_usize("b").ok() == Some(batch))?;
        let entry = self
            .manifest
            .artifacts_of_kind("micronet")
            .find(|e| e.get_usize("b").ok() == Some(batch))
            .unwrap()
            .clone();
        let (ind, h, classes) = (
            entry.get_usize("indim")?,
            entry.get_usize("h")?,
            entry.get_usize("classes")?,
        );
        ensure!(x.len() == batch * ind, "x shape");
        ensure!(w1.len() == ind * h && m1.len() == ind * h && a1.len() == ind * h, "w1 shape");
        ensure!(w2.len() == h * classes && m2.len() == h * classes && a2.len() == h * classes);
        ensure!(b1.len() == h && b2.len() == classes);
        self.compile(&name, &path)?;
        let l = |v: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(v).reshape(dims)?)
        };
        let args = [
            l(x, &[batch as i64, ind as i64])?,
            l(w1, &[ind as i64, h as i64])?,
            l(b1, &[h as i64])?,
            l(w2, &[h as i64, classes as i64])?,
            l(b2, &[classes as i64])?,
            l(m1, &[ind as i64, h as i64])?,
            l(a1, &[ind as i64, h as i64])?,
            l(m2, &[h as i64, classes as i64])?,
            l(a2, &[h as i64, classes as i64])?,
        ];
        let result = self.exe(&name).execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}
