//! PJRT runtime (Layer 3 <-> Layer 1/2 bridge): loads the HLO-text
//! artifacts produced once by `make artifacts` and executes them through
//! the `xla` crate's PJRT CPU client. Python is never on this path.
//!
//! `accel::XlaCrossbar` wraps the gate-scan executor as an alternative
//! crossbar backend, cross-validated against the native bit-packed
//! simulator in `rust/tests/integration_runtime.rs`.

pub mod accel;
pub mod artifacts;
pub mod executor;

pub use accel::XlaCrossbar;
pub use artifacts::{read_f32_blob, Manifest};
pub use executor::{GateScanShape, Runtime};
