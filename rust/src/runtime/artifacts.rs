//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. Plain `key=value` lines — no serde needed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One manifest entry (an HLO artifact or a data blob).
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub record: String,
    pub fields: BTreeMap<String, String>,
}

impl Entry {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .with_context(|| format!("missing field {key}"))?
            .parse()
            .with_context(|| format!("bad usize field {key}"))
    }
}

/// Parsed manifest plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Locate the artifacts directory: `$REMUS_ARTIFACTS` or `artifacts/`
    /// relative to the current directory (the repo root under
    /// cargo test/bench/run).
    pub fn default_dir() -> PathBuf {
        std::env::var("REMUS_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| "artifacts".into())
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let mut entries = vec![];
        for (lno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let record = parts.next().unwrap().to_string();
            let mut fields = BTreeMap::new();
            for kv in parts {
                let Some((k, v)) = kv.split_once('=') else {
                    bail!("manifest line {}: bad field {kv:?}", lno + 1);
                };
                fields.insert(k.to_string(), v.to_string());
            }
            entries.push(Entry { record, fields });
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// All artifacts of a given kind.
    pub fn artifacts_of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a Entry> {
        self.entries
            .iter()
            .filter(move |e| e.record == "artifact" && e.get("kind") == Some(kind))
    }

    /// A unique non-artifact record (weights, evalset).
    pub fn record(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.record == name)
            .with_context(|| format!("manifest has no {name:?} record"))
    }

    pub fn file_path(&self, entry: &Entry) -> Result<PathBuf> {
        Ok(self.dir.join(entry.get("file").context("entry has no file field")?))
    }
}

/// Read a little-endian f32 binary blob.
pub fn read_f32_blob(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    anyhow::ensure!(bytes.len() % 4 == 0, "blob not a multiple of 4 bytes");
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact name=gate_scan_r64_c64_s64 file=gate_scan_r64_c64_s64.hlo.txt kind=gate_scan r=64 c=64 s=64
artifact name=vote3_r64_c64 file=vote3_r64_c64.hlo.txt kind=vote3 r=64 c=64

# comment
weights file=weights.bin h=32 indim=64 classes=10 train_acc=1.0000
";

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let gs: Vec<_> = m.artifacts_of_kind("gate_scan").collect();
        assert_eq!(gs.len(), 1);
        assert_eq!(gs[0].get_usize("s").unwrap(), 64);
        let w = m.record("weights").unwrap();
        assert_eq!(w.get_usize("h").unwrap(), 32);
        assert!(m.record("nonexistent").is_err());
    }

    #[test]
    fn rejects_bad_fields() {
        assert!(Manifest::parse(Path::new("/tmp"), "artifact garbage").is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Soft dependency: validate the real artifacts when present.
        if let Ok(m) = Manifest::load_default() {
            assert!(m.artifacts_of_kind("gate_scan").count() >= 1);
            assert!(m.artifacts_of_kind("micronet").count() >= 1);
            assert!(m.record("weights").is_ok());
        }
    }
}
