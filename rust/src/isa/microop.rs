//! The micro-op: one stateful gate applied in parallel across lanes.
//!
//! The mMPU controller decomposes arithmetic functions into micro-ops
//! (paper §III-B). An *in-row* micro-op names column indices and executes
//! simultaneously in every lane (row) of its lane range — Fig. 1(a). An
//! *in-column* micro-op is the transpose — Fig. 1(b).

pub use crate::xbar::gate::Gate;

/// Orientation of a micro-op.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dir {
    /// Operands are columns; one gate instance per row (row-parallel).
    InRow,
    /// Operands are rows; one gate instance per column (column-parallel).
    InCol,
}

/// Lane range [start, end) — which rows (InRow) / columns (InCol)
/// participate. `LaneRange::all()` is resolved against the crossbar size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct LaneRange {
    pub start: u32,
    /// Exclusive end; `u32::MAX` means "all lanes".
    pub end: u32,
}

impl LaneRange {
    pub fn all() -> Self {
        Self { start: 0, end: u32::MAX }
    }

    pub fn new(start: u32, end: u32) -> Self {
        assert!(start < end, "empty lane range");
        Self { start, end }
    }

    /// Resolve against an actual lane count.
    pub fn resolve(self, lanes: usize) -> (usize, usize) {
        let end = if self.end == u32::MAX { lanes } else { self.end as usize };
        assert!(end <= lanes && (self.start as usize) < end, "lane range out of bounds");
        (self.start as usize, end)
    }

    pub fn len_in(self, lanes: usize) -> usize {
        let (s, e) = self.resolve(lanes);
        e - s
    }
}

/// One stateful gate execution (broadcast across its lane range).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MicroOp {
    pub gate: Gate,
    pub dir: Dir,
    /// Operand line indices (columns for InRow, rows for InCol).
    /// Unused operands (arity < 3) must repeat `a` — keeps encode exact.
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub out: u32,
    pub lanes: LaneRange,
}

impl MicroOp {
    /// In-row op over all rows — the common case for single-row functions
    /// repeated across the crossbar.
    pub fn row(gate: Gate, operands: &[u32], out: u32) -> Self {
        Self::with_dir(Dir::InRow, gate, operands, out, LaneRange::all())
    }

    pub fn col(gate: Gate, operands: &[u32], out: u32) -> Self {
        Self::with_dir(Dir::InCol, gate, operands, out, LaneRange::all())
    }

    pub fn with_dir(dir: Dir, gate: Gate, operands: &[u32], out: u32, lanes: LaneRange) -> Self {
        assert_eq!(operands.len(), gate.arity(), "{gate:?} arity mismatch");
        let a = operands.first().copied().unwrap_or(out);
        let b = operands.get(1).copied().unwrap_or(a);
        let c = operands.get(2).copied().unwrap_or(a);
        if gate.is_logic() {
            for &o in operands {
                assert_ne!(o, out, "{gate:?}: output line aliases an input");
            }
        }
        Self { gate, dir, a, b, c, out, lanes }
    }

    /// Set the lane range (builder style).
    pub fn over(mut self, lanes: LaneRange) -> Self {
        self.lanes = lanes;
        self
    }

    /// The set of line indices this op touches (operands + output).
    pub fn lines(&self) -> Vec<u32> {
        let mut v = Vec::with_capacity(4);
        match self.gate.arity() {
            0 => {}
            1 => v.push(self.a),
            2 => v.extend([self.a, self.b]),
            _ => v.extend([self.a, self.b, self.c]),
        }
        v.push(self.out);
        v
    }

    /// Smallest / largest line touched — used for partition validation.
    pub fn line_span(&self) -> (u32, u32) {
        let ls = self.lines();
        (*ls.iter().min().unwrap(), *ls.iter().max().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_fills_unused_operands() {
        let op = MicroOp::row(Gate::Not, &[3], 7);
        assert_eq!((op.a, op.b, op.c, op.out), (3, 3, 3, 7));
        let op = MicroOp::row(Gate::Nor2, &[1, 2], 5);
        assert_eq!((op.a, op.b, op.c), (1, 2, 1));
        let op = MicroOp::row(Gate::Set1, &[], 9);
        assert_eq!(op.out, 9);
        assert_eq!(op.a, 9);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let _ = MicroOp::row(Gate::Nor2, &[1], 5);
    }

    #[test]
    #[should_panic]
    fn alias_panics() {
        let _ = MicroOp::row(Gate::Nor2, &[1, 5], 5);
    }

    #[test]
    fn lane_range_resolution() {
        assert_eq!(LaneRange::all().resolve(128), (0, 128));
        assert_eq!(LaneRange::new(8, 16).resolve(128), (8, 16));
        assert_eq!(LaneRange::new(8, 16).len_in(128), 8);
    }

    #[test]
    #[should_panic]
    fn lane_range_oob_panics() {
        LaneRange::new(8, 200).resolve(128);
    }

    #[test]
    fn lines_and_span() {
        let op = MicroOp::row(Gate::Min3, &[4, 9, 2], 11);
        assert_eq!(op.lines(), vec![4, 9, 2, 11]);
        assert_eq!(op.line_span(), (2, 11));
    }
}
