//! The mMPU micro-op ISA: micro-ops, cycle-grouped programs, compiled
//! execution plans, and the dense encoding used by the AOT (PJRT)
//! program executor.

pub mod encode;
pub mod microop;
pub mod plan;
pub mod program;

pub use encode::{encode, EncodedProgram};
pub use microop::{Dir, LaneRange, MicroOp};
pub use plan::{BundleFootprint, CompiledPlan, ScheduleConfig};
pub use program::{Program, RowProgramBuilder, Step};
