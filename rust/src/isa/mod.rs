//! The mMPU micro-op ISA: micro-ops, cycle-grouped programs, and the
//! dense encoding used by the AOT (PJRT) program executor.

pub mod encode;
pub mod microop;
pub mod program;

pub use encode::{encode, EncodedProgram};
pub use microop::{Dir, LaneRange, MicroOp};
pub use program::{Program, RowProgramBuilder, Step};
