//! Compiled execution plans (§Perf).
//!
//! `Crossbar::run_program` historically re-did three kinds of scalar work
//! on every execution of a program that never changes: per-step
//! concurrency validation (with its per-op partition lookups and
//! temporary allocations), per-op operand bounds checks, and per-word
//! lane-mask recomputation. A [`CompiledPlan`] hoists all of it to a
//! one-time compile against a crossbar shape + partition configuration:
//!
//! * concurrency rules (fan-out bundles, partition disjointness) are
//!   validated exactly once, at build time;
//! * every micro-op is resolved to a [`PlanOp`]: lane range, word range
//!   and first/last word masks precomputed;
//! * execution (`Crossbar::run_plan`) is a tight, allocation-free
//!   interpreter loop that is bit-identical to the legacy per-step path
//!   (`Crossbar::run_program_uncompiled`), including the error-injection
//!   stream — property-tested in `rust/tests/prop_plan_equivalence.rs`.
//!
//! Plans are immutable and `Send + Sync`, so the coordinator shares them
//! across workers behind `Arc` (see `mmpu::PlanCache`).

use anyhow::{ensure, Result};

use crate::util::bitmat::{tail_mask, words_for};
use crate::xbar::gate::Gate;
use crate::xbar::partition::Partitions;

use super::microop::{Dir, MicroOp};
use super::program::Program;

/// A fully resolved micro-op: no bounds checks, lane resolution or mask
/// arithmetic left for execution time.
#[derive(Clone, Copy, Debug)]
pub struct PlanOp {
    pub gate: Gate,
    pub dir: Dir,
    /// Input arity of `gate` (cached: avoids the match per execution).
    pub arity: u8,
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub out: u32,
    /// Resolved lane range [s, e): rows for `InRow`, columns for `InCol`.
    pub s: u32,
    pub e: u32,
    /// Word range of the lane span within a packed column (`InRow` only).
    pub w_lo: u32,
    pub w_hi: u32,
    /// Lane mask applied to word `w_lo` / `w_hi` (`InRow` only; the last
    /// mask already folds in the column tail mask).
    pub first_mask: u64,
    pub last_mask: u64,
}

impl PlanOp {
    /// Resolve an in-row op against a crossbar shape. Mirrors the bounds
    /// checks of the legacy `exec_in_row`, as `Err` instead of panics.
    pub(crate) fn resolve_in_row(op: &MicroOp, rows: usize, cols: usize) -> Result<PlanOp> {
        for &line in &[op.a, op.b, op.c, op.out] {
            ensure!((line as usize) < cols, "column {line} out of range");
        }
        let (s, e) = resolve_lanes(op, rows)?;
        let w_lo = s / 64;
        let w_hi = (e - 1) / 64;
        let first_mask = u64::MAX << (s % 64);
        let top = e - w_hi * 64;
        let mut last_mask = if top < 64 { (1u64 << top) - 1 } else { u64::MAX };
        if w_hi == words_for(rows) - 1 {
            last_mask &= tail_mask(rows);
        }
        Ok(PlanOp {
            gate: op.gate,
            dir: Dir::InRow,
            arity: op.gate.arity() as u8,
            a: op.a,
            b: op.b,
            c: op.c,
            out: op.out,
            s: s as u32,
            e: e as u32,
            w_lo: w_lo as u32,
            w_hi: w_hi as u32,
            first_mask,
            last_mask,
        })
    }

    /// Resolve an in-column op (operands are rows, lanes are columns).
    pub(crate) fn resolve_in_col(op: &MicroOp, rows: usize, cols: usize) -> Result<PlanOp> {
        for &line in &[op.a, op.b, op.c, op.out] {
            ensure!((line as usize) < rows, "row {line} out of range");
        }
        let (s, e) = resolve_lanes(op, cols)?;
        Ok(PlanOp {
            gate: op.gate,
            dir: Dir::InCol,
            arity: op.gate.arity() as u8,
            a: op.a,
            b: op.b,
            c: op.c,
            out: op.out,
            s: s as u32,
            e: e as u32,
            w_lo: 0,
            w_hi: 0,
            first_mask: 0,
            last_mask: 0,
        })
    }
}

fn resolve_lanes(op: &MicroOp, lanes: usize) -> Result<(usize, usize)> {
    let start = op.lanes.start as usize;
    let end = if op.lanes.end == u32::MAX { lanes } else { op.lanes.end as usize };
    ensure!(
        end <= lanes && start < end,
        "lane range {start}..{end} out of bounds for {lanes} lanes"
    );
    Ok((start, end))
}

/// Concurrency rules for one cycle (Fig. 1c) — shared by the legacy
/// per-step validator and plan compilation so both paths enforce
/// identical semantics:
/// * all ops share a direction;
/// * **fan-out**: ops applying the same gate to the same operands
///   (distinct outputs) form one multi-output gate — always legal;
/// * otherwise each group's touched partition range must be pairwise
///   disjoint from every other group's.
pub(crate) fn validate_step_concurrency(
    ops: &[MicroOp],
    col_parts: &Partitions,
    row_parts: &Partitions,
) -> Result<()> {
    let dir = ops[0].dir;
    ensure!(ops.iter().all(|o| o.dir == dir), "concurrent ops must share direction");
    // Group ops into fan-out bundles: ops applying the same gate to the
    // same operands form ONE multi-output gate (distinct outputs
    // required). Groups then claim partition ranges; ranges must be
    // pairwise disjoint across groups.
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new(); // (rep idx, member idxs)
    'op: for (i, op) in ops.iter().enumerate() {
        for (rep, members) in groups.iter_mut() {
            let r = &ops[*rep];
            if op.gate == r.gate && op.gate.arity() > 0 && op.a == r.a && op.b == r.b && op.c == r.c
            {
                members.push(i);
                continue 'op;
            }
        }
        groups.push((i, vec![i]));
    }
    for (_, members) in &groups {
        if members.len() > 1 {
            let mut outs: Vec<u32> = members.iter().map(|&i| ops[i].out).collect();
            outs.sort_unstable();
            outs.dedup();
            ensure!(outs.len() == members.len(), "fan-out outputs must be distinct");
        }
    }
    let parts = match dir {
        Dir::InRow => col_parts,
        Dir::InCol => row_parts,
    };
    let mut used = vec![false; parts.count()];
    for (_, members) in &groups {
        let mut lo = u32::MAX;
        let mut hi = 0u32;
        for &i in members {
            let (l, h) = ops[i].line_span();
            lo = lo.min(l);
            hi = hi.max(h);
        }
        let (p_lo, p_hi) = (parts.partition_of(lo), parts.partition_of(hi));
        for p in p_lo..=p_hi {
            ensure!(
                !used[p],
                "concurrent op groups conflict on partition {p} (lines {lo}..={hi})"
            );
            used[p] = true;
        }
    }
    Ok(())
}

/// A program compiled against a crossbar shape + partition configuration:
/// validated once, resolved once, executed many times.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    pub name: String,
    rows: usize,
    cols: usize,
    ops: Vec<PlanOp>,
    /// One `(start, end)` op range per crossbar cycle.
    steps: Vec<(u32, u32)>,
    /// Declared output columns (copied from the program).
    pub output_cols: Vec<u32>,
    /// Column partitions the plan's in-row concurrency was validated
    /// against (`None` when no step needed validation — such plans run
    /// under any partition configuration).
    col_parts: Option<Partitions>,
    /// Row partitions for in-column concurrency, same contract.
    row_parts: Option<Partitions>,
}

impl CompiledPlan {
    /// Compile `prog` for a `rows x cols` crossbar under the given
    /// partition configuration. Validation errors that the legacy path
    /// would raise mid-execution are surfaced here instead.
    pub fn compile(
        prog: &Program,
        rows: usize,
        cols: usize,
        col_parts: &Partitions,
        row_parts: &Partitions,
    ) -> Result<CompiledPlan> {
        ensure!(col_parts.lines() as usize == cols, "column partition size mismatch");
        ensure!(row_parts.lines() as usize == rows, "row partition size mismatch");
        let mut ops = Vec::with_capacity(prog.num_ops());
        let mut steps = Vec::with_capacity(prog.steps.len());
        let mut needs_col_parts = false;
        let mut needs_row_parts = false;
        for step in &prog.steps {
            ensure!(!step.ops.is_empty(), "empty step");
            if step.ops.len() > 1 {
                validate_step_concurrency(&step.ops, col_parts, row_parts)?;
                match step.ops[0].dir {
                    Dir::InRow => needs_col_parts = true,
                    Dir::InCol => needs_row_parts = true,
                }
            }
            let start = ops.len() as u32;
            for op in &step.ops {
                ops.push(match op.dir {
                    Dir::InRow => PlanOp::resolve_in_row(op, rows, cols)?,
                    Dir::InCol => PlanOp::resolve_in_col(op, rows, cols)?,
                });
            }
            steps.push((start, ops.len() as u32));
        }
        Ok(CompiledPlan {
            name: prog.name.clone(),
            rows,
            cols,
            ops,
            steps,
            output_cols: prog.output_cols.clone(),
            col_parts: needs_col_parts.then(|| col_parts.clone()),
            row_parts: needs_row_parts.then(|| row_parts.clone()),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Latency in crossbar cycles.
    pub fn cycles(&self) -> usize {
        self.steps.len()
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Column partitions required at execution time (`None`: any).
    pub fn required_col_partitions(&self) -> Option<&Partitions> {
        self.col_parts.as_ref()
    }

    pub fn required_row_partitions(&self) -> Option<&Partitions> {
        self.row_parts.as_ref()
    }

    /// Iterate `(ops-of-cycle)` slices — the executor's inner loop.
    #[inline]
    pub(crate) fn step_ops(&self) -> impl Iterator<Item = &[PlanOp]> + '_ {
        self.steps.iter().map(move |&(s, e)| &self.ops[s as usize..e as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::microop::LaneRange;
    use crate::isa::program::{RowProgramBuilder, Step};

    fn whole(rows: usize, cols: usize) -> (Partitions, Partitions) {
        (Partitions::whole(cols as u32), Partitions::whole(rows as u32))
    }

    #[test]
    fn compile_resolves_masks() {
        let mut b = RowProgramBuilder::no_init("t");
        b.gate(Gate::Nor2, &[0, 1], 2);
        let prog = b.finish();
        let (cp, rp) = whole(130, 8);
        let plan = CompiledPlan::compile(&prog, 130, 8, &cp, &rp).unwrap();
        assert_eq!(plan.cycles(), 1);
        let op = plan.step_ops().next().unwrap()[0];
        assert_eq!((op.s, op.e), (0, 130));
        assert_eq!((op.w_lo, op.w_hi), (0, 2));
        assert_eq!(op.first_mask, u64::MAX);
        assert_eq!(op.last_mask, (1u64 << 2) - 1, "130 rows -> 2 tail bits");
    }

    #[test]
    fn compile_resolves_lane_ranges() {
        let mut prog = Program::new("lanes");
        prog.push(MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(10, 20)));
        let (cp, rp) = whole(128, 4);
        let plan = CompiledPlan::compile(&prog, 128, 4, &cp, &rp).unwrap();
        let op = plan.step_ops().next().unwrap()[0];
        assert_eq!((op.s, op.e), (10, 20));
        assert_eq!((op.w_lo, op.w_hi), (0, 0));
        assert_eq!(op.first_mask & op.last_mask, ((1u64 << 20) - 1) & !((1u64 << 10) - 1));
    }

    #[test]
    fn compile_rejects_out_of_range() {
        let mut prog = Program::new("oob");
        prog.push(MicroOp::row(Gate::Not, &[7], 1));
        let (cp, rp) = whole(8, 4);
        assert!(CompiledPlan::compile(&prog, 8, 4, &cp, &rp).is_err());
        let mut prog = Program::new("oob-lanes");
        prog.push(MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(4, 200)));
        assert!(CompiledPlan::compile(&prog, 8, 4, &cp, &rp).is_err());
    }

    #[test]
    fn compile_validates_concurrency_once() {
        // Two NOTs in one cycle in the same partition: rejected at
        // compile time (the legacy path rejects at execution time).
        let mut prog = Program::new("conflict");
        prog.push_parallel(vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[2], 3),
        ]);
        let (cp, rp) = whole(8, 8);
        assert!(CompiledPlan::compile(&prog, 8, 8, &cp, &rp).is_err());
        // Legal under 2-column partitions, and the plan records them.
        let cp4 = Partitions::uniform(8, 4);
        let plan = CompiledPlan::compile(&prog, 8, 8, &cp4, &rp).unwrap();
        assert_eq!(plan.required_col_partitions(), Some(&cp4));
        assert_eq!(plan.required_row_partitions(), None);
    }

    #[test]
    fn single_op_steps_need_no_partitions() {
        let mut b = RowProgramBuilder::new("seq");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Not, &[2], 3);
        let prog = b.finish();
        let (cp, rp) = whole(16, 8);
        let plan = CompiledPlan::compile(&prog, 16, 8, &cp, &rp).unwrap();
        assert!(plan.required_col_partitions().is_none());
        assert_eq!(plan.cycles(), 4);
        assert_eq!(plan.num_ops(), 4);
    }

    #[test]
    fn empty_step_rejected() {
        let mut prog = Program::new("empty");
        prog.steps.push(Step { ops: vec![] });
        let (cp, rp) = whole(8, 8);
        assert!(CompiledPlan::compile(&prog, 8, 8, &cp, &rp).is_err());
    }
}
