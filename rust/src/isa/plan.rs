//! Compiled execution plans (§Perf).
//!
//! `Crossbar::run_program` historically re-did three kinds of scalar work
//! on every execution of a program that never changes: per-step
//! concurrency validation (with its per-op partition lookups and
//! temporary allocations), per-op operand bounds checks, and per-word
//! lane-mask recomputation. A [`CompiledPlan`] hoists all of it to a
//! one-time compile against a crossbar shape + partition configuration:
//!
//! * concurrency rules (fan-out bundles, partition disjointness) are
//!   validated exactly once, at build time;
//! * every micro-op is resolved to a [`PlanOp`]: lane range, word range
//!   and first/last word masks precomputed;
//! * execution (`Crossbar::run_plan`) is a tight, allocation-free
//!   interpreter loop that is bit-identical to the legacy per-step path
//!   (`Crossbar::run_program_uncompiled`), including the error-injection
//!   stream — property-tested in `rust/tests/prop_plan_equivalence.rs`.
//!
//! Plans are immutable and `Send + Sync`, so the coordinator shares them
//! across workers behind `Arc` (see `mmpu::PlanCache`).
//!
//! §Perf, list scheduling: beyond the serial program-order plan,
//! [`CompiledPlan::compile_scheduled`] runs compile-time dependency
//! analysis (RAW/WAR/WAW over the lines each micro-op reads and writes,
//! intersected with its lane span) and greedily packs independent ops
//! into shared cycles — *bundles* — subject to the same partition
//! disjointness and fan-out rules the per-step validator enforces
//! (paper Fig. 1c; PartitionPIM-style packing). The bundle schedule is
//! deterministic (greedy earliest-fit over the fixed program order),
//! never slower than the serial plan (it falls back to the serial step
//! structure when packing removes no cycles), and bit-identical to the
//! program-order reference in the clean model: independent ops touch
//! disjoint (line, lane) sets, so every op sees the same inputs and
//! writes the same output no matter which cycle it shares. Under error
//! injection the *serial* plan remains the bit-exact reference — the
//! injector stream is consumed in execution order, so packing legally
//! re-seats where transient faults land (`tests/prop_plan_equivalence.rs`
//! pins both contracts).

use anyhow::{ensure, Result};

use crate::util::bitmat::{tail_mask, words_for};
use crate::xbar::gate::Gate;
use crate::xbar::partition::Partitions;

use super::microop::{Dir, MicroOp};
use super::program::Program;

/// A fully resolved micro-op: no bounds checks, lane resolution or mask
/// arithmetic left for execution time.
#[derive(Clone, Copy, Debug)]
pub struct PlanOp {
    pub gate: Gate,
    pub dir: Dir,
    /// Input arity of `gate` (cached: avoids the match per execution).
    pub arity: u8,
    pub a: u32,
    pub b: u32,
    pub c: u32,
    pub out: u32,
    /// Resolved lane range [s, e): rows for `InRow`, columns for `InCol`.
    pub s: u32,
    pub e: u32,
    /// Word range of the lane span within a packed column (`InRow` only).
    pub w_lo: u32,
    pub w_hi: u32,
    /// Lane mask applied to word `w_lo` / `w_hi` (`InRow` only; the last
    /// mask already folds in the column tail mask).
    pub first_mask: u64,
    pub last_mask: u64,
}

impl PlanOp {
    /// Resolve an in-row op against a crossbar shape. Mirrors the bounds
    /// checks of the legacy `exec_in_row`, as `Err` instead of panics.
    pub(crate) fn resolve_in_row(op: &MicroOp, rows: usize, cols: usize) -> Result<PlanOp> {
        for &line in &[op.a, op.b, op.c, op.out] {
            ensure!((line as usize) < cols, "column {line} out of range");
        }
        let (s, e) = resolve_lanes(op, rows)?;
        let w_lo = s / 64;
        let w_hi = (e - 1) / 64;
        let first_mask = u64::MAX << (s % 64);
        let top = e - w_hi * 64;
        let mut last_mask = if top < 64 { (1u64 << top) - 1 } else { u64::MAX };
        if w_hi == words_for(rows) - 1 {
            last_mask &= tail_mask(rows);
        }
        Ok(PlanOp {
            gate: op.gate,
            dir: Dir::InRow,
            arity: op.gate.arity() as u8,
            a: op.a,
            b: op.b,
            c: op.c,
            out: op.out,
            s: s as u32,
            e: e as u32,
            w_lo: w_lo as u32,
            w_hi: w_hi as u32,
            first_mask,
            last_mask,
        })
    }

    /// Resolve an in-column op (operands are rows, lanes are columns).
    pub(crate) fn resolve_in_col(op: &MicroOp, rows: usize, cols: usize) -> Result<PlanOp> {
        for &line in &[op.a, op.b, op.c, op.out] {
            ensure!((line as usize) < rows, "row {line} out of range");
        }
        let (s, e) = resolve_lanes(op, cols)?;
        Ok(PlanOp {
            gate: op.gate,
            dir: Dir::InCol,
            arity: op.gate.arity() as u8,
            a: op.a,
            b: op.b,
            c: op.c,
            out: op.out,
            s: s as u32,
            e: e as u32,
            w_lo: 0,
            w_hi: 0,
            first_mask: 0,
            last_mask: 0,
        })
    }
}

fn resolve_lanes(op: &MicroOp, lanes: usize) -> Result<(usize, usize)> {
    let start = op.lanes.start as usize;
    let end = if op.lanes.end == u32::MAX { lanes } else { op.lanes.end as usize };
    ensure!(
        end <= lanes && start < end,
        "lane range {start}..{end} out of bounds for {lanes} lanes"
    );
    Ok((start, end))
}

/// Concurrency rules for one cycle (Fig. 1c) — shared by the legacy
/// per-step validator and plan compilation so both paths enforce
/// identical semantics:
/// * all ops share a direction;
/// * **fan-out**: ops applying the same gate to the same operands
///   (distinct outputs) form one multi-output gate — always legal;
/// * otherwise each group's touched partition range must be pairwise
///   disjoint from every other group's.
pub(crate) fn validate_step_concurrency(
    ops: &[MicroOp],
    col_parts: &Partitions,
    row_parts: &Partitions,
) -> Result<()> {
    let dir = ops[0].dir;
    ensure!(ops.iter().all(|o| o.dir == dir), "concurrent ops must share direction");
    // Group ops into fan-out bundles: ops applying the same gate to the
    // same operands form ONE multi-output gate (distinct outputs
    // required). Groups then claim partition ranges; ranges must be
    // pairwise disjoint across groups.
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new(); // (rep idx, member idxs)
    'op: for (i, op) in ops.iter().enumerate() {
        for (rep, members) in groups.iter_mut() {
            let r = &ops[*rep];
            if op.gate == r.gate && op.gate.arity() > 0 && op.a == r.a && op.b == r.b && op.c == r.c
            {
                members.push(i);
                continue 'op;
            }
        }
        groups.push((i, vec![i]));
    }
    for (_, members) in &groups {
        if members.len() > 1 {
            let mut outs: Vec<u32> = members.iter().map(|&i| ops[i].out).collect();
            outs.sort_unstable();
            outs.dedup();
            ensure!(outs.len() == members.len(), "fan-out outputs must be distinct");
        }
    }
    let parts = match dir {
        Dir::InRow => col_parts,
        Dir::InCol => row_parts,
    };
    let mut used = vec![false; parts.count()];
    for (_, members) in &groups {
        let mut lo = u32::MAX;
        let mut hi = 0u32;
        for &i in members {
            let (l, h) = ops[i].line_span();
            lo = lo.min(l);
            hi = hi.max(h);
        }
        let (p_lo, p_hi) = (parts.partition_of(lo), parts.partition_of(hi));
        for p in p_lo..=p_hi {
            ensure!(
                !used[p],
                "concurrent op groups conflict on partition {p} (lines {lo}..={hi})"
            );
            used[p] = true;
        }
    }
    Ok(())
}

/// §Perf: compile-time list-scheduling configuration, threaded from
/// `MmpuConfig`/`CoordinatorConfig` through the `PlanCache` key down to
/// [`CompiledPlan::compile_scheduled`]. Off by default everywhere: the
/// serial program-order plan stays the shipped behavior (and the
/// bit-exact noisy reference) until a caller opts in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ScheduleConfig {
    /// Pack independent micro-ops into shared cycles when true;
    /// otherwise compile the program-order serial plan.
    pub enabled: bool,
    /// Uniform column-partition grid (segment count) unioned with the
    /// boundaries the program/TMR layout already requires, licensing
    /// same-cycle in-row gates. `<= 1`: only the existing boundaries.
    pub partitions: u32,
}

impl ScheduleConfig {
    /// Serial program-order compilation (the default).
    pub fn off() -> Self {
        Self { enabled: false, partitions: 0 }
    }

    /// Dependency-scheduled packing over `partitions` column segments.
    pub fn packed(partitions: u32) -> Self {
        Self { enabled: true, partitions }
    }
}

impl Default for ScheduleConfig {
    fn default() -> Self {
        Self::off()
    }
}

/// Lines `op` reads: live operands plus the output line — the stateful
/// gate folds over the output's previous contents, so `out` is an input
/// too (`eval_word` consumes `prev`). Arity-0 ops mirror `out` into
/// every operand slot, collapsing to `{out}`.
fn reads(op: &MicroOp) -> Vec<u32> {
    op.lines()
}

/// Resolved lane interval [s, e) of `op` against its lane count. Only
/// called after serial compilation validated the range, so the clamp
/// cannot underflow.
fn lane_interval(op: &MicroOp, lanes: usize) -> (u32, u32) {
    let e = if op.lanes.end == u32::MAX { lanes as u32 } else { op.lanes.end };
    (op.lanes.start, e)
}

/// Compile-time dependency test: must `later` stay ordered after
/// `earlier`? True on any RAW/WAR/WAW hazard — one op's write line in
/// the other's read set — restricted to overlapping lane spans (two ops
/// on the same line but disjoint lanes touch disjoint cells). Ops of
/// different directions always conflict: an in-row op's cell footprint
/// is (its lanes x its columns) while an in-column op's is (its rows x
/// its lanes), and a precise cross product is not worth the risk — the
/// conservative order preserves the reference semantics.
fn conflicts(earlier: &MicroOp, later: &MicroOp, rows: usize, cols: usize) -> bool {
    if earlier.dir != later.dir {
        return true;
    }
    let lanes = match earlier.dir {
        Dir::InRow => rows,
        Dir::InCol => cols,
    };
    let (s1, e1) = lane_interval(earlier, lanes);
    let (s2, e2) = lane_interval(later, lanes);
    if s1.max(s2) >= e1.min(e2) {
        return false;
    }
    reads(later).contains(&earlier.out) || reads(earlier).contains(&later.out)
}

/// Greedy earliest-fit list scheduler: walk the flattened program in
/// order; each op lands in the first cycle at or after all of its
/// dependencies whose bundle admits it under the frozen concurrency
/// rules ([`validate_step_concurrency`] — shared direction, fan-out
/// grouping, pairwise-disjoint partition claims). Deterministic by
/// construction: no hashing, no tie-breaking, fixed iteration order.
/// Returns op indices per bundle (program order within each bundle).
fn schedule_ops(
    flat: &[MicroOp],
    rows: usize,
    cols: usize,
    col_parts: &Partitions,
    row_parts: &Partitions,
) -> Vec<Vec<usize>> {
    let mut cycle_of: Vec<usize> = Vec::with_capacity(flat.len());
    let mut bundles: Vec<Vec<MicroOp>> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, op) in flat.iter().enumerate() {
        let mut earliest = 0usize;
        for (j, done) in flat[..i].iter().enumerate() {
            if conflicts(done, op, rows, cols) {
                earliest = earliest.max(cycle_of[j] + 1);
            }
        }
        // Ops already placed in a candidate bundle never conflict with
        // `op` (a conflicting predecessor would have pushed `earliest`
        // past its cycle), so admission is purely the concurrency rules.
        let mut placed = None;
        for c in earliest..bundles.len() {
            bundles[c].push(*op);
            if validate_step_concurrency(&bundles[c], col_parts, row_parts).is_ok() {
                placed = Some(c);
                break;
            }
            bundles[c].pop();
        }
        let c = placed.unwrap_or_else(|| {
            bundles.push(vec![*op]);
            members.push(Vec::new());
            bundles.len() - 1
        });
        members[c].push(i);
        cycle_of.push(c);
    }
    members
}

/// Per-cycle driver footprint of one bundle (§Perf): the union of the
/// member lane spans and, for in-row bundles, the fused word range +
/// boundary masks their word-parallel drivers activate together. Not
/// consulted by the interpreter (each member keeps its own resolved
/// masks, preserving bit-exactness) — this is the schedule's shape,
/// used by the packing telemetry and pinned by tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BundleFootprint {
    pub dir: Dir,
    /// Fused lane span [lane_lo, lane_hi) across the members.
    pub lane_lo: u32,
    pub lane_hi: u32,
    /// Fused word range + boundary masks (`InRow` only; zero otherwise).
    pub w_lo: u32,
    pub w_hi: u32,
    pub first_mask: u64,
    pub last_mask: u64,
}

impl BundleFootprint {
    fn of(ops: &[PlanOp]) -> BundleFootprint {
        let dir = ops[0].dir;
        let lane_lo = ops.iter().map(|o| o.s).min().unwrap();
        let lane_hi = ops.iter().map(|o| o.e).max().unwrap();
        if dir == Dir::InCol {
            return BundleFootprint {
                dir,
                lane_lo,
                lane_hi,
                w_lo: 0,
                w_hi: 0,
                first_mask: 0,
                last_mask: 0,
            };
        }
        let w_lo = ops.iter().map(|o| o.w_lo).min().unwrap();
        let w_hi = ops.iter().map(|o| o.w_hi).max().unwrap();
        // Fused boundary masks: which lanes of the extremal words any
        // member drives this cycle.
        let first_mask = ops
            .iter()
            .filter(|o| o.w_lo == w_lo)
            .fold(0u64, |m, o| m | if o.w_lo == o.w_hi { o.first_mask & o.last_mask } else { o.first_mask });
        let last_mask = ops
            .iter()
            .filter(|o| o.w_hi == w_hi)
            .fold(0u64, |m, o| m | if o.w_lo == o.w_hi { o.first_mask & o.last_mask } else { o.last_mask });
        BundleFootprint { dir, lane_lo, lane_hi, w_lo, w_hi, first_mask, last_mask }
    }
}

/// A program compiled against a crossbar shape + partition configuration:
/// validated once, resolved once, executed many times.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    pub name: String,
    rows: usize,
    cols: usize,
    ops: Vec<PlanOp>,
    /// One `(start, end)` op range per crossbar cycle — the bundle
    /// schedule. Serial plans are the 1-step-per-program-step case.
    steps: Vec<(u32, u32)>,
    /// Per-bundle fused driver footprints, parallel to `steps`.
    footprints: Vec<BundleFootprint>,
    /// Whether dependency scheduling reordered/packed the ops (false:
    /// program order, the bit-exact noisy reference).
    scheduled: bool,
    /// Declared output columns (copied from the program).
    pub output_cols: Vec<u32>,
    /// Column partitions the plan's in-row concurrency was validated
    /// against (`None` when no step needed validation — such plans run
    /// under any partition configuration).
    col_parts: Option<Partitions>,
    /// Row partitions for in-column concurrency, same contract.
    row_parts: Option<Partitions>,
}

impl CompiledPlan {
    /// Compile `prog` for a `rows x cols` crossbar under the given
    /// partition configuration. Validation errors that the legacy path
    /// would raise mid-execution are surfaced here instead.
    pub fn compile(
        prog: &Program,
        rows: usize,
        cols: usize,
        col_parts: &Partitions,
        row_parts: &Partitions,
    ) -> Result<CompiledPlan> {
        ensure!(col_parts.lines() as usize == cols, "column partition size mismatch");
        ensure!(row_parts.lines() as usize == rows, "row partition size mismatch");
        let mut ops = Vec::with_capacity(prog.num_ops());
        let mut steps = Vec::with_capacity(prog.steps.len());
        let mut footprints = Vec::with_capacity(prog.steps.len());
        let mut needs_col_parts = false;
        let mut needs_row_parts = false;
        for step in &prog.steps {
            ensure!(!step.ops.is_empty(), "empty step");
            if step.ops.len() > 1 {
                validate_step_concurrency(&step.ops, col_parts, row_parts)?;
                match step.ops[0].dir {
                    Dir::InRow => needs_col_parts = true,
                    Dir::InCol => needs_row_parts = true,
                }
            }
            let start = ops.len() as u32;
            for op in &step.ops {
                ops.push(match op.dir {
                    Dir::InRow => PlanOp::resolve_in_row(op, rows, cols)?,
                    Dir::InCol => PlanOp::resolve_in_col(op, rows, cols)?,
                });
            }
            steps.push((start, ops.len() as u32));
            footprints.push(BundleFootprint::of(&ops[start as usize..]));
        }
        Ok(CompiledPlan {
            name: prog.name.clone(),
            rows,
            cols,
            ops,
            steps,
            footprints,
            scheduled: false,
            output_cols: prog.output_cols.clone(),
            col_parts: needs_col_parts.then(|| col_parts.clone()),
            row_parts: needs_row_parts.then(|| row_parts.clone()),
        })
    }

    /// Compile `prog` with dependency scheduling (§Perf): pack
    /// independent micro-ops into shared cycles across the column
    /// partitions of `sched` (refined over `col_parts`, so every
    /// boundary the program/TMR layout already requires survives and
    /// originally-parallel steps stay valid). Falls back to the serial
    /// plan — byte-for-byte, including its (unrefined) partition
    /// requirements — when scheduling is off or packing removes no
    /// cycles, which makes `cycles(scheduled) <= cycles(serial)`
    /// mechanical rather than probabilistic.
    pub fn compile_scheduled(
        prog: &Program,
        rows: usize,
        cols: usize,
        col_parts: &Partitions,
        row_parts: &Partitions,
        sched: ScheduleConfig,
    ) -> Result<CompiledPlan> {
        // Serial compilation first: it owns validation (bounds, lane
        // ranges, declared concurrency) and is the fallback plan.
        let serial = Self::compile(prog, rows, cols, col_parts, row_parts)?;
        if !sched.enabled {
            return Ok(serial);
        }
        let packed_parts = if sched.partitions > 1 {
            col_parts.refined_with_grid(sched.partitions)
        } else {
            col_parts.clone()
        };
        let flat: Vec<MicroOp> =
            prog.steps.iter().flat_map(|s| s.ops.iter().copied()).collect();
        let members = schedule_ops(&flat, rows, cols, &packed_parts, row_parts);
        if members.len() >= serial.cycles() {
            return Ok(serial);
        }
        let mut ops = Vec::with_capacity(flat.len());
        let mut steps = Vec::with_capacity(members.len());
        let mut footprints = Vec::with_capacity(members.len());
        let mut needs_col_parts = false;
        let mut needs_row_parts = false;
        for bundle in &members {
            if bundle.len() > 1 {
                match flat[bundle[0]].dir {
                    Dir::InRow => needs_col_parts = true,
                    Dir::InCol => needs_row_parts = true,
                }
            }
            let start = ops.len() as u32;
            for &i in bundle {
                let op = &flat[i];
                ops.push(match op.dir {
                    Dir::InRow => PlanOp::resolve_in_row(op, rows, cols)?,
                    Dir::InCol => PlanOp::resolve_in_col(op, rows, cols)?,
                });
            }
            steps.push((start, ops.len() as u32));
            footprints.push(BundleFootprint::of(&ops[start as usize..]));
        }
        Ok(CompiledPlan {
            name: prog.name.clone(),
            rows,
            cols,
            ops,
            steps,
            footprints,
            scheduled: true,
            output_cols: prog.output_cols.clone(),
            col_parts: needs_col_parts.then(|| packed_parts.clone()),
            row_parts: needs_row_parts.then(|| row_parts.clone()),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Latency in crossbar cycles.
    pub fn cycles(&self) -> usize {
        self.steps.len()
    }

    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Whether dependency scheduling packed this plan (false: serial
    /// program order, the bit-exact noisy reference).
    pub fn is_scheduled(&self) -> bool {
        self.scheduled
    }

    /// Micro-ops per cycle — the schedule's packing factor (1.0 for a
    /// fully serial plan; > 1.0 when bundles share cycles).
    pub fn packing_factor(&self) -> f64 {
        if self.steps.is_empty() {
            return 1.0;
        }
        self.ops.len() as f64 / self.steps.len() as f64
    }

    /// Ops per bundle, in schedule order (determinism is asserted over
    /// this shape: same program + config -> same sizes every compile).
    pub fn bundle_sizes(&self) -> Vec<u32> {
        self.steps.iter().map(|&(s, e)| e - s).collect()
    }

    /// Per-bundle fused driver footprints, parallel to the schedule.
    pub fn footprints(&self) -> &[BundleFootprint] {
        &self.footprints
    }

    /// Column partitions required at execution time (`None`: any).
    pub fn required_col_partitions(&self) -> Option<&Partitions> {
        self.col_parts.as_ref()
    }

    pub fn required_row_partitions(&self) -> Option<&Partitions> {
        self.row_parts.as_ref()
    }

    /// Iterate `(ops-of-cycle)` slices — the executor's inner loop.
    #[inline]
    pub(crate) fn step_ops(&self) -> impl Iterator<Item = &[PlanOp]> + '_ {
        self.steps.iter().map(move |&(s, e)| &self.ops[s as usize..e as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::microop::LaneRange;
    use crate::isa::program::{RowProgramBuilder, Step};

    fn whole(rows: usize, cols: usize) -> (Partitions, Partitions) {
        (Partitions::whole(cols as u32), Partitions::whole(rows as u32))
    }

    #[test]
    fn compile_resolves_masks() {
        let mut b = RowProgramBuilder::no_init("t");
        b.gate(Gate::Nor2, &[0, 1], 2);
        let prog = b.finish();
        let (cp, rp) = whole(130, 8);
        let plan = CompiledPlan::compile(&prog, 130, 8, &cp, &rp).unwrap();
        assert_eq!(plan.cycles(), 1);
        let op = plan.step_ops().next().unwrap()[0];
        assert_eq!((op.s, op.e), (0, 130));
        assert_eq!((op.w_lo, op.w_hi), (0, 2));
        assert_eq!(op.first_mask, u64::MAX);
        assert_eq!(op.last_mask, (1u64 << 2) - 1, "130 rows -> 2 tail bits");
    }

    #[test]
    fn compile_resolves_lane_ranges() {
        let mut prog = Program::new("lanes");
        prog.push(MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(10, 20)));
        let (cp, rp) = whole(128, 4);
        let plan = CompiledPlan::compile(&prog, 128, 4, &cp, &rp).unwrap();
        let op = plan.step_ops().next().unwrap()[0];
        assert_eq!((op.s, op.e), (10, 20));
        assert_eq!((op.w_lo, op.w_hi), (0, 0));
        assert_eq!(op.first_mask & op.last_mask, ((1u64 << 20) - 1) & !((1u64 << 10) - 1));
    }

    #[test]
    fn compile_rejects_out_of_range() {
        let mut prog = Program::new("oob");
        prog.push(MicroOp::row(Gate::Not, &[7], 1));
        let (cp, rp) = whole(8, 4);
        assert!(CompiledPlan::compile(&prog, 8, 4, &cp, &rp).is_err());
        let mut prog = Program::new("oob-lanes");
        prog.push(MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(4, 200)));
        assert!(CompiledPlan::compile(&prog, 8, 4, &cp, &rp).is_err());
    }

    #[test]
    fn compile_validates_concurrency_once() {
        // Two NOTs in one cycle in the same partition: rejected at
        // compile time (the legacy path rejects at execution time).
        let mut prog = Program::new("conflict");
        prog.push_parallel(vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[2], 3),
        ]);
        let (cp, rp) = whole(8, 8);
        assert!(CompiledPlan::compile(&prog, 8, 8, &cp, &rp).is_err());
        // Legal under 2-column partitions, and the plan records them.
        let cp4 = Partitions::uniform(8, 4);
        let plan = CompiledPlan::compile(&prog, 8, 8, &cp4, &rp).unwrap();
        assert_eq!(plan.required_col_partitions(), Some(&cp4));
        assert_eq!(plan.required_row_partitions(), None);
    }

    #[test]
    fn single_op_steps_need_no_partitions() {
        let mut b = RowProgramBuilder::new("seq");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Not, &[2], 3);
        let prog = b.finish();
        let (cp, rp) = whole(16, 8);
        let plan = CompiledPlan::compile(&prog, 16, 8, &cp, &rp).unwrap();
        assert!(plan.required_col_partitions().is_none());
        assert_eq!(plan.cycles(), 4);
        assert_eq!(plan.num_ops(), 4);
    }

    #[test]
    fn empty_step_rejected() {
        let mut prog = Program::new("empty");
        prog.steps.push(Step { ops: vec![] });
        let (cp, rp) = whole(8, 8);
        assert!(CompiledPlan::compile(&prog, 8, 8, &cp, &rp).is_err());
    }

    #[test]
    fn scheduler_packs_independent_ops_across_partitions() {
        // Two independent NOTs in separate program steps: serial takes 2
        // cycles, the scheduler packs them into 1 under a 2-segment grid.
        let mut prog = Program::new("pack");
        prog.push(MicroOp::row(Gate::Not, &[0], 1));
        prog.push(MicroOp::row(Gate::Not, &[4], 5));
        let (cp, rp) = whole(8, 8);
        let serial = CompiledPlan::compile(&prog, 8, 8, &cp, &rp).unwrap();
        assert_eq!(serial.cycles(), 2);
        assert!(!serial.is_scheduled());
        let plan =
            CompiledPlan::compile_scheduled(&prog, 8, 8, &cp, &rp, ScheduleConfig::packed(2))
                .unwrap();
        assert!(plan.is_scheduled());
        assert_eq!(plan.cycles(), 1);
        assert_eq!(plan.num_ops(), 2, "packing never drops ops");
        assert_eq!(plan.bundle_sizes(), vec![2]);
        assert!((plan.packing_factor() - 2.0).abs() < 1e-12);
        // The packed plan requires the refined grid it was scheduled for.
        let grid = cp.refined_with_grid(2);
        assert_eq!(plan.required_col_partitions(), Some(&grid));
    }

    #[test]
    fn dependent_chain_falls_back_to_serial_plan() {
        // RAW chain: nothing can pack, so compile_scheduled returns the
        // serial plan itself — including its (unrefined) partition
        // requirements. This is the mechanical cycles(sched) <= serial.
        let mut prog = Program::new("chain");
        prog.push(MicroOp::row(Gate::Not, &[0], 1));
        prog.push(MicroOp::row(Gate::Not, &[1], 2));
        prog.push(MicroOp::row(Gate::Not, &[2], 3));
        let (cp, rp) = whole(8, 8);
        let plan =
            CompiledPlan::compile_scheduled(&prog, 8, 8, &cp, &rp, ScheduleConfig::packed(8))
                .unwrap();
        assert!(!plan.is_scheduled(), "no packing possible -> serial fallback");
        assert_eq!(plan.cycles(), 3);
        assert!(
            plan.required_col_partitions().is_none(),
            "fallback keeps the serial plan's partition requirements"
        );
    }

    #[test]
    fn scheduling_is_deterministic_and_never_slower() {
        let mut prog = Program::new("mix");
        prog.push(MicroOp::row(Gate::Nor2, &[0, 1], 2));
        prog.push(MicroOp::row(Gate::Not, &[4], 5));
        prog.push(MicroOp::row(Gate::Nor2, &[2, 5], 6));
        prog.push(MicroOp::row(Gate::Not, &[3], 7));
        let (cp, rp) = whole(16, 16);
        let sched = ScheduleConfig::packed(4);
        let a = CompiledPlan::compile_scheduled(&prog, 16, 16, &cp, &rp, sched).unwrap();
        let b = CompiledPlan::compile_scheduled(&prog, 16, 16, &cp, &rp, sched).unwrap();
        assert_eq!(a.bundle_sizes(), b.bundle_sizes());
        assert_eq!(a.footprints(), b.footprints());
        assert_eq!(a.cycles(), b.cycles());
        let serial = CompiledPlan::compile(&prog, 16, 16, &cp, &rp).unwrap();
        assert!(a.cycles() <= serial.cycles());
        assert_eq!(a.num_ops(), serial.num_ops());
        // ops 0+1 are independent (cycle 0); op 2 reads both outputs
        // (cycle 1); op 3 is independent but its span straddles the
        // claimed segments, so it lands alone (cycle 2).
        assert_eq!(a.cycles(), 3);
        assert_eq!(a.bundle_sizes(), vec![2, 1, 1]);
    }

    #[test]
    fn bundle_footprints_fuse_word_masks() {
        // Members in different words of the packed column: the fused
        // footprint spans both words, with each boundary mask showing
        // only the lanes actually driven there.
        let mut prog = Program::new("fuse");
        prog.push(MicroOp::row(Gate::Not, &[0], 1).over(LaneRange::new(0, 10)));
        prog.push(MicroOp::row(Gate::Not, &[4], 5).over(LaneRange::new(64, 70)));
        let (cp, rp) = whole(128, 8);
        let plan =
            CompiledPlan::compile_scheduled(&prog, 128, 8, &cp, &rp, ScheduleConfig::packed(2))
                .unwrap();
        assert!(plan.is_scheduled());
        assert_eq!(plan.cycles(), 1);
        let fp = plan.footprints()[0];
        assert_eq!(fp.dir, Dir::InRow);
        assert_eq!((fp.lane_lo, fp.lane_hi), (0, 70));
        assert_eq!((fp.w_lo, fp.w_hi), (0, 1));
        assert_eq!(fp.first_mask, (1u64 << 10) - 1, "word 0: lanes 0..10 only");
        assert_eq!(fp.last_mask, (1u64 << 6) - 1, "word 1: lanes 64..70 only");
    }

    #[test]
    fn schedule_off_returns_the_serial_plan() {
        let mut b = RowProgramBuilder::new("seq");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Not, &[2], 3);
        let prog = b.finish();
        let (cp, rp) = whole(16, 8);
        let serial = CompiledPlan::compile(&prog, 16, 8, &cp, &rp).unwrap();
        let off =
            CompiledPlan::compile_scheduled(&prog, 16, 8, &cp, &rp, ScheduleConfig::off())
                .unwrap();
        assert!(!off.is_scheduled());
        assert_eq!(off.bundle_sizes(), serial.bundle_sizes());
        assert_eq!(off.footprints(), serial.footprints());
        assert_eq!(off.cycles(), serial.cycles());
    }

    #[test]
    fn in_col_ops_keep_program_order_under_whole_row_partitions() {
        // The scheduler only refines the *column* grid; in-column ops
        // pack only as far as the existing row partitions allow. Under a
        // whole-array row configuration they stay serial.
        let mut prog = Program::new("col");
        prog.push(MicroOp::col(Gate::Not, &[0], 1));
        prog.push(MicroOp::col(Gate::Not, &[4], 5));
        let (cp, rp) = whole(8, 8);
        let plan =
            CompiledPlan::compile_scheduled(&prog, 8, 8, &cp, &rp, ScheduleConfig::packed(8))
                .unwrap();
        assert!(!plan.is_scheduled());
        assert_eq!(plan.cycles(), 2);
    }
}
