//! Encoding of micro-op programs into the dense arrays consumed by the
//! AOT gate-scan executor (`artifacts/gate_scan_*.hlo.txt`).
//!
//! The executor's signature (see python/compile/model.py::gate_scan):
//!   state (R, C) f32, ops (S,) i32, idxs (S, 4) i32, errs (S, R) f32.
//! Programs shorter than S are NOP-padded (NOP is a no-op in both the
//! rust simulator and the executor — verified by tests on both sides).

use anyhow::{bail, Result};

use crate::isa::microop::{Dir, MicroOp};
use crate::isa::program::Program;
use crate::xbar::gate::Gate;

/// Dense program encoding, ready to convert into PJRT literals.
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedProgram {
    /// Static step capacity S (NOP-padded).
    pub steps: usize,
    pub ops: Vec<i32>,
    /// S x 4 row-major [a, b, c, out].
    pub idxs: Vec<i32>,
    /// Number of real (non-pad) steps.
    pub real_steps: usize,
}

/// Lower IMPLY to the executor's gate set.
///
/// IMPLY reuses the output memristor as an operand, which the executor's
/// encoding cannot express; the mMPU controller schedules IMPLY only on
/// the native simulator path. Encoding a program containing IMPLY is an
/// error surfaced to the caller.
pub fn encode(prog: &Program, capacity: usize) -> Result<EncodedProgram> {
    let flat = prog.flatten();
    if flat.len() > capacity {
        bail!(
            "program {} has {} ops > executor capacity {}",
            prog.name,
            flat.len(),
            capacity
        );
    }
    let mut ops = Vec::with_capacity(capacity);
    let mut idxs = Vec::with_capacity(capacity * 4);
    for op in &flat {
        if op.dir != Dir::InRow {
            bail!("only in-row programs are encodable (op {:?} is in-column)", op.gate);
        }
        if op.gate == Gate::Imply {
            bail!("IMPLY is not encodable for the AOT executor");
        }
        if op.lanes != crate::isa::microop::LaneRange::all() {
            bail!("lane-restricted ops are not encodable (executor is all-rows)");
        }
        ops.push(op.gate.opcode() as i32);
        idxs.extend([op.a as i32, op.b as i32, op.c as i32, op.out as i32]);
    }
    let real_steps = flat.len();
    while ops.len() < capacity {
        ops.push(Gate::Nop.opcode() as i32);
        idxs.extend([0, 0, 0, 0]);
    }
    Ok(EncodedProgram { steps: capacity, ops, idxs, real_steps })
}

/// Decode back into a (serial) program — used by round-trip tests.
pub fn decode(enc: &EncodedProgram) -> Result<Vec<MicroOp>> {
    let mut out = Vec::new();
    for s in 0..enc.real_steps {
        let gate = match Gate::from_opcode(enc.ops[s] as u8) {
            Some(g) => g,
            None => bail!("bad opcode {}", enc.ops[s]),
        };
        let i = &enc.idxs[s * 4..s * 4 + 4];
        let operands: Vec<u32> = match gate.arity() {
            0 => vec![],
            1 => vec![i[0] as u32],
            2 => vec![i[0] as u32, i[1] as u32],
            _ => vec![i[0] as u32, i[1] as u32, i[2] as u32],
        };
        out.push(MicroOp::row(gate, &operands, i[3] as u32));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::RowProgramBuilder;

    fn sample_program() -> Program {
        let mut b = RowProgramBuilder::new("enc-test");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Min3, &[0, 1, 2], 3);
        b.gate(Gate::Not, &[3], 4);
        b.finish()
    }

    #[test]
    fn encode_pads_with_nops() {
        let p = sample_program();
        let enc = encode(&p, 16).unwrap();
        assert_eq!(enc.steps, 16);
        assert_eq!(enc.ops.len(), 16);
        assert_eq!(enc.idxs.len(), 64);
        assert_eq!(enc.real_steps, 6); // 3 logic + 3 auto-init SET1
        assert!(enc.ops[6..].iter().all(|&o| o == 0));
    }

    #[test]
    fn encode_rejects_overflow() {
        let p = sample_program();
        assert!(encode(&p, 3).is_err());
    }

    #[test]
    fn roundtrip() {
        let p = sample_program();
        let enc = encode(&p, 8).unwrap();
        let dec = decode(&enc).unwrap();
        assert_eq!(dec, p.flatten());
    }

    #[test]
    fn rejects_imply_and_in_col() {
        let mut p = Program::new("imply");
        p.push(MicroOp::row(Gate::Imply, &[0], 1));
        assert!(encode(&p, 8).is_err());
        let mut p = Program::new("incol");
        p.push(MicroOp::col(Gate::Not, &[0], 1));
        assert!(encode(&p, 8).is_err());
    }

    #[test]
    fn opcode_values_match_python_ref() {
        // The contract with python/compile/kernels/ref.py — keep frozen.
        assert_eq!(Gate::Nop.opcode(), 0);
        assert_eq!(Gate::Not.opcode(), 1);
        assert_eq!(Gate::Nor2.opcode(), 2);
        assert_eq!(Gate::Nor3.opcode(), 3);
        assert_eq!(Gate::Or2.opcode(), 4);
        assert_eq!(Gate::Nand2.opcode(), 5);
        assert_eq!(Gate::Min3.opcode(), 6);
        assert_eq!(Gate::Set1.opcode(), 7);
        assert_eq!(Gate::Set0.opcode(), 8);
    }
}
