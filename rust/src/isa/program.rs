//! Programs: sequences of cycles, each cycle holding one or more
//! micro-ops that execute concurrently (partition parallelism, Fig. 1c).

use std::fmt;

use crate::xbar::gate::Gate;

use super::microop::{Dir, MicroOp};

/// One crossbar cycle: all contained micro-ops fire simultaneously.
/// Concurrency is legal only across disjoint partitions (validated by
/// `isa::validate` against a partition configuration).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Step {
    pub ops: Vec<MicroOp>,
}

impl Step {
    pub fn one(op: MicroOp) -> Self {
        Self { ops: vec![op] }
    }

    pub fn many(ops: Vec<MicroOp>) -> Self {
        assert!(!ops.is_empty(), "empty step");
        Self { ops }
    }
}

/// A synthesized in-memory function: micro-op schedule plus interface
/// metadata (which columns hold inputs/outputs, how many work columns).
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub name: String,
    pub steps: Vec<Step>,
    /// Columns holding function inputs (must be valid before execution).
    pub input_cols: Vec<u32>,
    /// Columns holding function outputs (ECC must cover them afterwards).
    pub output_cols: Vec<u32>,
    /// Total columns used (inputs + intermediates + outputs).
    pub width: u32,
    /// Column-partition starts this program's parallel steps assume
    /// (empty = single partition).
    pub partition_starts: Vec<u32>,
}

impl Program {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    pub fn push(&mut self, op: MicroOp) {
        self.track_width_op(&op);
        self.steps.push(Step::one(op));
    }

    /// Push a cycle of concurrent ops (one per partition).
    pub fn push_parallel(&mut self, ops: Vec<MicroOp>) {
        for op in &ops {
            self.track_width_op(op);
        }
        self.steps.push(Step::many(ops));
    }

    fn track_width_op(&mut self, op: &MicroOp) {
        if op.dir == Dir::InRow {
            let (_, hi) = op.line_span();
            self.width = self.width.max(hi + 1);
        }
    }

    /// Latency in crossbar cycles.
    pub fn cycles(&self) -> usize {
        self.steps.len()
    }

    /// Total gate executions *per lane* that are soft-error sites
    /// (logic gates; init SETs counted separately).
    pub fn logic_gates_per_lane(&self) -> usize {
        self.steps.iter().flat_map(|s| &s.ops).filter(|o| o.gate.is_logic()).count()
    }

    pub fn init_writes_per_lane(&self) -> usize {
        self.steps.iter().flat_map(|s| &s.ops).filter(|o| o.gate.is_init()).count()
    }

    /// Total micro-ops (all cycles).
    pub fn num_ops(&self) -> usize {
        self.steps.iter().map(|s| s.ops.len()).sum()
    }

    /// Maximum concurrent ops in any cycle (partition pressure).
    pub fn max_parallelism(&self) -> usize {
        self.steps.iter().map(|s| s.ops.len()).max().unwrap_or(0)
    }

    /// Serialize concurrency away: one op per cycle, program-order
    /// preserved. Used by the AOT executor encoding (whose scan applies
    /// one op per step) — the final state is identical because concurrent
    /// ops touch disjoint lines.
    pub fn flatten(&self) -> Vec<MicroOp> {
        self.steps.iter().flat_map(|s| s.ops.iter().copied()).collect()
    }

    /// Append another program's steps (columns must already be disjoint /
    /// coordinated by the caller).
    pub fn extend(&mut self, other: &Program) {
        self.steps.extend(other.steps.iter().cloned());
        self.width = self.width.max(other.width);
    }

    /// Relocate every column index by `offset` (placing a single-row
    /// function at a different column base, e.g. for the parallel-TMR
    /// copies in separate partitions).
    pub fn relocate(&self, offset: u32) -> Program {
        let mut p = self.clone();
        let shift = |x: &mut u32| *x += offset;
        for s in &mut p.steps {
            for op in &mut s.ops {
                if op.gate.arity() >= 1 {
                    shift(&mut op.a);
                }
                shift(&mut op.b);
                shift(&mut op.c);
                shift(&mut op.out);
                // Unused operand convention: b/c mirror a when arity < 3;
                // relocation preserves that because all shift equally.
                if op.gate.arity() == 0 {
                    op.a = op.out;
                    op.b = op.out;
                    op.c = op.out;
                }
            }
        }
        for c in p.input_cols.iter_mut().chain(p.output_cols.iter_mut()) {
            *c += offset;
        }
        for s in p.partition_starts.iter_mut() {
            *s += offset;
        }
        p.width += offset;
        p
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "program {}: {} cycles, {} ops ({} logic/lane, {} init/lane), width {}",
            self.name,
            self.cycles(),
            self.num_ops(),
            self.logic_gates_per_lane(),
            self.init_writes_per_lane(),
            self.width
        )
    }
}

/// Builder helper: sequential single-partition program writer with
/// automatic MAGIC-style output initialization.
pub struct RowProgramBuilder {
    prog: Program,
    /// Emit a SET1 init before every logic gate (MAGIC/FELIX requirement);
    /// disable to model idealized init-free scheduling.
    pub auto_init: bool,
}

impl RowProgramBuilder {
    pub fn new(name: &str) -> Self {
        Self { prog: Program::new(name), auto_init: true }
    }

    pub fn no_init(name: &str) -> Self {
        Self { prog: Program::new(name), auto_init: false }
    }

    /// Emit `out = gate(operands)` (plus the init write when enabled).
    pub fn gate(&mut self, gate: Gate, operands: &[u32], out: u32) -> u32 {
        if self.auto_init && gate.is_logic() {
            self.prog.push(MicroOp::row(Gate::Set1, &[], out));
        }
        self.prog.push(MicroOp::row(gate, operands, out));
        out
    }

    pub fn set0(&mut self, out: u32) -> u32 {
        self.prog.push(MicroOp::row(Gate::Set0, &[], out));
        out
    }

    pub fn set1(&mut self, out: u32) -> u32 {
        self.prog.push(MicroOp::row(Gate::Set1, &[], out));
        out
    }

    pub fn inputs(&mut self, cols: &[u32]) {
        self.prog.input_cols.extend_from_slice(cols);
    }

    pub fn outputs(&mut self, cols: &[u32]) {
        self.prog.output_cols.extend_from_slice(cols);
    }

    pub fn finish(self) -> Program {
        self.prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xbar::gate::Gate;

    #[test]
    fn counts() {
        let mut b = RowProgramBuilder::new("t");
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.gate(Gate::Not, &[2], 3);
        let p = b.finish();
        assert_eq!(p.cycles(), 4); // 2 init + 2 logic
        assert_eq!(p.logic_gates_per_lane(), 2);
        assert_eq!(p.init_writes_per_lane(), 2);
        assert_eq!(p.width, 4);
    }

    #[test]
    fn no_init_builder() {
        let mut b = RowProgramBuilder::no_init("t");
        b.gate(Gate::Nor2, &[0, 1], 2);
        let p = b.finish();
        assert_eq!(p.cycles(), 1);
        assert_eq!(p.init_writes_per_lane(), 0);
    }

    #[test]
    fn relocate_shifts_everything() {
        let mut b = RowProgramBuilder::no_init("t");
        b.inputs(&[0, 1]);
        b.gate(Gate::Nor2, &[0, 1], 2);
        b.outputs(&[2]);
        let p = b.finish().relocate(10);
        let op = p.steps[0].ops[0];
        assert_eq!((op.a, op.b, op.out), (10, 11, 12));
        assert_eq!(p.input_cols, vec![10, 11]);
        assert_eq!(p.output_cols, vec![12]);
    }

    #[test]
    fn flatten_preserves_order() {
        let mut p = Program::new("par");
        p.push_parallel(vec![
            MicroOp::row(Gate::Not, &[0], 1),
            MicroOp::row(Gate::Not, &[2], 3),
        ]);
        p.push(MicroOp::row(Gate::Nor2, &[1, 3], 4));
        assert_eq!(p.cycles(), 2);
        assert_eq!(p.num_ops(), 3);
        assert_eq!(p.max_parallelism(), 2);
        let flat = p.flatten();
        assert_eq!(flat.len(), 3);
        assert_eq!(flat[2].gate, Gate::Nor2);
    }
}
