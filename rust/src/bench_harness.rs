//! In-tree micro-benchmark harness (criterion is not in the offline
//! vendor set). Provides warmup + repeated timed runs, median/MAD
//! reporting, and throughput lines, with output formatted consistently
//! across all `rust/benches/*` targets so EXPERIMENTS.md can quote them.
//!
//! Machine-readable mode: a bench target calls [`json_begin`] once at
//! startup and [`json_end`] at exit; every `bench`/`throughput` call in
//! between is also recorded and written as `BENCH_<name>.json` (used by
//! CI to archive the §Perf numbers; see EXPERIMENTS.md).

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One recorded benchmark for the JSON report.
struct JsonEntry {
    name: String,
    median_ns: u128,
    mad_ns: u128,
    iters_per_run: u64,
    throughput: Vec<(String, f64)>,
}

/// One recorded named scalar (a derived quantity that is not a timing,
/// e.g. a packing factor or a knee QPS) for the JSON report.
struct JsonScalar {
    name: String,
    unit: String,
    value: f64,
}

/// Active JSON collector: (report name, entries, scalars).
static JSON: Mutex<Option<(String, Vec<JsonEntry>, Vec<JsonScalar>)>> = Mutex::new(None);

/// Start recording benches into a machine-readable report named
/// `BENCH_<name>.json`. No-op for benches that never call it.
pub fn json_begin(name: &str) {
    *JSON.lock().unwrap() = Some((name.to_string(), Vec::new(), Vec::new()));
}

/// Record a named scalar into the active JSON report (top-level
/// `"scalars"` array) and print it in the standard bench format. Used
/// for derived, dimensionless-or-not quantities CI wants to diff that
/// are not wall-clock timings — e.g. packing factors. No-op (print
/// only) when no report is active.
pub fn json_scalar(name: &str, unit: &str, value: f64) {
    println!("scalar {name:<43} {value:>12.4} {unit}");
    if let Some((_, _, scalars)) = JSON.lock().unwrap().as_mut() {
        scalars.push(JsonScalar {
            name: name.to_string(),
            unit: unit.to_string(),
            value,
        });
    }
}

/// Write the recorded report to `BENCH_<name>.json` in the current
/// directory and stop recording. Returns the path when a report was
/// active and written.
pub fn json_end() -> Option<std::path::PathBuf> {
    let (name, entries, scalars) = JSON.lock().unwrap().take()?;
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", escape(&name)));
    out.push_str("  \"results\": [\n");
    for (i, e) in entries.iter().enumerate() {
        let ns_per_iter = e.median_ns as f64 / e.iters_per_run as f64;
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ns\": {}, \"mad_ns\": {}, \
             \"iters_per_run\": {}, \"ns_per_iter\": {:.3}, \"throughput\": [",
            escape(&e.name),
            e.median_ns,
            e.mad_ns,
            e.iters_per_run,
            ns_per_iter
        ));
        for (j, (unit, per_sec)) in e.throughput.iter().enumerate() {
            out.push_str(&format!("{{\"unit\": \"{}\", \"per_sec\": {:e}}}", escape(unit), per_sec));
            if j + 1 < e.throughput.len() {
                out.push_str(", ");
            }
        }
        out.push_str("]}");
        out.push_str(if i + 1 < entries.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"scalars\": [\n");
    for (i, s) in scalars.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"unit\": \"{}\", \"value\": {:e}}}",
            escape(&s.name),
            escape(&s.unit),
            s.value
        ));
        out.push_str(if i + 1 < scalars.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    let path = std::path::PathBuf::from(format!("BENCH_{name}.json"));
    if let Err(e) = std::fs::write(&path, out) {
        eprintln!("warning: could not write {path:?}: {e}");
        return None;
    }
    println!("(machine-readable results written to {})", path.display());
    Some(path)
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_record(r: &BenchResult) {
    if let Some((_, entries, _)) = JSON.lock().unwrap().as_mut() {
        entries.push(JsonEntry {
            name: r.name.clone(),
            median_ns: r.median.as_nanos(),
            mad_ns: r.mad.as_nanos(),
            iters_per_run: r.iters_per_run,
            throughput: Vec::new(),
        });
    }
}

fn json_record_throughput(unit: &str, per_sec: f64) {
    if let Some((_, entries, _)) = JSON.lock().unwrap().as_mut() {
        if let Some(last) = entries.last_mut() {
            last.throughput.push((unit.to_string(), per_sec));
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub iters_per_run: u64,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_run as f64
    }
}

/// Run `f` (which performs `iters_per_run` logical iterations) repeatedly
/// and report the median wall time.
pub fn bench<F: FnMut()>(name: &str, iters_per_run: u64, mut f: F) -> BenchResult {
    // Warmup: run until ~100 ms or 3 runs, whichever first.
    let warm_start = Instant::now();
    let mut warm_runs = 0;
    while warm_runs < 3 || (warm_start.elapsed() < Duration::from_millis(100) && warm_runs < 50) {
        f();
        warm_runs += 1;
    }
    // Measure.
    let runs = 9;
    let mut samples: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[runs / 2];
    let mad = {
        let mut devs: Vec<i128> = samples
            .iter()
            .map(|s| (s.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        devs.sort();
        Duration::from_nanos(devs[runs / 2] as u64)
    };
    let r = BenchResult { name: name.to_string(), median, mad, iters_per_run };
    println!(
        "bench {:<44} {:>12.3} ms/run  ±{:>8.3}  {:>14.1} ns/iter",
        r.name,
        r.median.as_secs_f64() * 1e3,
        r.mad.as_secs_f64() * 1e3,
        r.per_iter_ns()
    );
    json_record(&r);
    r
}

/// Print a throughput line derived from a bench result.
pub fn throughput(r: &BenchResult, unit: &str, units_per_run: f64) {
    let per_sec = units_per_run / r.median.as_secs_f64();
    println!("      -> {:.3e} {unit}/s", per_sec);
    json_record_throughput(unit, per_sec);
}

/// Standard bench header so every target announces itself the same way.
pub fn header(title: &str, paper_ref: &str) {
    println!("\n################################################################");
    println!("# {title}");
    println!("# reproduces: {paper_ref}");
    println!("################################################################");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_mode_writes_report() {
        json_begin("harness_selftest");
        let r = bench("json-selftest", 10, || {
            std::hint::black_box(0u64);
        });
        throughput(&r, "op", 10.0);
        json_scalar("selftest packing factor", "ops/bundle", 2.5);
        let path = json_end().expect("report written");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"bench\": \"harness_selftest\""));
        assert!(text.contains("json-selftest"));
        assert!(text.contains("\"unit\": \"op\""));
        assert!(text.contains("\"scalars\""));
        assert!(text.contains("selftest packing factor"));
        assert!(text.contains("\"unit\": \"ops/bundle\""));
        assert!(text.contains("2.5e0"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-loop", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.per_iter_ns() < 1e6);
        throughput(&r, "iter", 1000.0);
    }
}
