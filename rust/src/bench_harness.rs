//! In-tree micro-benchmark harness (criterion is not in the offline
//! vendor set). Provides warmup + repeated timed runs, median/MAD
//! reporting, and throughput lines, with output formatted consistently
//! across all `rust/benches/*` targets so EXPERIMENTS.md can quote them.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub iters_per_run: u64,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_run as f64
    }
}

/// Run `f` (which performs `iters_per_run` logical iterations) repeatedly
/// and report the median wall time.
pub fn bench<F: FnMut()>(name: &str, iters_per_run: u64, mut f: F) -> BenchResult {
    // Warmup: run until ~100 ms or 3 runs, whichever first.
    let warm_start = Instant::now();
    let mut warm_runs = 0;
    while warm_runs < 3 || (warm_start.elapsed() < Duration::from_millis(100) && warm_runs < 50) {
        f();
        warm_runs += 1;
    }
    // Measure.
    let runs = 9;
    let mut samples: Vec<Duration> = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[runs / 2];
    let mad = {
        let mut devs: Vec<i128> = samples
            .iter()
            .map(|s| (s.as_nanos() as i128 - median.as_nanos() as i128).abs())
            .collect();
        devs.sort();
        Duration::from_nanos(devs[runs / 2] as u64)
    };
    let r = BenchResult { name: name.to_string(), median, mad, iters_per_run };
    println!(
        "bench {:<44} {:>12.3} ms/run  ±{:>8.3}  {:>14.1} ns/iter",
        r.name,
        r.median.as_secs_f64() * 1e3,
        r.mad.as_secs_f64() * 1e3,
        r.per_iter_ns()
    );
    r
}

/// Print a throughput line derived from a bench result.
pub fn throughput(r: &BenchResult, unit: &str, units_per_run: f64) {
    let per_sec = units_per_run / r.median.as_secs_f64();
    println!("      -> {:.3e} {unit}/s", per_sec);
}

/// Standard bench header so every target announces itself the same way.
pub fn header(title: &str, paper_ref: &str) {
    println!("\n################################################################");
    println!("# {title}");
    println!("# reproduces: {paper_ref}");
    println!("################################################################");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-loop", 1000, || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            std::hint::black_box(acc);
        });
        assert!(r.median.as_nanos() > 0);
        assert!(r.per_iter_ns() < 1e6);
        throughput(&r, "iter", 1000.0);
    }
}
