//! Neural-network case study (paper §VI) — substrate S10/S11.
//!
//! Two tiers, mirroring the paper:
//! * **Analytical AlexNet/FloatPIM** (`alexnet`, `degradation`): the
//!   paper's constants (M = 612e6 multiplications/sample, W = 62M
//!   weights, p_mask = 0.03 %, inherent top-1 error 27 %) and its
//!   extrapolation formulas — these regenerate Fig. 4 (bottom) and Fig. 5.
//! * **Executable MicroNet** (`micronet`, `quant`): the small MLP trained
//!   at build time (python/compile/train.py), whose inference actually
//!   runs through the mMPU simulator multiplication by multiplication —
//!   validating the error-propagation mechanism end-to-end on real
//!   hardware-path code (examples/nn_inference.rs).

pub mod alexnet;
pub mod degradation;
pub mod micronet;
pub mod quant;

pub use alexnet::AlexNetModel;
pub use micronet::{EvalSet, MicroNet};
pub use quant::Fixed;
