//! The analytical AlexNet/FloatPIM model (paper §VI-B).
//!
//! The paper treats the large-scale accelerator analytically: AlexNet on
//! FloatPIM performs `M = 612e6` multiplications per sample over `W =
//! 62M` 32-bit weights; per G. Li et al. [45], only `p_mask = 0.03 %` of
//! soft errors affect the final classification; the network's inherent
//! top-1 error is ~27 %. This module encodes those constants, the layer
//! table they derive from, and the feed-forward reliability formula.

use crate::util::stats::one_minus_pow;

/// One AlexNet layer (enough structure to recover the paper's counts).
#[derive(Clone, Copy, Debug)]
pub struct Layer {
    pub name: &'static str,
    /// Weights in this layer.
    pub weights: u64,
    /// Multiplications per sample (weights x spatial reuse).
    pub mults: u64,
}

/// AlexNet (ImageNet, 32-bit fixed point on FloatPIM).
#[derive(Clone, Debug)]
pub struct AlexNetModel {
    pub layers: Vec<Layer>,
    /// Fraction of soft errors that affect classification [45].
    pub p_mask: f64,
    /// Inherent top-1 classification error.
    pub inherent_error: f64,
}

impl AlexNetModel {
    pub fn paper() -> Self {
        // Standard AlexNet shapes; mults = output spatial positions x
        // kernel volume x output channels (conv) or weights (fc).
        // Grouped convolutions (the original two-GPU AlexNet: conv2/4/5
        // use groups=2), which is what FloatPIM maps.
        let layers = vec![
            Layer { name: "conv1", weights: 34_848, mults: 105_415_200 },
            Layer { name: "conv2", weights: 307_200, mults: 223_948_800 },
            Layer { name: "conv3", weights: 884_736, mults: 149_520_384 },
            Layer { name: "conv4", weights: 663_552, mults: 112_140_288 },
            Layer { name: "conv5", weights: 442_368, mults: 74_760_192 },
            Layer { name: "fc6", weights: 37_748_736, mults: 37_748_736 },
            Layer { name: "fc7", weights: 16_777_216, mults: 16_777_216 },
            Layer { name: "fc8", weights: 4_096_000, mults: 4_096_000 },
        ];
        Self { layers, p_mask: 3e-4, inherent_error: 0.27 }
    }

    /// Total weights W (paper: 62M).
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights).sum()
    }

    /// Total multiplications per sample (paper: 612e6). The paper's
    /// number counts the FloatPIM mapping; our layer table reproduces the
    /// same order of magnitude and the paper constant is used for the
    /// figure reproduction.
    pub fn total_mults(&self) -> u64 {
        self.layers.iter().map(|l| l.mults).sum()
    }

    /// The paper's constant M (used by the Fig. 4 bottom reproduction).
    pub const M_PAPER: f64 = 612e6;
    /// The paper's constant W.
    pub const W_PAPER: f64 = 62e6;

    /// Probability of soft-error-induced misclassification given the
    /// per-multiplication failure probability:
    /// `1 - (1 - p_mask * p_mult)^M` (paper §VI-B1).
    pub fn p_network(&self, p_mult: f64) -> f64 {
        one_minus_pow(self.p_mask * p_mult, Self::M_PAPER)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_paper() {
        let m = AlexNetModel::paper();
        let w = m.total_weights() as f64;
        assert!((w - 62e6).abs() / 62e6 < 0.03, "W = {w}");
        let mults = m.total_mults() as f64;
        assert!(
            (mults - 612e6).abs() / 612e6 < 0.25,
            "mults {mults} close to the paper's 612e6"
        );
        assert_eq!(m.p_mask, 3e-4);
    }

    #[test]
    fn paper_operating_points() {
        // Fig 4 bottom anchor: baseline p_mult at p_gate = 1e-9 produces
        // ~74 % misclassification => implied p_mult ~= 7.3e-6.
        let m = AlexNetModel::paper();
        let p = m.p_network(7.3e-6);
        assert!((p - 0.74).abs() < 0.03, "p = {p}");
        // TMR at ~1.1e-7 => ~2 %.
        let p = m.p_network(1.1e-7);
        assert!((p - 0.02).abs() < 0.005, "p = {p}");
    }

    #[test]
    fn p_network_monotone() {
        let m = AlexNetModel::paper();
        let mut last = 0.0;
        for e in [-12i32, -10, -8, -6, -4] {
            let p = m.p_network(10f64.powi(e));
            assert!(p >= last);
            last = p;
        }
        assert_eq!(m.p_network(0.0), 0.0);
    }
}
