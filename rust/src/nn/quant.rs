//! Fixed-point arithmetic (Q8.8 in 16-bit words) for running MicroNet on
//! the mMPU's unsigned integer multiplier. Signs are handled
//! sign-magnitude style by the layer code (the crossbar multiplies
//! magnitudes; FloatPIM-style accelerators handle exponent/sign in
//! separate bit fields the same way).

/// Q8.8 fixed-point value held as sign + 16-bit magnitude.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fixed {
    pub neg: bool,
    /// Magnitude in Q8.8 (0..=65535, i.e. |x| < 256.0).
    pub mag: u16,
}

pub const FRAC_BITS: u32 = 8;
pub const SCALE: f32 = 256.0;

impl Fixed {
    pub fn from_f32(x: f32) -> Self {
        let neg = x < 0.0;
        let mag = (x.abs() * SCALE).round().min(u16::MAX as f32) as u16;
        Self { neg, mag }
    }

    pub fn to_f32(self) -> f32 {
        let v = self.mag as f32 / SCALE;
        if self.neg {
            -v
        } else {
            v
        }
    }

    pub fn zero() -> Self {
        Self { neg: false, mag: 0 }
    }

    /// The signed Q16.16 product of two Q8.8 magnitudes as computed by a
    /// 16x16 -> 32-bit unsigned in-memory multiplication.
    pub fn product_i64(self, other: Fixed) -> i64 {
        let p = (self.mag as i64) * (other.mag as i64); // Q16.16
        if self.neg != other.neg {
            -p
        } else {
            p
        }
    }
}

/// Accumulate Q16.16 products and convert back to f32.
pub fn acc_to_f32(acc: i64) -> f32 {
    acc as f32 / (SCALE * SCALE)
}

/// Quantize an f32 slice.
pub fn quantize(xs: &[f32]) -> Vec<Fixed> {
    xs.iter().map(|&x| Fixed::from_f32(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Cases;

    #[test]
    fn roundtrip_error_bounded() {
        Cases::new(200).run(|g| {
            let x = g.f64_in(-100.0, 100.0) as f32;
            let q = Fixed::from_f32(x);
            assert!((q.to_f32() - x).abs() <= 0.5 / SCALE + 1e-6, "{x}");
        });
    }

    #[test]
    fn product_matches_float() {
        Cases::new(200).run(|g| {
            let a = g.f64_in(-10.0, 10.0) as f32;
            let b = g.f64_in(-10.0, 10.0) as f32;
            let qa = Fixed::from_f32(a);
            let qb = Fixed::from_f32(b);
            let got = acc_to_f32(qa.product_i64(qb));
            assert!((got - a * b).abs() < 0.1, "{a}*{b} = {got}");
        });
    }

    #[test]
    fn sign_handling() {
        let a = Fixed::from_f32(-2.0);
        let b = Fixed::from_f32(3.0);
        assert_eq!(acc_to_f32(a.product_i64(b)), -6.0);
        assert_eq!(acc_to_f32(a.product_i64(a)), 4.0);
        assert_eq!(Fixed::zero().to_f32(), 0.0);
    }
}
