//! Weight degradation over time (paper §VI-B2, Fig. 5).
//!
//! Every batch, the accelerator accesses all W weights; each accessed bit
//! drifts with probability `p_input`. Unprotected, a 32-bit weight
//! corrupts in one batch with `1-(1-p_input)^32`; over T batches the
//! expected number of corrupted weights is
//! `W * (1-(1-p_w)^T)`.
//!
//! With the diagonal ECC, every access is verified and single errors per
//! m x m block are corrected, so a weight survives unless >= 2 errors
//! land in the same block within one batch (before the next scrub):
//! `p_block = P[Bin(m^2, p_input) >= 2]`, and a failing block corrupts
//! ~1.87 weights in expectation (two errors hit two distinct 32-bit
//! weights w.p. (m^2-32)/(m^2-1)).

use crate::util::stats::{one_minus_pow, prob_at_least_two};

/// Model parameters for the Fig. 5 sweep.
#[derive(Clone, Copy, Debug)]
pub struct DegradationModel {
    /// Total weights (paper: 62e6).
    pub weights: f64,
    /// Bits per weight (32).
    pub bits: f64,
    /// ECC block side m (16).
    pub m: f64,
}

impl DegradationModel {
    pub fn paper() -> Self {
        Self { weights: 62e6, bits: 32.0, m: 16.0 }
    }

    /// Probability one weight corrupts during one batch, unprotected.
    pub fn p_weight_batch(&self, p_input: f64) -> f64 {
        one_minus_pow(p_input, self.bits)
    }

    /// Expected corrupted weights after T batches, no ECC (baseline).
    pub fn expected_corrupted_baseline(&self, p_input: f64, t: f64) -> f64 {
        self.weights * one_minus_pow(self.p_weight_batch(p_input), t)
    }

    /// Expected corrupted weights after T batches with diagonal ECC.
    pub fn expected_corrupted_ecc(&self, p_input: f64, t: f64) -> f64 {
        let block_bits = self.m * self.m;
        let blocks = self.weights * self.bits / block_bits;
        let p_block = prob_at_least_two(block_bits, p_input);
        // expected weights hit by a (>=2)-error block ~ 1 + (m^2-32)/(m^2-1)
        let w_per_block = 1.0 + (block_bits - self.bits) / (block_bits - 1.0);
        blocks * one_minus_pow(p_block, t) * w_per_block
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_anchor_points() {
        let m = DegradationModel::paper();
        // p_input = 1e-8: "nearly all weights corrupted after 1e7 batches".
        let base = m.expected_corrupted_baseline(1e-8, 1e7);
        assert!(base / m.weights > 0.9, "baseline@1e-8: {base}");
        // ECC @ p_input = 1e-9, T = 1e7: ~ a single corrupted weight.
        let ecc = m.expected_corrupted_ecc(1e-9, 1e7);
        assert!((0.1..30.0).contains(&ecc), "ecc@1e-9: {ecc}");
        // And the baseline at the same point is ~7 orders worse.
        let base9 = m.expected_corrupted_baseline(1e-9, 1e7);
        assert!(base9 / ecc > 1e5, "gap {base9} vs {ecc}");
    }

    #[test]
    fn monotone_in_t_and_p() {
        let m = DegradationModel::paper();
        assert!(
            m.expected_corrupted_baseline(1e-9, 1e6)
                < m.expected_corrupted_baseline(1e-9, 1e7)
        );
        assert!(
            m.expected_corrupted_ecc(1e-10, 1e7) < m.expected_corrupted_ecc(1e-9, 1e7)
        );
    }

    #[test]
    fn ecc_never_worse() {
        let m = DegradationModel::paper();
        for &p in &[1e-11, 1e-10, 1e-9, 1e-8] {
            for &t in &[1e3, 1e5, 1e7, 1e8] {
                assert!(
                    m.expected_corrupted_ecc(p, t) <= m.expected_corrupted_baseline(p, t) + 1e-9,
                    "p={p} t={t}"
                );
            }
        }
    }
}
