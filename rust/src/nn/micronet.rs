//! MicroNet: the executable end-to-end case study.
//!
//! Weights are trained once at build time (python/compile/train.py) and
//! loaded from `artifacts/weights.bin`; inference can run three ways:
//!
//! 1. [`MicroNet::forward_f32`] — float reference;
//! 2. [`MicroNet::forward_mmpu`] — every multiplication executed on the
//!    crossbar simulator as a Q8.8 x Q8.8 -> Q16.16 MultPIM-style
//!    in-memory multiplication under the configured reliability policy
//!    (row-parallel batches of multiplications — the FloatPIM execution
//!    style), with soft errors injected in the gate stream;
//! 3. through the PJRT `micronet_fwd` artifact with value-level fault
//!    masks (`runtime::Runtime::run_micronet`) for fast campaigns.

use anyhow::{ensure, Context, Result};

use crate::mmpu::{FunctionKind, FunctionSpec, Mmpu};
use crate::runtime::artifacts::{read_f32_blob, Manifest};

use super::quant::{acc_to_f32, Fixed};

/// Loaded MicroNet parameters.
#[derive(Clone, Debug)]
pub struct MicroNet {
    pub indim: usize,
    pub hidden: usize,
    pub classes: usize,
    /// (indim x hidden) row-major.
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// (hidden x classes) row-major.
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// Held-out evaluation set exported at build time.
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub n: usize,
    pub indim: usize,
    /// (n x indim) row-major pixels.
    pub x: Vec<f32>,
    pub labels: Vec<usize>,
}

impl MicroNet {
    /// Load from the artifacts manifest.
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let rec = manifest.record("weights")?;
        let (indim, hidden, classes) =
            (rec.get_usize("indim")?, rec.get_usize("h")?, rec.get_usize("classes")?);
        let blob = read_f32_blob(&manifest.file_path(rec)?)?;
        let expect = indim * hidden + hidden + hidden * classes + classes;
        ensure!(blob.len() == expect, "weights.bin length {} != {expect}", blob.len());
        let mut off = 0;
        let mut take = |n: usize| {
            let v = blob[off..off + n].to_vec();
            off += n;
            v
        };
        Ok(Self {
            indim,
            hidden,
            classes,
            w1: take(indim * hidden),
            b1: take(hidden),
            w2: take(hidden * classes),
            b2: take(classes),
        })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&Manifest::load_default()?)
    }

    /// Float reference forward pass -> logits (batch x classes).
    pub fn forward_f32(&self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(x.len(), batch * self.indim);
        let mut h = vec![0f32; batch * self.hidden];
        for s in 0..batch {
            for j in 0..self.hidden {
                let mut acc = self.b1[j];
                for i in 0..self.indim {
                    acc += x[s * self.indim + i] * self.w1[i * self.hidden + j];
                }
                h[s * self.hidden + j] = acc.max(0.0);
            }
        }
        let mut out = vec![0f32; batch * self.classes];
        for s in 0..batch {
            for j in 0..self.classes {
                let mut acc = self.b2[j];
                for i in 0..self.hidden {
                    acc += h[s * self.hidden + i] * self.w2[i * self.classes + j];
                }
                out[s * self.classes + j] = acc;
            }
        }
        out
    }

    /// Forward pass with EVERY multiplication executed in-memory on the
    /// mMPU (Q8.8 fixed point). Within a layer all products are
    /// independent, so they are batched row-parallel across the crossbar
    /// — the FloatPIM high-throughput execution style. The mMPU's
    /// reliability policy / error model applies to each multiplication.
    pub fn forward_mmpu(&self, mmpu: &mut Mmpu, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        assert_eq!(x.len(), batch * self.indim);
        let func = FunctionSpec::build(FunctionKind::Mul(16));

        let xq: Vec<Fixed> = x.iter().map(|&v| Fixed::from_f32(v)).collect();
        let w1q: Vec<Fixed> = self.w1.iter().map(|&v| Fixed::from_f32(v)).collect();
        let w2q: Vec<Fixed> = self.w2.iter().map(|&v| Fixed::from_f32(v)).collect();

        // Layer 1: products x[s,i] * w1[i,j], all independent.
        let pairs1: Vec<(Fixed, Fixed)> = (0..batch)
            .flat_map(|s| {
                let xq = &xq;
                let w1q = &w1q;
                (0..self.hidden).flat_map(move |j| {
                    (0..self.indim)
                        .map(move |i| (xq[s * self.indim + i], w1q[i * self.hidden + j]))
                })
            })
            .collect();
        let prods1 = batched_products(mmpu, &func, &pairs1)?;
        let mut h = vec![0f32; batch * self.hidden];
        let mut it = prods1.iter();
        for s in 0..batch {
            for j in 0..self.hidden {
                let mut acc: i64 = (self.b1[j] * 65536.0) as i64;
                for _ in 0..self.indim {
                    acc += *it.next().unwrap();
                }
                h[s * self.hidden + j] = acc_to_f32(acc).max(0.0);
            }
        }
        let hq: Vec<Fixed> = h.iter().map(|&v| Fixed::from_f32(v)).collect();

        // Layer 2.
        let pairs2: Vec<(Fixed, Fixed)> = (0..batch)
            .flat_map(|s| {
                let hq = &hq;
                let w2q = &w2q;
                (0..self.classes).flat_map(move |j| {
                    (0..self.hidden)
                        .map(move |i| (hq[s * self.hidden + i], w2q[i * self.classes + j]))
                })
            })
            .collect();
        let prods2 = batched_products(mmpu, &func, &pairs2)?;
        let mut out = vec![0f32; batch * self.classes];
        let mut it = prods2.iter();
        for s in 0..batch {
            for j in 0..self.classes {
                let mut acc: i64 = (self.b2[j] * 65536.0) as i64;
                for _ in 0..self.hidden {
                    acc += *it.next().unwrap();
                }
                out[s * self.classes + j] = acc_to_f32(acc);
            }
        }
        Ok(out)
    }

    pub fn argmax(&self, logits: &[f32], batch: usize) -> Vec<usize> {
        (0..batch)
            .map(|s| {
                let row = &logits[s * self.classes..(s + 1) * self.classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    pub fn accuracy(&self, logits: &[f32], labels: &[usize]) -> f64 {
        let preds = self.argmax(logits, labels.len());
        let hits = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        hits as f64 / labels.len() as f64
    }
}

impl EvalSet {
    pub fn load(manifest: &Manifest) -> Result<Self> {
        let rec = manifest.record("evalset")?;
        let (n, indim) = (rec.get_usize("n")?, rec.get_usize("indim")?);
        let blob = read_f32_blob(&manifest.file_path(rec)?)?;
        ensure!(blob.len() == n * indim + n, "evalset.bin length mismatch");
        let x = blob[..n * indim].to_vec();
        let labels = blob[n * indim..].iter().map(|&v| v as usize).collect();
        Ok(Self { n, indim, x, labels })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&Manifest::load_default()?)
    }

    /// First `k` samples (for faster campaigns).
    pub fn take(&self, k: usize) -> EvalSet {
        let k = k.min(self.n);
        EvalSet {
            n: k,
            indim: self.indim,
            x: self.x[..k * self.indim].to_vec(),
            labels: self.labels[..k].to_vec(),
        }
    }
}

/// Run a list of fixed-point products through the mMPU in row-parallel
/// chunks (one crossbar execution per `rows` products). The crossbar
/// multiplies Q8.8 magnitudes to Q16.16; signs are resolved here
/// (sign-magnitude, FloatPIM style).
pub fn batched_products(
    mmpu: &mut Mmpu,
    func: &FunctionSpec,
    pairs: &[(Fixed, Fixed)],
) -> Result<Vec<i64>> {
    let capacity = match mmpu.config().policy.tmr {
        crate::tmr::TmrMode::SemiParallel => (mmpu.rows() - 1) / 3,
        _ => mmpu.rows(),
    };
    let mut out = Vec::with_capacity(pairs.len());
    for chunk in pairs.chunks(capacity) {
        let a: Vec<u64> = chunk.iter().map(|(x, _)| x.mag as u64).collect();
        let b: Vec<u64> = chunk.iter().map(|(_, y)| y.mag as u64).collect();
        let r = mmpu.exec_vector(0, func, &a, &b).context("mmpu multiplication batch")?;
        for (i, &v) in r.values.iter().enumerate() {
            let neg = chunk[i].0.neg != chunk[i].1.neg;
            out.push(if neg { -(v as i64) } else { v as i64 });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_accuracy() {
        let net = MicroNet {
            indim: 2,
            hidden: 2,
            classes: 3,
            w1: vec![0.0; 4],
            b1: vec![0.0; 2],
            w2: vec![0.0; 6],
            b2: vec![0.0; 3],
        };
        let logits = vec![0.1, 0.9, 0.0, /* s1 */ 2.0, -1.0, 0.5];
        assert_eq!(net.argmax(&logits, 2), vec![1, 0]);
        assert_eq!(net.accuracy(&logits, &[1, 2]), 0.5);
    }

    #[test]
    fn forward_f32_linear_sanity() {
        // Identity-ish network: one input passes through.
        let net = MicroNet {
            indim: 1,
            hidden: 1,
            classes: 1,
            w1: vec![2.0],
            b1: vec![0.0],
            w2: vec![3.0],
            b2: vec![1.0],
        };
        let y = net.forward_f32(&[4.0], 1);
        assert_eq!(y, vec![25.0]); // relu(4*2)*3+1
    }
}
