//! Barrel shifter — the peripheral that emulates diagonal wires between
//! the main crossbar and the check-bit extension (paper Fig. 2c).
//!
//! A log-stage barrel shifter rotates an m-bit lane bundle by any amount
//! in one cycle; the shift pattern over consecutive rows (rotate row i by
//! i) aligns each wrap-around diagonal into a single column of the
//! extension. Communication through the shifter remains stateful
//! (memristor-to-memristor), like partition transfers.

use crate::util::bitmat::BitVec;

/// Cycle/usage accounting for the shifter periphery.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BarrelStats {
    pub rotations: u64,
    pub cycles: u64,
}

/// An m-lane barrel shifter.
#[derive(Clone, Debug)]
pub struct BarrelShifter {
    m: usize,
    pub stats: BarrelStats,
}

impl BarrelShifter {
    pub fn new(m: usize) -> Self {
        assert!(m > 0);
        Self { m, stats: BarrelStats::default() }
    }

    pub fn lanes(&self) -> usize {
        self.m
    }

    /// Rotate an m-bit vector left by `k` (one cycle, any k).
    pub fn rotate_left(&mut self, v: &BitVec, k: usize) -> BitVec {
        assert_eq!(v.len(), self.m);
        self.stats.rotations += 1;
        self.stats.cycles += 1;
        let m = self.m;
        BitVec::from_fn(m, |i| v.get((i + k) % m))
    }

    pub fn rotate_right(&mut self, v: &BitVec, k: usize) -> BitVec {
        let m = self.m;
        self.rotate_left(v, m - (k % m))
    }

    /// The Fig. 2(c) alignment: given the m rows of a block (each an
    /// m-bit vector), rotate row i left by i so that leading diagonal d
    /// lands in column d of every rotated row. One cycle per row bundle
    /// (rows stream through the shifter).
    pub fn align_leading(&mut self, rows: &[BitVec]) -> Vec<BitVec> {
        rows.iter().enumerate().map(|(i, r)| self.rotate_left(r, i)).collect()
    }

    /// Counter-diagonal alignment: rotate row i *right* by i, so counter
    /// diagonal d lands in column d.
    pub fn align_counter(&mut self, rows: &[BitVec]) -> Vec<BitVec> {
        rows.iter().enumerate().map(|(i, r)| self.rotate_right(r, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bv(bits: &[u8]) -> BitVec {
        BitVec::from_fn(bits.len(), |i| bits[i] == 1)
    }

    #[test]
    fn rotate_left_basic() {
        let mut s = BarrelShifter::new(4);
        let v = bv(&[1, 0, 0, 0]);
        assert_eq!(s.rotate_left(&v, 1), bv(&[0, 0, 0, 1]));
        assert_eq!(s.rotate_left(&v, 0), v);
        assert_eq!(s.rotate_left(&v, 4), v);
        assert_eq!(s.stats.rotations, 3);
    }

    #[test]
    fn rotate_right_inverts_left() {
        let mut s = BarrelShifter::new(8);
        let v = bv(&[1, 1, 0, 1, 0, 0, 1, 0]);
        for k in 0..8 {
            let l = s.rotate_left(&v, k);
            assert_eq!(s.rotate_right(&l, k), v, "k={k}");
        }
    }

    #[test]
    fn leading_alignment_collects_diagonals() {
        // block[i][j]; leading diagonal d = (j - i) mod m. After
        // align_leading, rotated[i][d] == block[i][(i + d) % m].
        let m = 4;
        let block: Vec<BitVec> =
            (0..m).map(|i| BitVec::from_fn(m, |j| (i * m + j) % 3 == 0)).collect();
        let mut s = BarrelShifter::new(m);
        let aligned = s.align_leading(&block);
        for i in 0..m {
            for d in 0..m {
                assert_eq!(aligned[i].get(d), block[i].get((i + d) % m), "i={i} d={d}");
            }
        }
    }

    #[test]
    fn counter_alignment_collects_diagonals() {
        // counter diagonal d = (i + j) mod m: rotated[i][d] == block[i][(d - i) mod m].
        let m = 8;
        let block: Vec<BitVec> =
            (0..m).map(|i| BitVec::from_fn(m, |j| (i * 7 + j * 3) % 5 == 0)).collect();
        let mut s = BarrelShifter::new(m);
        let aligned = s.align_counter(&block);
        for i in 0..m {
            for d in 0..m {
                assert_eq!(aligned[i].get(d), block[i].get((d + m - i % m) % m), "i={i} d={d}");
            }
        }
    }
}
