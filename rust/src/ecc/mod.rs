//! High-throughput memristive ECC (paper §IV) — the diagonal-parity code
//! of Fig. 2(b,c), plus the naive horizontal baseline of Fig. 2(a).
//!
//! Check bits are stored in a dedicated memristive extension that works
//! in parallel to the main array; diagonal alignment between the two uses
//! a barrel shifter (`barrel`). Updates exploit XOR linearity
//! (`parity' = parity ^ old ^ new`) with the same row/column parallelism
//! as the user's operation, making the added latency O(1) cycles for
//! **both** in-row and in-column operations — the property the horizontal
//! baseline lacks (O(n) for in-column, Fig. 2a).

pub mod barrel;
pub mod diagonal;
pub mod horizontal;

pub use barrel::BarrelShifter;
pub use diagonal::{CorrectionOutcome, DiagonalEcc, EccStats};
pub use horizontal::HorizontalEcc;
