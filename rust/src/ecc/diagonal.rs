//! The mMPU-compatible diagonal ECC (paper §IV, Fig. 2b,c).
//!
//! Per `m x m` block, the check-bit extension holds one parity bit per
//! wrap-around **leading** diagonal (`d = (j - i) mod m`), one per
//! **counter** diagonal (`d = (i + j) mod m`) and one per **row**.
//!
//! A single flipped data bit fails exactly one diagonal of each family,
//! giving `2i = dc - dl (mod m)`. For even m (the paper's m = 16) that
//! intersection leaves a two-candidate ambiguity `{i, i + m/2}`; the row
//! parities disambiguate (a third dimension of the multidimensional
//! parity [42] — see DESIGN.md §5 for the note on this divergence).
//!
//! Cost model (latency the extension adds to the main array):
//! * verify of any set of touched blocks: `2m + 2` cycles — rows stream
//!   through the barrel shifter once per diagonal family, in parallel
//!   across blocks and block-rows;
//! * update after an operation that wrote `k` lines: `k + 3` cycles —
//!   the deltas are computed with the same row/column parallelism as the
//!   user op, shifted, and XOR-folded into the parity columns. O(1) per
//!   line for in-row AND in-column ops — the Fig. 2(b) property.

use crate::util::bitmat::{BitMatrix, BitVec};

use super::barrel::BarrelShifter;

/// Accounting for the ECC extension.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EccStats {
    /// Cycles spent verifying (extension-side).
    pub verify_cycles: u64,
    /// Cycles spent updating check bits.
    pub update_cycles: u64,
    /// Verification passes run.
    pub verifications: u64,
    /// Data bits corrected.
    pub corrected: u64,
    /// Check bits repaired (parity itself was corrupted).
    pub parity_fixes: u64,
    /// Blocks flagged uncorrectable (>= 2 errors).
    pub uncorrectable: u64,
}

/// Result of a correction pass.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CorrectionOutcome {
    pub corrected_bits: Vec<(usize, usize)>,
    pub parity_fixes: usize,
    pub uncorrectable_blocks: Vec<(usize, usize)>,
}

impl CorrectionOutcome {
    pub fn is_clean(&self) -> bool {
        self.corrected_bits.is_empty()
            && self.parity_fixes == 0
            && self.uncorrectable_blocks.is_empty()
    }
}

/// Diagonal-parity ECC engine for one (rows x cols) crossbar region.
#[derive(Clone, Debug)]
pub struct DiagonalEcc {
    rows: usize,
    cols: usize,
    m: usize,
    blocks_r: usize,
    blocks_c: usize,
    /// (blocks_r, blocks_c * m): leading-diagonal parities.
    lead: BitMatrix,
    /// (blocks_r, blocks_c * m): counter-diagonal parities.
    counter: BitMatrix,
    /// (blocks_r, blocks_c * m): row parities.
    rowp: BitMatrix,
    shifter: BarrelShifter,
    pub stats: EccStats,
}

impl DiagonalEcc {
    pub fn new(rows: usize, cols: usize, m: usize) -> Self {
        assert!(m >= 2 && rows % m == 0 && cols % m == 0, "m must divide rows and cols");
        let blocks_r = rows / m;
        let blocks_c = cols / m;
        Self {
            rows,
            cols,
            m,
            blocks_r,
            blocks_c,
            lead: BitMatrix::zeros(blocks_r, blocks_c * m),
            counter: BitMatrix::zeros(blocks_r, blocks_c * m),
            rowp: BitMatrix::zeros(blocks_r, blocks_c * m),
            shifter: BarrelShifter::new(m),
            stats: EccStats::default(),
        }
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Check-bit storage overhead: 3m per m^2 data bits.
    pub fn overhead_ratio(&self) -> f64 {
        3.0 / self.m as f64
    }

    /// Latency model: verifying any set of touched blocks (parallel
    /// across blocks) — 2m + 2 cycles.
    pub fn verify_cost(&self) -> u64 {
        2 * self.m as u64 + 2
    }

    /// Latency model: updating parities after writing `lines` lines.
    pub fn update_cost(&self, lines: u64) -> u64 {
        lines + 3
    }

    /// Recompute every check bit from `state` (initial encode).
    pub fn encode(&mut self, state: &BitMatrix) {
        assert_eq!((state.rows(), state.cols()), (self.rows, self.cols));
        for bi in 0..self.blocks_r {
            for bj in 0..self.blocks_c {
                let (lead, counter, rowp) = self.block_parities(state, bi, bj);
                for d in 0..self.m {
                    self.lead.set(bi, bj * self.m + d, lead.get(d));
                    self.counter.set(bi, bj * self.m + d, counter.get(d));
                    self.rowp.set(bi, bj * self.m + d, rowp.get(d));
                }
            }
        }
        // Extension-side encode: stream m rows through the shifter for
        // each family (parallel across blocks).
        self.stats.update_cycles += 3 * self.m as u64;
    }

    /// True parities of block (bi, bj) computed from the data (uses the
    /// barrel-shifter alignment of Fig. 2c for the diagonal families).
    fn block_parities(&mut self, state: &BitMatrix, bi: usize, bj: usize) -> (BitVec, BitVec, BitVec) {
        let m = self.m;
        let rows: Vec<BitVec> = (0..m)
            .map(|i| BitVec::from_fn(m, |j| state.get(bi * m + i, bj * m + j)))
            .collect();
        let lead_aligned = self.shifter.align_leading(&rows);
        let cnt_aligned = self.shifter.align_counter(&rows);
        let fold = |aligned: &[BitVec]| {
            BitVec::from_fn(m, |d| {
                aligned.iter().fold(false, |acc, r| acc ^ r.get(d))
            })
        };
        let rowp = BitVec::from_fn(m, |i| rows[i].parity());
        (fold(&lead_aligned), fold(&cnt_aligned), rowp)
    }

    /// Verify the blocks intersecting the given column range; returns
    /// per-block syndromes for failing blocks.
    pub fn verify_cols(
        &mut self,
        state: &BitMatrix,
        col_lo: usize,
        col_hi: usize,
    ) -> Vec<(usize, usize, Syndrome)> {
        let bj_lo = col_lo / self.m;
        let bj_hi = (col_hi.min(self.cols - 1)) / self.m;
        self.stats.verifications += 1;
        self.stats.verify_cycles += self.verify_cost();
        let mut fails = vec![];
        for bi in 0..self.blocks_r {
            for bj in bj_lo..=bj_hi {
                if let Some(s) = self.syndrome(state, bi, bj) {
                    fails.push((bi, bj, s));
                }
            }
        }
        fails
    }

    /// Verify everything.
    pub fn verify_all(&mut self, state: &BitMatrix) -> Vec<(usize, usize, Syndrome)> {
        self.verify_cols(state, 0, self.cols - 1)
    }

    fn syndrome(&mut self, state: &BitMatrix, bi: usize, bj: usize) -> Option<Syndrome> {
        let m = self.m;
        let (lead, counter, rowp) = self.block_parities(state, bi, bj);
        let mut s = Syndrome::default();
        for d in 0..m {
            if lead.get(d) != self.lead.get(bi, bj * m + d) {
                s.lead.push(d);
            }
            if counter.get(d) != self.counter.get(bi, bj * m + d) {
                s.counter.push(d);
            }
            if rowp.get(d) != self.rowp.get(bi, bj * m + d) {
                s.row.push(d);
            }
        }
        if s.lead.is_empty() && s.counter.is_empty() && s.row.is_empty() {
            None
        } else {
            Some(s)
        }
    }

    /// Correct single-bit errors in all failing blocks (flips data bits
    /// in `state` / repairs check bits). Multi-error blocks are flagged.
    pub fn correct(&mut self, state: &mut BitMatrix) -> CorrectionOutcome {
        let mut out = CorrectionOutcome::default();
        let fails = self.verify_all(state);
        for (bi, bj, s) in fails {
            let m = self.m;
            match (s.lead.len(), s.counter.len(), s.row.len()) {
                (1, 1, 1) => {
                    let (dl, dc, i) = (s.lead[0], s.counter[0], s.row[0]);
                    // consistency: dl = (j-i) mod m, dc = (i+j) mod m
                    let j = (i + dl) % m;
                    if (i + j) % m == dc {
                        let (r, c) = (bi * m + i, bj * m + j);
                        state.flip(r, c);
                        self.stats.corrected += 1;
                        out.corrected_bits.push((r, c));
                    } else {
                        self.stats.uncorrectable += 1;
                        out.uncorrectable_blocks.push((bi, bj));
                    }
                }
                // Exactly one failing check bit across all families and
                // consistent data parities otherwise => the check bit
                // itself drifted; recompute it.
                (1, 0, 0) | (0, 1, 0) | (0, 0, 1) => {
                    let (lead, counter, rowp) = self.block_parities(state, bi, bj);
                    for d in 0..m {
                        self.lead.set(bi, bj * m + d, lead.get(d));
                        self.counter.set(bi, bj * m + d, counter.get(d));
                        self.rowp.set(bi, bj * m + d, rowp.get(d));
                    }
                    self.stats.parity_fixes += 1;
                    out.parity_fixes += 1;
                }
                _ => {
                    self.stats.uncorrectable += 1;
                    out.uncorrectable_blocks.push((bi, bj));
                }
            }
        }
        // Correction piggybacks on a verification pass; charge the fix-up
        // writes (constant per failing block, done in the extension).
        self.stats.update_cycles +=
            (out.corrected_bits.len() + out.parity_fixes) as u64 * 2;
        out
    }

    /// O(1) incremental update after an in-row op wrote column `c`:
    /// `parity' = parity ^ old ^ new` for every crossed diagonal/row.
    pub fn note_col_write(&mut self, c: usize, old: &BitVec, new: &BitVec) {
        assert_eq!(old.len(), self.rows);
        assert_eq!(new.len(), self.rows);
        let m = self.m;
        let bj = c / m;
        let j = c % m;
        for r in 0..self.rows {
            if old.get(r) != new.get(r) {
                let bi = r / m;
                let i = r % m;
                self.lead.flip(bi, bj * m + (j + m - i % m) % m);
                self.counter.flip(bi, bj * m + (i + j) % m);
                self.rowp.flip(bi, bj * m + i);
            }
        }
        self.stats.update_cycles += self.update_cost(1);
    }

    /// O(1) incremental update after an in-column op wrote row `r`.
    pub fn note_row_write(&mut self, r: usize, old: &BitVec, new: &BitVec) {
        assert_eq!(old.len(), self.cols);
        assert_eq!(new.len(), self.cols);
        let m = self.m;
        let bi = r / m;
        let i = r % m;
        for c in 0..self.cols {
            if old.get(c) != new.get(c) {
                let bj = c / m;
                let j = c % m;
                self.lead.flip(bi, bj * m + (j + m - i % m) % m);
                self.counter.flip(bi, bj * m + (i + j) % m);
                self.rowp.flip(bi, bj * m + i);
            }
        }
        self.stats.update_cycles += self.update_cost(1);
    }

    pub fn barrel_stats(&self) -> super::barrel::BarrelStats {
        self.shifter.stats
    }
}

/// Which check bits disagree with the data, per family.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Syndrome {
    pub lead: Vec<usize>,
    pub counter: Vec<usize>,
    pub row: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Cases;
    use crate::util::rng::Pcg64;

    fn random_state(rows: usize, cols: usize, seed: u64) -> BitMatrix {
        let mut r = Pcg64::new(seed, 0);
        BitMatrix::from_fn(rows, cols, |_, _| r.bernoulli(0.5))
    }

    #[test]
    fn clean_state_verifies() {
        let state = random_state(32, 32, 1);
        let mut ecc = DiagonalEcc::new(32, 32, 8);
        ecc.encode(&state);
        assert!(ecc.verify_all(&state).is_empty());
    }

    #[test]
    fn single_flip_detected_and_corrected_anywhere() {
        Cases::new(64).run(|g| {
            let mut state = random_state(32, 32, g.u64());
            let mut ecc = DiagonalEcc::new(32, 32, 8);
            ecc.encode(&state);
            let r = g.usize_in(0..=31);
            let c = g.usize_in(0..=31);
            state.flip(r, c);
            let orig = state.get(r, c);
            let out = ecc.correct(&mut state);
            assert_eq!(out.corrected_bits, vec![(r, c)]);
            assert_eq!(state.get(r, c), !orig, "bit restored");
            assert!(ecc.verify_all(&state).is_empty(), "clean after correction");
        });
    }

    #[test]
    fn ambiguous_pair_resolved_by_row_parity() {
        // The even-m ambiguity: cells (i, j) and (i + m/2, j + m/2) share
        // both diagonals. Row parity must disambiguate.
        let m = 8;
        let mut state = random_state(16, 16, 7);
        let mut ecc = DiagonalEcc::new(16, 16, m);
        ecc.encode(&state);
        state.flip(2, 3);
        let out = ecc.correct(&mut state);
        assert_eq!(out.corrected_bits, vec![(2, 3)], "not (6, 7)");
    }

    #[test]
    fn corrupted_check_bit_is_repaired_not_data() {
        let state = random_state(16, 16, 3);
        let mut ecc = DiagonalEcc::new(16, 16, 8);
        ecc.encode(&state);
        ecc.lead.flip(0, 3); // parity drifted, data fine
        let mut s = state.clone();
        let out = ecc.correct(&mut s);
        assert_eq!(out.parity_fixes, 1);
        assert!(out.corrected_bits.is_empty());
        assert_eq!(s, state, "data untouched");
        assert!(ecc.verify_all(&s).is_empty());
    }

    #[test]
    fn double_error_in_block_flagged_uncorrectable() {
        let mut state = random_state(16, 16, 5);
        let mut ecc = DiagonalEcc::new(16, 16, 8);
        ecc.encode(&state);
        state.flip(1, 1);
        state.flip(2, 5); // same block (m=8)
        let out = ecc.correct(&mut state);
        assert!(!out.uncorrectable_blocks.is_empty());
    }

    #[test]
    fn two_errors_in_different_blocks_both_corrected() {
        let mut state = random_state(32, 32, 9);
        let mut ecc = DiagonalEcc::new(32, 32, 8);
        ecc.encode(&state);
        state.flip(1, 1); // block (0,0)
        state.flip(20, 28); // block (2,3)
        let out = ecc.correct(&mut state);
        assert_eq!(out.corrected_bits.len(), 2);
        assert!(ecc.verify_all(&state).is_empty());
    }

    #[test]
    fn incremental_col_update_matches_reencode() {
        Cases::new(32).run(|g| {
            let mut state = random_state(32, 32, g.u64());
            let mut ecc = DiagonalEcc::new(32, 32, 8);
            ecc.encode(&state);
            // Simulate an in-row op rewriting one column.
            let c = g.usize_in(0..=31);
            let old = state.col_bitvec(c);
            for r in 0..32 {
                state.set(r, c, g.bool());
            }
            let new = state.col_bitvec(c);
            ecc.note_col_write(c, &old, &new);
            assert!(ecc.verify_all(&state).is_empty(), "incremental == reencode");
        });
    }

    #[test]
    fn incremental_row_update_matches_reencode() {
        Cases::new(32).run(|g| {
            let mut state = random_state(32, 32, g.u64());
            let mut ecc = DiagonalEcc::new(32, 32, 8);
            ecc.encode(&state);
            let r = g.usize_in(0..=31);
            let old = state.row_bitvec(r);
            for c in 0..32 {
                state.set(r, c, g.bool());
            }
            let new = state.row_bitvec(r);
            ecc.note_row_write(r, &old, &new);
            assert!(ecc.verify_all(&state).is_empty());
        });
    }

    #[test]
    fn cost_model_o1_for_both_orientations() {
        // The Fig. 2(b) claim: both in-row and in-column updates cost
        // O(1) (independent of n).
        for n in [16usize, 64, 256] {
            let ecc = DiagonalEcc::new(n, n, 16);
            assert_eq!(ecc.update_cost(1), 4);
            assert_eq!(ecc.verify_cost(), 34);
        }
    }

    #[test]
    fn overhead_ratio() {
        let ecc = DiagonalEcc::new(64, 64, 16);
        assert!((ecc.overhead_ratio() - 3.0 / 16.0).abs() < 1e-12);
    }
}
