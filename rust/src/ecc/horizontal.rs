//! The naive horizontal ECC baseline (paper Fig. 2a).
//!
//! One parity bit per `g`-bit horizontal group (the classic "eighth bit
//! of every byte"). After an in-row operation (one column rewritten
//! across all rows) the parity updates in O(1) cycles using row
//! parallelism; after an in-**column** operation (one row rewritten
//! across all columns) every parity bit of that row changes and, lacking
//! column-parallel access to the horizontally-arranged check bits, the
//! update costs O(n) cycles — the incompatibility that motivates the
//! diagonal code.

use crate::util::bitmat::{BitMatrix, BitVec};

/// Accounting mirror of `EccStats` for the baseline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HorizontalStats {
    pub verify_cycles: u64,
    pub update_cycles: u64,
    pub verifications: u64,
    pub detected_groups: u64,
}

/// Horizontal parity code over a (rows x cols) region.
#[derive(Clone, Debug)]
pub struct HorizontalEcc {
    rows: usize,
    cols: usize,
    g: usize,
    /// (rows, cols / g) parity bits.
    parity: BitMatrix,
    pub stats: HorizontalStats,
}

impl HorizontalEcc {
    pub fn new(rows: usize, cols: usize, g: usize) -> Self {
        assert!(g >= 2 && cols % g == 0, "group size must divide cols");
        Self { rows, cols, g, parity: BitMatrix::zeros(rows, cols / g), stats: HorizontalStats::default() }
    }

    pub fn group_size(&self) -> usize {
        self.g
    }

    /// Storage overhead: 1 check bit per g data bits.
    pub fn overhead_ratio(&self) -> f64 {
        1.0 / self.g as f64
    }

    fn group_parity(&self, state: &BitMatrix, r: usize, grp: usize) -> bool {
        (0..self.g).fold(false, |acc, k| acc ^ state.get(r, grp * self.g + k))
    }

    pub fn encode(&mut self, state: &BitMatrix) {
        assert_eq!((state.rows(), state.cols()), (self.rows, self.cols));
        for r in 0..self.rows {
            for grp in 0..self.cols / self.g {
                let p = self.group_parity(state, r, grp);
                self.parity.set(r, grp, p);
            }
        }
        self.stats.update_cycles += self.g as u64;
    }

    /// Detect groups whose parity disagrees (no correction capability —
    /// a single horizontal parity can only localize to the group).
    pub fn verify_all(&mut self, state: &BitMatrix) -> Vec<(usize, usize)> {
        self.stats.verifications += 1;
        self.stats.verify_cycles += self.g as u64 + 2;
        let mut fails = vec![];
        for r in 0..self.rows {
            for grp in 0..self.cols / self.g {
                if self.group_parity(state, r, grp) != self.parity.get(r, grp) {
                    fails.push((r, grp));
                }
            }
        }
        self.stats.detected_groups += fails.len() as u64;
        fails
    }

    /// In-row op wrote column `c`: O(1) — parity bits of the containing
    /// group update with the same row parallelism (XOR linearity).
    pub fn note_col_write(&mut self, c: usize, old: &BitVec, new: &BitVec) {
        let grp = c / self.g;
        for r in 0..self.rows {
            if old.get(r) != new.get(r) {
                self.parity.flip(r, grp);
            }
        }
        self.stats.update_cycles += self.update_cost_in_row(1);
    }

    /// In-column op wrote row `r`: O(n) — every group parity of the row
    /// must be serially recomputed (Fig. 2a's failure mode).
    pub fn note_row_write(&mut self, r: usize, old: &BitVec, new: &BitVec) {
        for c in 0..self.cols {
            if old.get(c) != new.get(c) {
                self.parity.flip(r, c / self.g);
            }
        }
        self.stats.update_cycles += self.update_cost_in_col();
    }

    /// Cost model: in-row update is O(1) per written column.
    pub fn update_cost_in_row(&self, cols_written: u64) -> u64 {
        cols_written + 3
    }

    /// Cost model: in-column update is O(n) (n = number of columns).
    pub fn update_cost_in_col(&self) -> u64 {
        self.cols as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_state(rows: usize, cols: usize, seed: u64) -> BitMatrix {
        let mut r = Pcg64::new(seed, 0);
        BitMatrix::from_fn(rows, cols, |_, _| r.bernoulli(0.5))
    }

    #[test]
    fn clean_verifies() {
        let s = random_state(16, 32, 1);
        let mut e = HorizontalEcc::new(16, 32, 8);
        e.encode(&s);
        assert!(e.verify_all(&s).is_empty());
    }

    #[test]
    fn single_flip_detected_in_right_group() {
        let mut s = random_state(16, 32, 2);
        let mut e = HorizontalEcc::new(16, 32, 8);
        e.encode(&s);
        s.flip(5, 19);
        assert_eq!(e.verify_all(&s), vec![(5, 2)]);
    }

    #[test]
    fn double_flip_same_group_is_missed() {
        // The classic parity blind spot — motivates the multidimensional
        // diagonal code.
        let mut s = random_state(16, 32, 3);
        let mut e = HorizontalEcc::new(16, 32, 8);
        e.encode(&s);
        s.flip(5, 17);
        s.flip(5, 18);
        assert!(e.verify_all(&s).is_empty());
    }

    #[test]
    fn incremental_updates_match() {
        let mut s = random_state(16, 32, 4);
        let mut e = HorizontalEcc::new(16, 32, 8);
        e.encode(&s);
        let old = s.col_bitvec(7);
        for r in 0..16 {
            s.set(r, 7, r % 3 == 0);
        }
        e.note_col_write(7, &old, &s.col_bitvec(7));
        let old_row = s.row_bitvec(4);
        for c in 0..32 {
            s.set(4, c, c % 5 == 0);
        }
        e.note_row_write(4, &old_row, &s.row_bitvec(4));
        assert!(e.verify_all(&s).is_empty());
    }

    #[test]
    fn cost_asymmetry_is_the_fig2_point() {
        // In-row O(1) vs in-column O(n): the gap grows with n.
        for n in [64usize, 256, 1024] {
            let e = HorizontalEcc::new(n, n, 8);
            assert_eq!(e.update_cost_in_row(1), 4);
            assert_eq!(e.update_cost_in_col(), n as u64);
        }
    }
}
