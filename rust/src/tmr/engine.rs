//! The TMR execution engine: serial / parallel / semi-parallel strategies
//! around an arbitrary single-row function program (paper §V, Fig. 3).
//!
//! §Perf: each strategy can be **compiled once** into a [`CompiledTmr`]
//! — the retargeted/relocated copies, the zipped parallel cycles, the
//! per-item semi-parallel voting schedule and the per-bit vote program
//! are all synthesized and plan-compiled at build time, then executed
//! through `Crossbar::run_plan` with no per-execution program cloning or
//! concurrency re-validation. [`TmrEngine::execute`] remains the
//! uncompiled reference path (bit-identical by property test).

use anyhow::{bail, ensure, Result};

use crate::errs::Injector;
use crate::isa::microop::{Dir, LaneRange, MicroOp};
use crate::isa::plan::{CompiledPlan, ScheduleConfig};
use crate::isa::program::{Program, Step};
use crate::xbar::crossbar::Crossbar;
use crate::xbar::gate::Gate;
use crate::xbar::partition::Partitions;

use super::voting::per_bit_vote_program;

/// Reliability strategy for function execution.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TmrMode {
    /// Unreliable baseline (Fig. 3a).
    Off,
    /// 3x latency, ~1x area: inputs/intermediates shared (Fig. 3b).
    Serial,
    /// 1x latency, 3x area: partition-isolated copies (Fig. 3c).
    Parallel,
    /// 1x latency, 1x area, 1/3 throughput: copies across rows.
    SemiParallel,
}

/// Where the final (voted) outputs live, plus trade-off accounting.
#[derive(Clone, Debug)]
pub struct TmrRun {
    /// Columns of the final outputs (after voting, if any).
    pub output_cols: Vec<u32>,
    /// Crossbar cycles consumed by this execution (incl. voting).
    pub cycles: u64,
    /// Total columns occupied (area proxy).
    pub area_cols: u32,
    /// Logical items per crossbar execution (throughput proxy):
    /// rows for Off/Serial/Parallel, rows/3 for SemiParallel.
    pub items: usize,
}

/// Executes programs under a TMR strategy.
#[derive(Clone, Copy, Debug)]
pub struct TmrEngine {
    pub mode: TmrMode,
}

impl TmrEngine {
    pub fn new(mode: TmrMode) -> Self {
        Self { mode }
    }

    /// Execute `prog` on `x` through the **uncompiled** per-step path
    /// (kept as the bit-exact reference for `CompiledTmr`; hot paths
    /// should [`TmrEngine::compile`] once and reuse the plan). For
    /// `Parallel`, the caller must have replicated the input values into
    /// the relocated copies' input columns (`copy_input_cols`); for
    /// `SemiParallel`, into the row triples (item i at rows
    /// {i, i+k, i+2k}, k = (rows-1)/3).
    pub fn execute(
        &self,
        x: &mut Crossbar,
        prog: &Program,
        mut inj: Option<&mut Injector>,
    ) -> Result<TmrRun> {
        let c0 = x.stats.cycles;
        match self.mode {
            TmrMode::Off => {
                self.configure_partitions(x, std::slice::from_ref(prog))?;
                x.run_program_uncompiled(prog, inj)?;
                Ok(TmrRun {
                    output_cols: prog.output_cols.clone(),
                    cycles: x.stats.cycles - c0,
                    area_cols: prog.width,
                    items: x.rows(),
                })
            }
            TmrMode::Serial => self.execute_serial(x, prog, inj.as_deref_mut(), c0),
            TmrMode::Parallel => self.execute_parallel(x, prog, inj.as_deref_mut(), c0),
            TmrMode::SemiParallel => self.execute_semi(x, prog, inj.as_deref_mut(), c0),
        }
    }

    /// Compile this strategy for `prog` on a `rows x cols` crossbar: all
    /// copy synthesis, partition configuration, concurrency validation
    /// and operand resolution happen here, once. The returned
    /// [`CompiledTmr`] executes bit-identically to [`TmrEngine::execute`]
    /// (same state, stats, and injector stream) at a fraction of the
    /// per-execution cost.
    pub fn compile(&self, prog: &Program, rows: usize, cols: usize) -> Result<CompiledTmr> {
        self.compile_with(prog, rows, cols, ScheduleConfig::off())
    }

    /// [`TmrEngine::compile`] with §Perf list scheduling: every phase
    /// plan (copies, zipped cycles, votes) is recompiled through
    /// [`CompiledPlan::compile_scheduled`] against one column grid
    /// refined from the strategy's frozen partition configuration —
    /// refining once at the strategy level keeps all phases runnable
    /// back to back under a single reconfiguration. Falls back to the
    /// serial compilation whenever packing (net of the extra reconfig
    /// cycle the grid may cost) saves nothing, so
    /// `cycles(scheduled) <= cycles(serial)` holds at the strategy
    /// level, reconfiguration included.
    pub fn compile_with(
        &self,
        prog: &Program,
        rows: usize,
        cols: usize,
        sched: ScheduleConfig,
    ) -> Result<CompiledTmr> {
        let bp = self.blueprint(prog, rows, cols)?;
        let row_parts = Partitions::whole(rows as u32);
        let whole_cols = Partitions::whole(cols as u32);
        let base_parts = bp.parts.clone().unwrap_or_else(|| whole_cols.clone());
        let serial_plans = bp
            .progs
            .iter()
            .map(|p| CompiledPlan::compile(p, rows, cols, &base_parts, &row_parts))
            .collect::<Result<Vec<_>>>()?;
        let serial = CompiledTmr {
            mode: self.mode,
            rows,
            cols,
            parts: bp.parts.clone(),
            plans: serial_plans,
            sched: ScheduleConfig::off(),
            output_cols: bp.output_cols.clone(),
            area_cols: bp.area_cols,
            items: bp.items,
        };
        if !sched.enabled {
            return Ok(serial);
        }
        let refined = if sched.partitions > 1 {
            base_parts.refined_with_grid(sched.partitions)
        } else {
            base_parts
        };
        // The grid is already refined; the plan-level scheduler must not
        // refine again, so it packs over `refined` as-is.
        let inner = ScheduleConfig { enabled: true, partitions: 0 };
        let sched_plans = bp
            .progs
            .iter()
            .map(|p| CompiledPlan::compile_scheduled(p, rows, cols, &refined, &row_parts, inner))
            .collect::<Result<Vec<_>>>()?;
        let needs_grid = sched_plans.iter().any(|p| p.required_col_partitions().is_some());
        let sched_parts = if needs_grid { Some(refined) } else { bp.parts.clone() };
        // Run cost = one reconfiguration cycle (when partitions are set)
        // plus the plan cycles; compare honestly, reconfig included.
        let total = |parts: &Option<Partitions>, plans: &[CompiledPlan]| {
            parts.is_some() as usize + plans.iter().map(|p| p.cycles()).sum::<usize>()
        };
        if total(&sched_parts, &sched_plans) >= total(&serial.parts, &serial.plans) {
            return Ok(serial);
        }
        Ok(CompiledTmr {
            mode: self.mode,
            rows,
            cols,
            parts: sched_parts,
            plans: sched_plans,
            sched,
            output_cols: bp.output_cols,
            area_cols: bp.area_cols,
            items: bp.items,
        })
    }

    /// Mode-specific synthesis shared by the serial and scheduled
    /// compilations (§Perf refactor: *what programs run* is split from
    /// *how their plans are compiled*): the phase programs in execution
    /// order, the column partitions the strategy configures, and the
    /// run accounting.
    fn blueprint(&self, prog: &Program, rows: usize, cols: usize) -> Result<TmrBlueprint> {
        match self.mode {
            TmrMode::Off => Ok(TmrBlueprint {
                progs: vec![prog.clone()],
                parts: single_program_partitions(prog, cols)?,
                output_cols: prog.output_cols.clone(),
                area_cols: prog.width,
                items: rows,
            }),
            TmrMode::Serial => {
                let lay = Self::serial_layout(prog);
                ensure!((lay.width as usize) <= cols, "crossbar too narrow for serial TMR");
                let parts = single_program_partitions(prog, cols)?;
                let p2 = retarget_outputs(prog, &lay.copy2)?;
                let p3 = retarget_outputs(prog, &lay.copy3)?;
                let vote = per_bit_vote_program(
                    &prog.output_cols,
                    &lay.copy2,
                    &lay.copy3,
                    &lay.voted,
                    lay.scratch,
                );
                Ok(TmrBlueprint {
                    progs: vec![prog.clone(), p2, p3, vote],
                    parts,
                    output_cols: lay.voted,
                    area_cols: lay.width,
                    items: rows,
                })
            }
            TmrMode::Parallel => {
                let w = prog.width;
                let o = prog.output_cols.len() as u32;
                let vote_base = 3 * w;
                ensure!(
                    (vote_base + o + 1) as usize <= cols,
                    "crossbar too narrow for parallel TMR"
                );
                let p2 = prog.relocate(w);
                let p3 = prog.relocate(2 * w);
                let mut starts: Vec<u32> = vec![0, w, 2 * w];
                for p in [prog, &p2, &p3] {
                    starts.extend(p.partition_starts.iter().copied());
                }
                starts.sort_unstable();
                starts.dedup();
                starts.retain(|&s| (s as usize) < cols);
                let col_parts = Partitions::new(cols as u32, starts);
                ensure!(
                    prog.steps.len() == p2.steps.len() && p2.steps.len() == p3.steps.len(),
                    "copies must share cycle structure"
                );
                // Zip the three copies cycle-by-cycle: same latency as
                // one copy; validated once here instead of per cycle.
                let mut zipped = Program::new(&format!("{}*tmr3", prog.name));
                for i in 0..prog.steps.len() {
                    let mut ops = prog.steps[i].ops.clone();
                    ops.extend(p2.steps[i].ops.iter().copied());
                    ops.extend(p3.steps[i].ops.iter().copied());
                    zipped.steps.push(Step::many(ops));
                }
                let voted: Vec<u32> = (vote_base..vote_base + o).collect();
                let vote = per_bit_vote_program(
                    &prog.output_cols,
                    &p2.output_cols,
                    &p3.output_cols,
                    &voted,
                    vote_base + o,
                );
                Ok(TmrBlueprint {
                    progs: vec![zipped, vote],
                    parts: Some(col_parts),
                    output_cols: voted,
                    area_cols: vote_base + o + 1,
                    items: rows,
                })
            }
            TmrMode::SemiParallel => {
                ensure!(rows >= 4, "semi-parallel TMR needs >= 4 rows");
                let k = (rows - 1) / 3; // items; last row is voting scratch
                let scratch_row = (rows - 1) as u32;
                let parts = single_program_partitions(prog, cols)?;
                let (lo, hi) = match (prog.output_cols.iter().min(), prog.output_cols.iter().max())
                {
                    (Some(&lo), Some(&hi)) => (lo, hi),
                    _ => bail!("program has no outputs"),
                };
                let lanes = LaneRange::new(lo, hi + 1);
                // Per-item vote schedule: two in-column gates (Min3 + NOT,
                // each with its Set1 init) spanning the output columns,
                // copies at rows {i, i+k, i+2k} — one plan for all items.
                let vote = semi_vote_program(
                    &format!("{}*semivote", prog.name),
                    k,
                    scratch_row,
                    lanes,
                    |r| r,
                );
                Ok(TmrBlueprint {
                    progs: vec![prog.clone(), vote],
                    parts,
                    output_cols: prog.output_cols.clone(),
                    area_cols: prog.width,
                    items: k,
                })
            }
        }
    }

    /// Column layout of the two extra output copies + vote area appended
    /// after the program's width (serial mode).
    pub fn serial_layout(prog: &Program) -> SerialLayout {
        let o = prog.output_cols.len() as u32;
        let base = prog.width;
        SerialLayout {
            copy2: (base..base + o).collect(),
            copy3: (base + o..base + 2 * o).collect(),
            voted: (base + 2 * o..base + 3 * o).collect(),
            scratch: base + 3 * o,
            width: base + 3 * o + 1,
        }
    }

    fn execute_serial(
        &self,
        x: &mut Crossbar,
        prog: &Program,
        mut inj: Option<&mut Injector>,
        c0: u64,
    ) -> Result<TmrRun> {
        let lay = Self::serial_layout(prog);
        ensure!((lay.width as usize) <= x.cols(), "crossbar too narrow for serial TMR");
        self.configure_partitions(x, std::slice::from_ref(prog))?;
        // Copy 1: the original program.
        x.run_program_uncompiled(prog, inj.as_deref_mut())?;
        // Copies 2 and 3: same inputs, shared intermediates, retargeted
        // outputs (every gate re-inits its outputs, so reuse is sound).
        let p2 = retarget_outputs(prog, &lay.copy2)?;
        let p3 = retarget_outputs(prog, &lay.copy3)?;
        x.run_program_uncompiled(&p2, inj.as_deref_mut())?;
        x.run_program_uncompiled(&p3, inj.as_deref_mut())?;
        // Per-bit Minority3 voting (fallible).
        let vote = per_bit_vote_program(
            &prog.output_cols,
            &lay.copy2,
            &lay.copy3,
            &lay.voted,
            lay.scratch,
        );
        x.run_program_uncompiled(&vote, inj)?;
        Ok(TmrRun {
            output_cols: lay.voted,
            cycles: x.stats.cycles - c0,
            area_cols: lay.width,
            items: x.rows(),
        })
    }

    /// Column bases of the three parallel copies.
    pub fn parallel_copy_bases(prog: &Program) -> [u32; 3] {
        [0, prog.width, 2 * prog.width]
    }

    fn execute_parallel(
        &self,
        x: &mut Crossbar,
        prog: &Program,
        mut inj: Option<&mut Injector>,
        c0: u64,
    ) -> Result<TmrRun> {
        let w = prog.width;
        let o = prog.output_cols.len() as u32;
        let vote_base = 3 * w;
        ensure!((vote_base + o + 1) as usize <= x.cols(), "crossbar too narrow for parallel TMR");
        let p2 = prog.relocate(w);
        let p3 = prog.relocate(2 * w);
        // Each copy gets its own partition range (plus any internal
        // partition structure the function itself requires).
        let mut starts: Vec<u32> = vec![0, w, 2 * w];
        for p in [prog, &p2, &p3] {
            starts.extend(p.partition_starts.iter().copied());
        }
        starts.sort_unstable();
        starts.dedup();
        starts.retain(|&s| (s as usize) < x.cols());
        x.set_col_partitions(Partitions::new(x.cols() as u32, starts));
        // Zip the three copies cycle-by-cycle: same latency as one copy.
        ensure!(
            prog.steps.len() == p2.steps.len() && p2.steps.len() == p3.steps.len(),
            "copies must share cycle structure"
        );
        for i in 0..prog.steps.len() {
            let mut ops = prog.steps[i].ops.clone();
            ops.extend(p2.steps[i].ops.iter().copied());
            ops.extend(p3.steps[i].ops.iter().copied());
            x.apply_step(&Step::many(ops), inj.as_deref_mut())?;
        }
        let voted: Vec<u32> = (vote_base..vote_base + o).collect();
        let vote = per_bit_vote_program(
            &prog.output_cols,
            &p2.output_cols,
            &p3.output_cols,
            &voted,
            vote_base + o,
        );
        x.run_program_uncompiled(&vote, inj)?;
        Ok(TmrRun {
            output_cols: voted,
            cycles: x.stats.cycles - c0,
            area_cols: vote_base + o + 1,
            items: x.rows(),
        })
    }

    fn execute_semi(
        &self,
        x: &mut Crossbar,
        prog: &Program,
        mut inj: Option<&mut Injector>,
        c0: u64,
    ) -> Result<TmrRun> {
        let rows = x.rows();
        ensure!(rows >= 4, "semi-parallel TMR needs >= 4 rows");
        let k = (rows - 1) / 3; // items; last row is voting scratch
        let scratch_row = (rows - 1) as u32;
        self.configure_partitions(x, std::slice::from_ref(prog))?;
        // One pass over ALL rows computes all three copies at once —
        // that is the row-parallelism doing the triplication.
        x.run_program_uncompiled(prog, inj.as_deref_mut())?;
        // Vote per item: two in-column gates (Min3 + NOT) spanning the
        // output column range, copies at rows {i, i+k, i+2k}.
        let (lo, hi) = match (prog.output_cols.iter().min(), prog.output_cols.iter().max()) {
            (Some(&lo), Some(&hi)) => (lo, hi),
            _ => bail!("program has no outputs"),
        };
        let lanes = LaneRange::new(lo, hi + 1);
        for i in 0..k {
            let (r1, r2, r3) = (i as u32, (i + k) as u32, (i + 2 * k) as u32);
            x.apply_step(
                &Step::one(
                    MicroOp::with_dir(Dir::InCol, Gate::Set1, &[], scratch_row, lanes),
                ),
                inj.as_deref_mut(),
            )?;
            x.apply_step(
                &Step::one(MicroOp::with_dir(
                    Dir::InCol,
                    Gate::Min3,
                    &[r1, r2, r3],
                    scratch_row,
                    lanes,
                )),
                inj.as_deref_mut(),
            )?;
            // NOT back into the item row (overwrites the copy-1 outputs;
            // every column in [lo, hi] is an output or dead scratch).
            x.apply_step(
                &Step::one(MicroOp::with_dir(Dir::InCol, Gate::Set1, &[], r1, lanes)),
                inj.as_deref_mut(),
            )?;
            x.apply_step(
                &Step::one(MicroOp::with_dir(Dir::InCol, Gate::Not, &[scratch_row], r1, lanes)),
                inj.as_deref_mut(),
            )?;
        }
        Ok(TmrRun {
            output_cols: prog.output_cols.clone(),
            cycles: x.stats.cycles - c0,
            area_cols: prog.width,
            items: k,
        })
    }

    fn configure_partitions(&self, x: &mut Crossbar, progs: &[Program]) -> Result<()> {
        let mut starts: Vec<u32> = vec![0];
        for p in progs {
            starts.extend(p.partition_starts.iter().copied());
        }
        starts.sort_unstable();
        starts.dedup();
        if starts.len() > 1 || progs.iter().any(|p| !p.partition_starts.is_empty()) {
            x.set_col_partitions(Partitions::new(x.cols() as u32, starts));
        }
        Ok(())
    }
}

/// The semi-parallel per-item vote schedule: for each item i, Set1 +
/// Min3(rows {i, i+k, i+2k}) into the scratch row, then Set1 + NOT back
/// into item i's row — every row operand translated through `phys`
/// (§Health spare-row remap; the identity for a healthy array). Shared
/// by the compile-time plan and the runtime remapped path so the two
/// can never diverge.
fn semi_vote_program(
    name: &str,
    k: usize,
    scratch_row: u32,
    lanes: LaneRange,
    phys: impl Fn(u32) -> u32,
) -> Program {
    let mut vote = Program::new(name);
    for i in 0..k {
        let (r1, r2, r3) = (phys(i as u32), phys((i + k) as u32), phys((i + 2 * k) as u32));
        vote.steps.push(Step::one(MicroOp::with_dir(
            Dir::InCol,
            Gate::Set1,
            &[],
            scratch_row,
            lanes,
        )));
        vote.steps.push(Step::one(MicroOp::with_dir(
            Dir::InCol,
            Gate::Min3,
            &[r1, r2, r3],
            scratch_row,
            lanes,
        )));
        vote.steps.push(Step::one(MicroOp::with_dir(Dir::InCol, Gate::Set1, &[], r1, lanes)));
        vote.steps.push(Step::one(MicroOp::with_dir(
            Dir::InCol,
            Gate::Not,
            &[scratch_row],
            r1,
            lanes,
        )));
    }
    vote
}

/// Partition configuration a single program requires, mirroring
/// `TmrEngine::configure_partitions`: `None` when the program carries no
/// partition structure (the crossbar keeps its current configuration).
fn single_program_partitions(prog: &Program, cols: usize) -> Result<Option<Partitions>> {
    let mut starts: Vec<u32> = vec![0];
    starts.extend(prog.partition_starts.iter().copied());
    starts.sort_unstable();
    starts.dedup();
    if starts.len() > 1 || !prog.partition_starts.is_empty() {
        ensure!(
            starts.iter().all(|&s| (s as usize) < cols),
            "partition start beyond {cols} columns"
        );
        Ok(Some(Partitions::new(cols as u32, starts)))
    } else {
        Ok(None)
    }
}

/// Mode-specific synthesis output ([`TmrEngine::blueprint`]): the phase
/// programs and strategy metadata, before any plan compilation.
struct TmrBlueprint {
    /// Phase programs, in execution order.
    progs: Vec<Program>,
    /// Column partitions the strategy configures before running.
    parts: Option<Partitions>,
    output_cols: Vec<u32>,
    area_cols: u32,
    items: usize,
}

/// A TMR strategy compiled for one program on one crossbar shape: the
/// copies, the partition configuration and the vote schedule are frozen
/// into plans; execution is reduced to partition setup (when required)
/// plus `run_plan` calls. Immutable and `Send + Sync` — the coordinator
/// shares these across workers behind `Arc` (`mmpu::PlanCache`).
#[derive(Clone, Debug)]
pub struct CompiledTmr {
    pub mode: TmrMode,
    rows: usize,
    cols: usize,
    /// Column partitions to (re)configure before each execution, exactly
    /// when the legacy path would (`None`: leave the crossbar as-is).
    /// For a scheduled compilation this is the refined packing grid.
    parts: Option<Partitions>,
    plans: Vec<CompiledPlan>,
    /// The schedule the plans were compiled under (`off` for serial —
    /// including scheduled compilations that fell back to serial).
    sched: ScheduleConfig,
    output_cols: Vec<u32>,
    area_cols: u32,
    items: usize,
}

impl CompiledTmr {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Columns of the final (voted) outputs.
    pub fn output_cols(&self) -> &[u32] {
        &self.output_cols
    }

    /// Logical items per execution (throughput proxy): `rows` for
    /// Off/Serial/Parallel, `(rows - 1) / 3` for SemiParallel.
    pub fn items(&self) -> usize {
        self.items
    }

    /// Total compiled micro-ops across all phases (diagnostics).
    pub fn num_ops(&self) -> usize {
        self.plans.iter().map(|p| p.num_ops()).sum()
    }

    /// Total schedule cycles (bundles) across all phases — the packing
    /// telemetry's denominator: `num_ops / num_bundles` is the measured
    /// ops-per-cycle of this strategy.
    pub fn num_bundles(&self) -> usize {
        self.plans.iter().map(|p| p.cycles()).sum()
    }

    /// Whether any phase plan was packed by the list scheduler.
    pub fn is_scheduled(&self) -> bool {
        self.plans.iter().any(|p| p.is_scheduled())
    }

    /// Execute on a crossbar of the compiled shape. Bit-identical to
    /// `TmrEngine::execute` with the same injector stream.
    pub fn run(&self, x: &mut Crossbar, mut inj: Option<&mut Injector>) -> Result<TmrRun> {
        ensure!(
            x.rows() == self.rows && x.cols() == self.cols,
            "compiled for {}x{}, crossbar is {}x{}",
            self.rows,
            self.cols,
            x.rows(),
            x.cols()
        );
        let c0 = x.stats.cycles;
        if let Some(parts) = &self.parts {
            x.set_col_partitions(parts.clone());
        }
        for plan in &self.plans {
            x.run_plan(plan, inj.as_deref_mut())?;
        }
        Ok(TmrRun {
            output_cols: self.output_cols.clone(),
            cycles: x.stats.cycles - c0,
            area_cols: self.area_cols,
            items: self.items,
        })
    }

    /// SemiParallel + §Health: compile the per-item vote schedule with
    /// every row operand translated through a spare-row remap, so a
    /// scrubbed-out stuck row no longer consumes one of its triple's
    /// votes (the freed margin is what the remap buys). Remap *events*
    /// are rare but remapped *state* is permanent, so callers cache the
    /// returned plan until the remap changes (`mmpu::Mmpu` keeps one
    /// per crossbar per function) and the per-batch path stays fully
    /// compiled — same builder the identity plan froze, so the two can
    /// never diverge.
    pub fn compile_semi_remapped_vote(&self, remap: &[(u32, u32)]) -> Result<CompiledPlan> {
        ensure!(
            self.mode == TmrMode::SemiParallel,
            "row-remapped voting is a SemiParallel-only path"
        );
        let (lo, hi) = match (self.output_cols.iter().min(), self.output_cols.iter().max()) {
            (Some(&lo), Some(&hi)) => (lo, hi),
            _ => bail!("compiled semi-parallel strategy has no outputs"),
        };
        let lanes = LaneRange::new(lo, hi + 1);
        let scratch_row = (self.rows - 1) as u32;
        let phys = |r: u32| remap.iter().find(|&&(l, _)| l == r).map_or(r, |&(_, p)| p);
        let vote = semi_vote_program("semivote*remapped", self.items, scratch_row, lanes, phys);
        let row_parts = Partitions::whole(self.rows as u32);
        let whole_cols = Partitions::whole(self.cols as u32);
        let col_parts = self.parts.as_ref().unwrap_or(&whole_cols);
        // Same compilation mode as the frozen identity vote: a scheduled
        // strategy reschedules the remapped vote over its (already
        // refined) grid, a serial one compiles it serially — the two
        // vote plans can never diverge structurally from `plans[1]`.
        let inner = ScheduleConfig { enabled: self.sched.enabled, partitions: 0 };
        CompiledPlan::compile_scheduled(&vote, self.rows, self.cols, col_parts, &row_parts, inner)
    }

    /// Execute with a replacement vote plan (from
    /// [`CompiledTmr::compile_semi_remapped_vote`]) instead of the
    /// frozen identity vote; the function phase is byte-identical to
    /// [`CompiledTmr::run`] — in-row micro-ops already execute in every
    /// physical lane, spares included.
    pub fn run_semi_with_vote(
        &self,
        x: &mut Crossbar,
        mut inj: Option<&mut Injector>,
        vote: &CompiledPlan,
    ) -> Result<TmrRun> {
        ensure!(
            self.mode == TmrMode::SemiParallel,
            "row-remapped execution is a SemiParallel-only path"
        );
        ensure!(
            x.rows() == self.rows && x.cols() == self.cols,
            "compiled for {}x{}, crossbar is {}x{}",
            self.rows,
            self.cols,
            x.rows(),
            x.cols()
        );
        let c0 = x.stats.cycles;
        if let Some(parts) = &self.parts {
            x.set_col_partitions(parts.clone());
        }
        x.run_plan(&self.plans[0], inj.as_deref_mut())?;
        x.run_plan(vote, inj)?;
        Ok(TmrRun {
            output_cols: self.output_cols.clone(),
            cycles: x.stats.cycles - c0,
            area_cols: self.area_cols,
            items: self.items,
        })
    }
}

/// Layout of serial-TMR auxiliary columns.
#[derive(Clone, Debug)]
pub struct SerialLayout {
    pub copy2: Vec<u32>,
    pub copy3: Vec<u32>,
    pub voted: Vec<u32>,
    pub scratch: u32,
    pub width: u32,
}

/// Rewrite a program so its *output* columns land at `new_outs` instead.
/// Sound because function outputs are write-only within the program
/// (asserted here).
pub fn retarget_outputs(prog: &Program, new_outs: &[u32]) -> Result<Program> {
    ensure!(new_outs.len() == prog.output_cols.len(), "output arity mismatch");
    let map: std::collections::HashMap<u32, u32> =
        prog.output_cols.iter().copied().zip(new_outs.iter().copied()).collect();
    let mut p = prog.clone();
    for step in &mut p.steps {
        for op in &mut step.ops {
            // Outputs must never be read back.
            let arity = op.gate.arity();
            let reads = [op.a, op.b, op.c];
            for r in reads.iter().take(arity) {
                ensure!(
                    !map.contains_key(r),
                    "program {} reads output column {r}; cannot retarget",
                    prog.name
                );
            }
            if let Some(&n) = map.get(&op.out) {
                op.out = n;
                if arity == 0 {
                    op.a = n;
                    op.b = n;
                    op.c = n;
                }
            }
        }
    }
    p.output_cols = new_outs.to_vec();
    p.width = p.width.max(new_outs.iter().max().copied().unwrap_or(0) + 1);
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::adder::ripple_adder;
    use crate::errs::ErrorModel;

    fn load_adder_inputs(x: &mut Crossbar, lay: &crate::arith::adder::AdderLayout, pairs: &[(u64, u64)]) {
        for (r, &(a, b)) in pairs.iter().enumerate() {
            for i in 0..lay.a.width {
                x.state_mut().set(r, lay.a.col(i) as usize, (a >> i) & 1 == 1);
                x.state_mut().set(r, lay.b.col(i) as usize, (b >> i) & 1 == 1);
            }
        }
    }

    fn read_word(x: &Crossbar, row: usize, cols: &[u32]) -> u64 {
        cols.iter()
            .enumerate()
            .fold(0u64, |acc, (i, &c)| acc | ((x.get(row, c as usize) as u64) << i))
    }

    #[test]
    fn serial_tmr_clean_matches_baseline() {
        let (prog, lay) = ripple_adder(8);
        let pairs: Vec<(u64, u64)> = (0..16).map(|i| (i * 11 % 256, i * 7 % 256)).collect();
        let serial_width = TmrEngine::serial_layout(&prog).width as usize;
        let mut x = Crossbar::new(16, serial_width);
        load_adder_inputs(&mut x, &lay, &pairs);
        let run = TmrEngine::new(TmrMode::Serial).execute(&mut x, &prog, None).unwrap();
        for (r, &(a, b)) in pairs.iter().enumerate() {
            // outputs = sum bits then cout (order of prog.output_cols)
            let v = read_word(&x, r, &run.output_cols);
            assert_eq!(v & 0xFF, (a + b) & 0xFF, "row {r}");
        }
    }

    #[test]
    fn serial_tmr_trade_off_3x_latency_1x_area() {
        let (prog, _) = ripple_adder(16);
        let base_width = TmrEngine::serial_layout(&prog).width as usize;
        let mut xb = Crossbar::new(8, base_width);
        let base = TmrEngine::new(TmrMode::Off).execute(&mut xb, &prog, None).unwrap();
        let mut xs = Crossbar::new(8, base_width);
        let tmr = TmrEngine::new(TmrMode::Serial).execute(&mut xs, &prog, None).unwrap();
        let latency_ratio = tmr.cycles as f64 / base.cycles as f64;
        assert!((2.8..3.6).contains(&latency_ratio), "latency x{latency_ratio}");
        let area_ratio = tmr.area_cols as f64 / base.area_cols as f64;
        assert!(area_ratio < 2.0, "serial area should be ~1x (+outputs): x{area_ratio}");
    }

    #[test]
    fn parallel_tmr_trade_off_1x_latency_3x_area() {
        let (prog, lay) = ripple_adder(16);
        let w = prog.width as usize;
        let mut xb = Crossbar::new(8, 4 * w + 40);
        let base = TmrEngine::new(TmrMode::Off).execute(&mut xb, &prog, None).unwrap();
        let pairs: Vec<(u64, u64)> = (0..8).map(|i| (i * 311 % 65536, i * 77 % 65536)).collect();
        let mut xp = Crossbar::new(8, 4 * w + 40);
        // Pre-replicate the inputs into all three copies (paper: no
        // sharing in parallel mode).
        for base_col in TmrEngine::parallel_copy_bases(&prog) {
            for (r, &(a, b)) in pairs.iter().enumerate() {
                for i in 0..16 {
                    xp.state_mut().set(r, (base_col + lay.a.col(i)) as usize, (a >> i) & 1 == 1);
                    xp.state_mut().set(r, (base_col + lay.b.col(i)) as usize, (b >> i) & 1 == 1);
                }
            }
        }
        let run = TmrEngine::new(TmrMode::Parallel).execute(&mut xp, &prog, None).unwrap();
        for (r, &(a, b)) in pairs.iter().enumerate() {
            let v = read_word(&xp, r, &run.output_cols);
            assert_eq!(v & 0xFFFF, (a + b) & 0xFFFF, "row {r}");
        }
        // ~1x plus the per-bit voting tail; for a short 16-bit adder the
        // 2-gate/bit vote is a visible fraction (it amortizes away for
        // longer functions like MultPIM — asserted in the benches).
        let latency_ratio = run.cycles as f64 / base.cycles as f64;
        assert!(latency_ratio < 1.5, "parallel latency must stay ~1x: x{latency_ratio}");
        assert!(latency_ratio < 2.0, "must be far below serial's 3x");
        assert!(run.area_cols >= 3 * prog.width, "area 3x");
    }

    #[test]
    fn semi_parallel_keeps_area_divides_throughput() {
        let (prog, lay) = ripple_adder(8);
        let rows = 16; // 5 items + scratch
        let mut x = Crossbar::new(rows, prog.width as usize);
        let items = (rows - 1) / 3;
        let pairs: Vec<(u64, u64)> = (0..items as u64).map(|i| (i * 13 % 256, i * 29 % 256)).collect();
        for (i, &(a, b)) in pairs.iter().enumerate() {
            for copy in 0..3 {
                let r = i + copy * items;
                for bit in 0..8 {
                    x.state_mut().set(r, lay.a.col(bit) as usize, (a >> bit) & 1 == 1);
                    x.state_mut().set(r, lay.b.col(bit) as usize, (b >> bit) & 1 == 1);
                }
            }
        }
        let run = TmrEngine::new(TmrMode::SemiParallel).execute(&mut x, &prog, None).unwrap();
        assert_eq!(run.items, items, "throughput / 3");
        assert_eq!(run.area_cols, prog.width, "area 1x");
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let v = read_word(&x, i, &run.output_cols);
            assert_eq!(v & 0xFF, (a + b) & 0xFF, "item {i}");
        }
    }

    #[test]
    fn serial_tmr_corrects_injected_faults() {
        // Fig 3(b): with a high gate-error rate, the baseline is almost
        // always wrong somewhere, while TMR's voted output is right far
        // more often.
        let (prog, lay) = ripple_adder(8);
        let width = TmrEngine::serial_layout(&prog).width as usize;
        let pairs: Vec<(u64, u64)> = (0..64).map(|i| (i * 3 % 256, i * 5 % 256)).collect();
        let p = 2e-4;
        let count_correct = |mode: TmrMode, seed: u64| -> usize {
            let mut x = Crossbar::new(64, width);
            load_adder_inputs(&mut x, &lay, &pairs);
            let mut inj = Injector::new(ErrorModel::direct_only(p), seed, 0);
            let run = TmrEngine::new(mode).execute(&mut x, &prog, Some(&mut inj)).unwrap();
            pairs
                .iter()
                .enumerate()
                .filter(|(r, &(a, b))| read_word(&x, *r, &run.output_cols) & 0xFF == (a + b) & 0xFF)
                .count()
        };
        let mut base_correct = 0;
        let mut tmr_correct = 0;
        for seed in 0..8 {
            base_correct += count_correct(TmrMode::Off, seed);
            tmr_correct += count_correct(TmrMode::Serial, seed);
        }
        assert!(
            tmr_correct > base_correct,
            "TMR must beat baseline: {tmr_correct} vs {base_correct}"
        );
    }

    #[test]
    fn compiled_tmr_matches_legacy_all_modes() {
        // Same crossbar contents + same injector seed: the compiled path
        // must reproduce the legacy path bit-for-bit — state, stats, and
        // consumed error stream — for every strategy.
        let (prog, lay) = ripple_adder(8);
        let width = (TmrEngine::serial_layout(&prog).width as usize)
            .max(4 * prog.width as usize + 40);
        let pairs: Vec<(u64, u64)> = (0..21).map(|i| (i * 13 % 256, i * 57 % 256)).collect();
        for mode in [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel] {
            let rows = match mode {
                TmrMode::SemiParallel => 3 * pairs.len() + 1,
                _ => pairs.len(),
            };
            let load = |x: &mut Crossbar| match mode {
                TmrMode::Parallel => {
                    for base in TmrEngine::parallel_copy_bases(&prog) {
                        for (r, &(a, b)) in pairs.iter().enumerate() {
                            for i in 0..8 {
                                x.state_mut()
                                    .set(r, (base + lay.a.col(i)) as usize, (a >> i) & 1 == 1);
                                x.state_mut()
                                    .set(r, (base + lay.b.col(i)) as usize, (b >> i) & 1 == 1);
                            }
                        }
                    }
                }
                TmrMode::SemiParallel => {
                    for copy in 0..3 {
                        for (i, &(a, b)) in pairs.iter().enumerate() {
                            let r = i + copy * pairs.len();
                            for bit in 0..8 {
                                x.state_mut().set(r, lay.a.col(bit) as usize, (a >> bit) & 1 == 1);
                                x.state_mut().set(r, lay.b.col(bit) as usize, (b >> bit) & 1 == 1);
                            }
                        }
                    }
                }
                _ => load_adder_inputs(x, &lay, &pairs),
            };
            let engine = TmrEngine::new(mode);
            let mut legacy = Crossbar::new(rows, width);
            load(&mut legacy);
            let mut inj_a = Injector::new(ErrorModel::direct_only(1e-3), 77, 0);
            let run_a = engine.execute(&mut legacy, &prog, Some(&mut inj_a)).unwrap();
            let mut compiled = Crossbar::new(rows, width);
            load(&mut compiled);
            let ct = engine.compile(&prog, rows, width).unwrap();
            let mut inj_b = Injector::new(ErrorModel::direct_only(1e-3), 77, 0);
            let run_b = ct.run(&mut compiled, Some(&mut inj_b)).unwrap();
            assert_eq!(legacy.state(), compiled.state(), "{mode:?} state");
            assert_eq!(legacy.stats, compiled.stats, "{mode:?} stats");
            assert_eq!(inj_a.counters, inj_b.counters, "{mode:?} injector");
            assert_eq!(run_a.output_cols, run_b.output_cols, "{mode:?} outputs");
            assert_eq!(run_a.cycles, run_b.cycles, "{mode:?} cycles");
            assert_eq!(run_a.items, run_b.items, "{mode:?} items");
            assert_eq!(run_a.area_cols, run_b.area_cols, "{mode:?} area");
        }
    }

    #[test]
    fn compiled_tmr_is_reusable() {
        let (prog, lay) = ripple_adder(8);
        let width = TmrEngine::serial_layout(&prog).width as usize;
        let ct = TmrEngine::new(TmrMode::Serial).compile(&prog, 8, width).unwrap();
        let pairs: Vec<(u64, u64)> = (0..8).map(|i| (i * 9 % 256, i * 5 % 256)).collect();
        for _ in 0..3 {
            let mut x = Crossbar::new(8, width);
            load_adder_inputs(&mut x, &lay, &pairs);
            let run = ct.run(&mut x, None).unwrap();
            for (r, &(a, b)) in pairs.iter().enumerate() {
                let v = read_word(&x, r, &run.output_cols);
                assert_eq!(v & 0xFF, (a + b) & 0xFF, "row {r}");
            }
        }
        assert!(ct.num_ops() > 0);
    }

    #[test]
    fn scheduled_tmr_matches_serial_all_modes_clean() {
        // §Perf list scheduling at the strategy level: for every mode,
        // the scheduled compilation produces bit-identical final state
        // and wear (switched_bits) in the clean model, and never takes
        // more cycles than the serial compilation — partition
        // reconfiguration included.
        let (prog, lay) = ripple_adder(8);
        let width = (TmrEngine::serial_layout(&prog).width as usize)
            .max(4 * prog.width as usize + 40);
        let pairs: Vec<(u64, u64)> = (0..15).map(|i| (i * 13 % 256, i * 57 % 256)).collect();
        for mode in [TmrMode::Off, TmrMode::Serial, TmrMode::Parallel, TmrMode::SemiParallel] {
            let rows = match mode {
                TmrMode::SemiParallel => 3 * pairs.len() + 1,
                _ => pairs.len(),
            };
            let load = |x: &mut Crossbar| match mode {
                TmrMode::Parallel => {
                    for base in TmrEngine::parallel_copy_bases(&prog) {
                        for (r, &(a, b)) in pairs.iter().enumerate() {
                            for i in 0..8 {
                                x.state_mut()
                                    .set(r, (base + lay.a.col(i)) as usize, (a >> i) & 1 == 1);
                                x.state_mut()
                                    .set(r, (base + lay.b.col(i)) as usize, (b >> i) & 1 == 1);
                            }
                        }
                    }
                }
                TmrMode::SemiParallel => {
                    for copy in 0..3 {
                        for (i, &(a, b)) in pairs.iter().enumerate() {
                            let r = i + copy * pairs.len();
                            for bit in 0..8 {
                                x.state_mut().set(r, lay.a.col(bit) as usize, (a >> bit) & 1 == 1);
                                x.state_mut().set(r, lay.b.col(bit) as usize, (b >> bit) & 1 == 1);
                            }
                        }
                    }
                }
                _ => load_adder_inputs(x, &lay, &pairs),
            };
            let engine = TmrEngine::new(mode);
            let serial = engine.compile(&prog, rows, width).unwrap();
            let sched =
                engine.compile_with(&prog, rows, width, ScheduleConfig::packed(16)).unwrap();
            assert_eq!(sched.num_ops(), serial.num_ops(), "{mode:?}: packing drops no ops");
            assert!(sched.num_bundles() <= serial.num_bundles(), "{mode:?} bundles");
            let mut xs = Crossbar::new(rows, width);
            load(&mut xs);
            let run_s = serial.run(&mut xs, None).unwrap();
            let mut xp = Crossbar::new(rows, width);
            load(&mut xp);
            let run_p = sched.run(&mut xp, None).unwrap();
            assert_eq!(xs.state(), xp.state(), "{mode:?} final state");
            assert_eq!(xs.stats.switched_bits, xp.stats.switched_bits, "{mode:?} wear");
            assert_eq!(run_s.output_cols, run_p.output_cols, "{mode:?} outputs");
            assert!(
                run_p.cycles <= run_s.cycles,
                "{mode:?}: scheduled {} cycles vs serial {}",
                run_p.cycles,
                run_s.cycles
            );
            // Outputs stay correct through the scheduled path.
            for (i, &(a, b)) in pairs.iter().enumerate().take(sched.items()) {
                let v = read_word(&xp, i, &run_p.output_cols);
                assert_eq!(v & 0xFF, (a + b) & 0xFF, "{mode:?} item {i}");
            }
        }
    }

    #[test]
    fn scheduled_semi_remapped_vote_stays_consistent() {
        // The remapped vote of a *scheduled* semi-parallel strategy goes
        // through the same compilation mode as its frozen identity vote;
        // with an identity remap the two runs are bit-identical.
        let (prog, lay) = ripple_adder(8);
        let rows = 16;
        let items = (rows - 1) / 3;
        let pairs: Vec<(u64, u64)> =
            (0..items as u64).map(|i| (i * 13 % 256, i * 29 % 256)).collect();
        let load = |x: &mut Crossbar| {
            for copy in 0..3 {
                for (i, &(a, b)) in pairs.iter().enumerate() {
                    let r = i + copy * items;
                    for bit in 0..8 {
                        x.state_mut().set(r, lay.a.col(bit) as usize, (a >> bit) & 1 == 1);
                        x.state_mut().set(r, lay.b.col(bit) as usize, (b >> bit) & 1 == 1);
                    }
                }
            }
        };
        let ct = TmrEngine::new(TmrMode::SemiParallel)
            .compile_with(&prog, rows, prog.width as usize, ScheduleConfig::packed(8))
            .unwrap();
        let vote = ct.compile_semi_remapped_vote(&[]).unwrap();
        let mut xa = Crossbar::new(rows, prog.width as usize);
        load(&mut xa);
        let run_a = ct.run(&mut xa, None).unwrap();
        let mut xb = Crossbar::new(rows, prog.width as usize);
        load(&mut xb);
        let run_b = ct.run_semi_with_vote(&mut xb, None, &vote).unwrap();
        assert_eq!(xa.state(), xb.state());
        assert_eq!(run_a.cycles, run_b.cycles);
        for (i, &(a, b)) in pairs.iter().enumerate() {
            let v = read_word(&xb, i, &run_b.output_cols);
            assert_eq!(v & 0xFF, (a + b) & 0xFF, "item {i}");
        }
    }

    #[test]
    fn retarget_rejects_programs_reading_outputs() {
        use crate::isa::program::RowProgramBuilder;
        let mut b = RowProgramBuilder::no_init("bad");
        b.gate(Gate::Not, &[0], 1);
        b.gate(Gate::Not, &[1], 2); // reads col 1...
        b.outputs(&[1, 2]); // ...which is declared an output
        let p = b.finish();
        assert!(retarget_outputs(&p, &[5, 6]).is_err());
    }
}
