//! High-throughput in-memory TMR (paper §V, Fig. 3).
//!
//! Three execution strategies for a single-row function repeated across
//! all crossbar rows, each voting **per-bit** with the in-memory
//! Minority3 gate (itself fallible):
//!
//! * [`serial`]   — run the function three times, inputs and
//!   intermediates shared, outputs in three copies: ~3x latency, ~1x area;
//! * [`parallel`] — three partition-isolated copies in the same cycles:
//!   ~1x latency, 3x area;
//! * [`semi-parallel`] — three copies across *rows* (no partitions):
//!   ~1x latency, 1x area, 1/3 throughput, voting via in-column gates.

pub mod engine;
pub mod voting;

pub use engine::{CompiledTmr, TmrEngine, TmrMode, TmrRun};
pub use voting::{per_bit_vote_program, per_element_vote, VoteKind};
