//! Voting between TMR copies (paper §V).
//!
//! Per-bit voting: for every output bit position, `maj(o1, o2, o3)` is
//! realized as Minority3 followed by NOT — two stateful gates, repeated
//! with full row parallelism, so voting any number of output words costs
//! 2 gates per bit regardless of row count. Per-bit voting strictly
//! dominates per-element voting: they differ only where per-element
//! voting is undefined (no two copies agree on the whole element), where
//! per-bit still recovers every bit on which some two copies agree — the
//! paper's 1000/0100/0010 -> 0000 example.

use crate::isa::program::{Program, RowProgramBuilder};
use crate::xbar::gate::Gate;

/// Voting flavor (for the comparison study E10).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VoteKind {
    /// In-memory Minority3 + NOT per bit (fallible gates).
    PerBit,
    /// Whole-element agreement (reference model, not in-memory).
    PerElement,
    /// Idealized error-free per-bit majority (the dashed line of Fig. 4).
    IdealPerBit,
}

/// Synthesize the per-bit voting program: for each bit position k,
/// `out[k] = maj(c1[k], c2[k], c3[k])` via Min3 + NOT (2 logic gates +
/// 2 init writes per bit with auto-init).
///
/// `c1/c2/c3/out` are equal-length column lists (the three output copies
/// and the final destination); `scratch` is one work column.
pub fn per_bit_vote_program(
    c1: &[u32],
    c2: &[u32],
    c3: &[u32],
    out: &[u32],
    scratch: u32,
) -> Program {
    assert!(c1.len() == c2.len() && c2.len() == c3.len() && c3.len() == out.len());
    let mut b = RowProgramBuilder::new("vote3");
    b.inputs(c1);
    b.inputs(c2);
    b.inputs(c3);
    for k in 0..c1.len() {
        b.gate(Gate::Min3, &[c1[k], c2[k], c3[k]], scratch);
        b.gate(Gate::Not, &[scratch], out[k]);
    }
    b.outputs(out);
    b.finish()
}

/// Reference per-element vote: the value on which at least two copies
/// agree entirely, or `None` when all three disagree (undefined — the
/// case where per-bit voting still recovers agreeing bits).
pub fn per_element_vote(a: u64, b: u64, c: u64) -> Option<u64> {
    if a == b || a == c {
        Some(a)
    } else if b == c {
        Some(b)
    } else {
        None
    }
}

/// Reference per-bit majority of three words.
pub fn per_bit_vote_word(a: u64, b: u64, c: u64) -> u64 {
    (a & b) | (a & c) | (b & c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Cases;
    use crate::xbar::crossbar::Crossbar;

    #[test]
    fn paper_example_1000_0100_0010() {
        // Per-element: undefined. Per-bit: 0000 (correct when the true
        // value is 0000 and each copy took one distinct bit flip).
        assert_eq!(per_element_vote(0b1000, 0b0100, 0b0010), None);
        assert_eq!(per_bit_vote_word(0b1000, 0b0100, 0b0010), 0);
    }

    #[test]
    fn per_bit_dominates_per_element() {
        // Whenever per-element voting is defined, per-bit agrees with it;
        // per-bit additionally resolves the undefined cases.
        Cases::new(500).run(|g| {
            let a = g.u64() & 0xFF;
            let b = g.u64() & 0xFF;
            let c = g.u64() & 0xFF;
            if let Some(e) = per_element_vote(a, b, c) {
                assert_eq!(per_bit_vote_word(a, b, c), e);
            }
        });
    }

    #[test]
    fn vote_program_computes_majority_row_parallel() {
        // 8 output bits x 3 copies, across 32 rows at once.
        let w = 8usize;
        let c1: Vec<u32> = (0..w as u32).collect();
        let c2: Vec<u32> = (w as u32..2 * w as u32).collect();
        let c3: Vec<u32> = (2 * w as u32..3 * w as u32).collect();
        let out: Vec<u32> = (3 * w as u32..4 * w as u32).collect();
        let prog = per_bit_vote_program(&c1, &c2, &c3, &out, 4 * w as u32);
        let mut x = Crossbar::new(32, 4 * w + 1);
        let mut rng = crate::util::rng::Pcg64::new(5, 0);
        let mut words = vec![];
        for r in 0..32 {
            let (a, b, c) = (rng.next_u64() & 0xFF, rng.next_u64() & 0xFF, rng.next_u64() & 0xFF);
            words.push((a, b, c));
            for k in 0..w {
                x.state_mut().set(r, c1[k] as usize, (a >> k) & 1 == 1);
                x.state_mut().set(r, c2[k] as usize, (b >> k) & 1 == 1);
                x.state_mut().set(r, c3[k] as usize, (c >> k) & 1 == 1);
            }
        }
        x.run_program(&prog, None).unwrap();
        for (r, &(a, b, c)) in words.iter().enumerate() {
            let want = per_bit_vote_word(a, b, c);
            for k in 0..w {
                assert_eq!(x.get(r, out[k] as usize), (want >> k) & 1 == 1, "row {r} bit {k}");
            }
        }
        // Cost: 2 logic gates per bit, independent of the 32 rows.
        assert_eq!(prog.logic_gates_per_lane(), 2 * w);
    }

    #[test]
    fn vote_corrects_one_faulty_copy() {
        // Fig 3(b): each copy wrong in a different row/bit -> vote fixes.
        let c1 = [0u32];
        let c2 = [1u32];
        let c3 = [2u32];
        let out = [3u32];
        let prog = per_bit_vote_program(&c1, &c2, &c3, &out, 4);
        let mut x = Crossbar::new(3, 5);
        // truth = 1; one copy flipped per row (different copy each row)
        for r in 0..3 {
            for c in 0..3 {
                x.state_mut().set(r, c, c != r);
            }
        }
        x.run_program(&prog, None).unwrap();
        for r in 0..3 {
            assert!(x.get(r, 3), "row {r} majority must be 1");
        }
    }
}
