//! # REMUS — Reliable Memristive Processing-in-Memory
//!
//! A reproduction of *“Making Memristive Processing-in-Memory Reliable”*
//! (Leitersdorf, Ronen, Kvatinsky, 2021): a cycle-accurate memristive
//! Memory Processing Unit (mMPU) simulator with the paper's
//! high-throughput reliability mechanisms — diagonal-parity ECC and
//! in-memory TMR — plus the neural-network case study, built as a
//! three-layer Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! Layer map:
//! * [`xbar`], [`isa`], [`arith`], [`errs`] — the crossbar substrate:
//!   stateful logic, micro-op programs, arithmetic synthesis, soft errors.
//! * [`ecc`], [`tmr`], [`health`] — the paper's reliability contributions
//!   plus the online fault manager (scrubbing, spare remapping, wear-out).
//! * [`mmpu`], [`coordinator`], [`fabric`] — the controller, the
//!   request path, and the sharded multi-process serving layer.
//! * [`telemetry`] — per-request trace spans and the reliability
//!   event journal (fleet-wide observability).
//! * [`runtime`] — PJRT execution of the AOT-lowered JAX/Pallas kernels.
//! * [`nn`], [`analysis`], [`bitlet`] — the case study and the
//!   figure/table reproductions.

// Index-heavy bit-level simulation code: these pedantic-style lints fight
// the domain idiom (explicit (row, col) loops, wide config plumbing).
#![allow(clippy::needless_range_loop, clippy::too_many_arguments, clippy::type_complexity)]

pub mod analysis;
pub mod arith;
pub mod bench_harness;
pub mod bitlet;
pub mod coordinator;
pub mod ecc;
pub mod errs;
pub mod fabric;
pub mod health;
pub mod isa;
pub mod mmpu;
pub mod nn;
pub mod runtime;
pub mod telemetry;
pub mod testutil;
pub mod tmr;
pub mod util;
pub mod xbar;
