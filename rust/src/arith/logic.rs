//! Macro gates built from MAGIC/FELIX primitives.
//!
//! Every helper appends to a `RowProgramBuilder` and returns the output
//! column, so larger functions compose by chaining.

use crate::isa::program::RowProgramBuilder;
use crate::xbar::gate::Gate;

use super::layout::ColAlloc;

/// out = !x (one MAGIC NOT).
pub fn not(b: &mut RowProgramBuilder, x: u32, out: u32) -> u32 {
    b.gate(Gate::Not, &[x], out)
}

/// Copy x into out (two cascaded NOTs through a scratch column).
pub fn copy_bit(b: &mut RowProgramBuilder, alloc: &mut ColAlloc, x: u32, out: u32) -> u32 {
    let t = alloc.one();
    b.gate(Gate::Not, &[x], t);
    b.gate(Gate::Not, &[t], out)
}

/// out = x & y  (FELIX NAND + MAGIC NOT).
pub fn and2(b: &mut RowProgramBuilder, alloc: &mut ColAlloc, x: u32, y: u32, out: u32) -> u32 {
    let t = alloc.one();
    b.gate(Gate::Nand2, &[x, y], t);
    b.gate(Gate::Not, &[t], out)
}

/// out = x | y  (FELIX OR).
pub fn or2(b: &mut RowProgramBuilder, x: u32, y: u32, out: u32) -> u32 {
    b.gate(Gate::Or2, &[x, y], out)
}

/// out = x ^ y via NOR composition:
/// x^y = NOR(NOR(x,y), AND(x,y)); AND realized as NAND + NOT.
/// 4 logic gates total.
pub fn xor2(b: &mut RowProgramBuilder, alloc: &mut ColAlloc, x: u32, y: u32, out: u32) -> u32 {
    let cp = alloc.checkpoint();
    let nor_xy = alloc.one();
    let nand_xy = alloc.one();
    let and_xy = alloc.one();
    b.gate(Gate::Nor2, &[x, y], nor_xy);
    b.gate(Gate::Nand2, &[x, y], nand_xy);
    b.gate(Gate::Not, &[nand_xy], and_xy);
    b.gate(Gate::Nor2, &[nor_xy, and_xy], out);
    alloc.restore(cp);
    out
}

/// out = maj(x, y, z)  (FELIX Minority3 + NOT).
pub fn maj3(b: &mut RowProgramBuilder, alloc: &mut ColAlloc, x: u32, y: u32, z: u32, out: u32) -> u32 {
    let t = alloc.one();
    b.gate(Gate::Min3, &[x, y, z], t);
    b.gate(Gate::Not, &[t], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xbar::crossbar::Crossbar;

    /// Run a 2-input macro over all 4 input combinations (one per row).
    fn truth2(
        build: impl Fn(&mut RowProgramBuilder, &mut ColAlloc, u32, u32, u32) -> u32,
    ) -> Vec<bool> {
        let mut x = Crossbar::new(4, 32);
        for r in 0..4 {
            x.state_mut().set(r, 0, r & 1 == 1);
            x.state_mut().set(r, 1, r & 2 == 2);
        }
        let mut b = RowProgramBuilder::new("truth2");
        let mut alloc = ColAlloc::new(3, 32);
        build(&mut b, &mut alloc, 0, 1, 2);
        x.run_program(&b.finish(), None).unwrap();
        (0..4).map(|r| x.get(r, 2)).collect()
    }

    #[test]
    fn xor2_truth_table() {
        assert_eq!(truth2(|b, a, x, y, o| xor2(b, a, x, y, o)), vec![false, true, true, false]);
    }

    #[test]
    fn and2_truth_table() {
        assert_eq!(truth2(|b, a, x, y, o| and2(b, a, x, y, o)), vec![false, false, false, true]);
    }

    #[test]
    fn or2_truth_table() {
        assert_eq!(truth2(|b, _a, x, y, o| or2(b, x, y, o)), vec![false, true, true, true]);
    }

    #[test]
    fn copy_preserves_value() {
        assert_eq!(truth2(|b, a, x, _y, o| copy_bit(b, a, x, o)), vec![false, true, false, true]);
    }

    #[test]
    fn maj3_truth_table() {
        let mut x = Crossbar::new(8, 32);
        for r in 0..8 {
            x.state_mut().set(r, 0, r & 1 == 1);
            x.state_mut().set(r, 1, r & 2 == 2);
            x.state_mut().set(r, 2, r & 4 == 4);
        }
        let mut b = RowProgramBuilder::new("maj");
        let mut alloc = ColAlloc::new(4, 32);
        maj3(&mut b, &mut alloc, 0, 1, 2, 3);
        x.run_program(&b.finish(), None).unwrap();
        for r in 0..8 {
            let ones = (r & 1) + ((r >> 1) & 1) + ((r >> 2) & 1);
            assert_eq!(x.get(r, 3), ones >= 2, "row {r}");
        }
    }

    #[test]
    fn xor2_scratch_is_reusable() {
        // Two XORs sharing the allocator must not clobber each other.
        let mut x = Crossbar::new(4, 32);
        for r in 0..4 {
            x.state_mut().set(r, 0, r & 1 == 1);
            x.state_mut().set(r, 1, r & 2 == 2);
        }
        let mut b = RowProgramBuilder::new("xx");
        let mut alloc = ColAlloc::new(4, 32);
        xor2(&mut b, &mut alloc, 0, 1, 2);
        xor2(&mut b, &mut alloc, 2, 1, 3); // (x^y)^y = x
        x.run_program(&b.finish(), None).unwrap();
        for r in 0..4 {
            assert_eq!(x.get(r, 3), r & 1 == 1, "row {r}");
        }
    }
}
