//! Arithmetic-function synthesis (paper §III-B) — substrate S4.
//!
//! Maps Boolean/arithmetic functions onto single-row micro-op programs so
//! the same function repeats across every crossbar row (vectored
//! execution). Provides the MAGIC/FELIX macro gates, the ripple-carry
//! adder, the partition-parallel **MultPIM-style multiplier** (the
//! paper's §VI-A workload, after [9]), and a serial shift-add baseline.

pub mod adder;
pub mod layout;
pub mod logic;
pub mod multiplier;

pub use adder::{full_adder_gates, ripple_adder, AdderLayout};
pub use layout::{BitField, ColAlloc};
pub use multiplier::{multpim_program, naive_mult_program, MultLayout};
