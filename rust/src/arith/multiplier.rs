//! Fixed-point multiplication as a single-row function.
//!
//! Two implementations:
//!
//! * [`multpim_program`] — a **partition-parallel carry-save multiplier**
//!   in the spirit of MultPIM [9] (the algorithm the paper's §VI-A
//!   reliability case study simulates): N partitions, one per bit
//!   position, each holding one bit of A/B plus a carry-save accumulator
//!   slice. Every iteration broadcasts one B bit (multi-output NOT, one
//!   cycle), forms partial products, runs the 6-gate Min3 full adder in
//!   all partitions simultaneously, and shifts the sum one partition
//!   right (two-phase neighbor transfers). O(N) cycles per iteration
//!   constant, O(N) iterations; O(N^2) total gate *executions* per lane —
//!   the soft-error sites that drive Fig. 4.
//! * [`naive_mult_program`] — the serial shift-add baseline confined to a
//!   single partition: O(N^2) cycles. Used for the throughput/ablation
//!   comparisons (it is what the mMPU would do *without* partition
//!   parallelism).
//!
//! Layout of the MultPIM-style program (see `MultLayout`): the low result
//! field `r[0..2n)` lives at the start of partition 0; partition k then
//! occupies `SLOTS` columns holding
//! `[a, b, na, s, c, nbb, pp, t0, t1, t2, t3, sum, tmpS, tmpR]`.

use crate::isa::microop::MicroOp;
use crate::isa::program::Program;
use crate::xbar::gate::Gate;

use super::layout::BitField;

/// Per-partition slot indices.
const SLOT_A: u32 = 0;
const SLOT_B: u32 = 1;
const SLOT_NA: u32 = 2;
const SLOT_S: u32 = 3;
const SLOT_C: u32 = 4;
const SLOT_NBB: u32 = 5; // broadcast !b_i; reused as CPA carry
const SLOT_PP: u32 = 6;
const SLOT_T0: u32 = 7;
const SLOT_T1: u32 = 8;
const SLOT_T2: u32 = 9;
const SLOT_T3: u32 = 10;
const SLOT_SUM: u32 = 11;
const SLOT_TMPS: u32 = 12;
const SLOT_TMPR: u32 = 13;
/// Columns per partition.
pub const SLOTS: u32 = 14;

/// Interface of the synthesized multiplier.
#[derive(Clone, Debug)]
pub struct MultLayout {
    pub n: u32,
    /// Column of bit k of operand A (scattered: one per partition).
    pub a_cols: Vec<u32>,
    /// Column of bit k of operand B.
    pub b_cols: Vec<u32>,
    /// 2n-bit little-endian product field.
    pub result: BitField,
    /// Total columns used.
    pub width: u32,
    /// Column-partition starts to configure on the crossbar before
    /// running with partition-parallel steps.
    pub partition_starts: Vec<u32>,
}

struct Builder {
    prog: Program,
    n: u32,
}

impl Builder {
    fn base(&self, k: u32) -> u32 {
        2 * self.n + k * SLOTS
    }

    fn col(&self, k: u32, slot: u32) -> u32 {
        self.base(k) + slot
    }

    /// One parallel logic step across partitions, preceded by its
    /// parallel SET1 init step (MAGIC output initialization).
    fn par(&mut self, ops: Vec<MicroOp>) {
        let inits: Vec<MicroOp> =
            ops.iter().map(|o| MicroOp::row(Gate::Set1, &[], o.out)).collect();
        self.prog.push_parallel(inits);
        self.prog.push_parallel(ops);
    }

    /// One serial gate with init.
    fn one(&mut self, op: MicroOp) {
        self.prog.push(MicroOp::row(Gate::Set1, &[], op.out));
        self.prog.push(op);
    }
}

/// Synthesize the n-bit partition-parallel multiplier.
/// `r = a * b`, all little-endian; see module docs for cost model.
pub fn multpim_program(n: u32) -> (Program, MultLayout) {
    assert!(n >= 2, "multiplier needs n >= 2");
    let mut bld = Builder { prog: Program::new(&format!("multpim{n}")), n };

    // --- prologue: na_k = !a_k ; s_k = c_k = 0 ----------------------
    let nots: Vec<MicroOp> = (0..n)
        .map(|k| MicroOp::row(Gate::Not, &[bld.col(k, SLOT_A)], bld.col(k, SLOT_NA)))
        .collect();
    bld.par(nots);
    bld.prog.push_parallel(
        (0..n).map(|k| MicroOp::row(Gate::Set0, &[], bld.col(k, SLOT_S))).collect(),
    );
    bld.prog.push_parallel(
        (0..n).map(|k| MicroOp::row(Gate::Set0, &[], bld.col(k, SLOT_C))).collect(),
    );

    // --- main loop: one iteration per B bit -------------------------
    for i in 0..n {
        let b_i = bld.col(i, SLOT_B);
        // (1) broadcast !b_i into every partition (fan-out NOT, 1 cycle).
        let bcast: Vec<MicroOp> =
            (0..n).map(|k| MicroOp::row(Gate::Not, &[b_i], bld.col(k, SLOT_NBB))).collect();
        bld.par(bcast);
        // (2) partial product: pp_k = a_k & b_i = NOR(na_k, nbb_k).
        let pps: Vec<MicroOp> = (0..n)
            .map(|k| {
                MicroOp::row(
                    Gate::Nor2,
                    &[bld.col(k, SLOT_NA), bld.col(k, SLOT_NBB)],
                    bld.col(k, SLOT_PP),
                )
            })
            .collect();
        bld.par(pps);
        // (3) carry-save full adder in every partition.
        for (ins, out) in [
            ([SLOT_PP, SLOT_S, SLOT_C], SLOT_T0),
            ([SLOT_PP, SLOT_S, SLOT_T0], SLOT_T1),
            ([SLOT_PP, SLOT_C, SLOT_T0], SLOT_T2),
            ([SLOT_S, SLOT_C, SLOT_T0], SLOT_T3),
            ([SLOT_T1, SLOT_T2, SLOT_T3], SLOT_SUM),
        ] {
            let ops: Vec<MicroOp> = (0..n)
                .map(|k| {
                    MicroOp::row(
                        Gate::Min3,
                        &[bld.col(k, ins[0]), bld.col(k, ins[1]), bld.col(k, ins[2])],
                        bld.col(k, out),
                    )
                })
                .collect();
            bld.par(ops);
        }
        // c_k = !t0 (new carry, weight k after the shift below).
        let carries: Vec<MicroOp> = (0..n)
            .map(|k| MicroOp::row(Gate::Not, &[bld.col(k, SLOT_T0)], bld.col(k, SLOT_C)))
            .collect();
        bld.par(carries);
        // (4) result bit i = sum_0 (2-NOT copy inside partition 0).
        bld.one(MicroOp::row(Gate::Not, &[bld.col(0, SLOT_SUM)], bld.col(0, SLOT_TMPR)));
        bld.one(MicroOp::row(Gate::Not, &[bld.col(0, SLOT_TMPR)], i));
        // (5) shift: s_k = sum_{k+1} (two-phase neighbor transfers),
        //     s_{n-1} = 0.
        for phase in 0..2u32 {
            let ops: Vec<MicroOp> = (0..n - 1)
                .filter(|k| k % 2 == phase)
                .map(|k| {
                    MicroOp::row(
                        Gate::Not,
                        &[bld.col(k + 1, SLOT_SUM)],
                        bld.col(k, SLOT_TMPS),
                    )
                })
                .collect();
            if !ops.is_empty() {
                bld.par(ops);
            }
        }
        let mut settle: Vec<MicroOp> = (0..n - 1)
            .map(|k| MicroOp::row(Gate::Not, &[bld.col(k, SLOT_TMPS)], bld.col(k, SLOT_S)))
            .collect();
        let init_settle: Vec<MicroOp> =
            settle.iter().map(|o| MicroOp::row(Gate::Set1, &[], o.out)).collect();
        bld.prog.push_parallel(init_settle);
        // s_{n-1} = 0 can share the settle cycle (distinct partition).
        settle.push(MicroOp::row(Gate::Set0, &[], bld.col(n - 1, SLOT_S)));
        bld.prog.push_parallel(settle);
    }

    // --- epilogue: carry-propagate add of (s, c) -> high result bits --
    // carry lives in SLOT_NBB (free after the loop); serial ripple.
    bld.prog.push(MicroOp::row(Gate::Set0, &[], bld.col(0, SLOT_NBB)));
    for k in 0..n {
        let (a, b, cin) = (bld.col(k, SLOT_S), bld.col(k, SLOT_C), bld.col(k, SLOT_NBB));
        let (t0, t1, t2, t3) =
            (bld.col(k, SLOT_T0), bld.col(k, SLOT_T1), bld.col(k, SLOT_T2), bld.col(k, SLOT_T3));
        let (h, cout) = (bld.col(k, SLOT_SUM), bld.col(k, SLOT_TMPR));
        bld.one(MicroOp::row(Gate::Min3, &[a, b, cin], t0));
        bld.one(MicroOp::row(Gate::Not, &[t0], cout));
        bld.one(MicroOp::row(Gate::Min3, &[a, b, t0], t1));
        bld.one(MicroOp::row(Gate::Min3, &[a, cin, t0], t2));
        bld.one(MicroOp::row(Gate::Min3, &[b, cin, t0], t3));
        bld.one(MicroOp::row(Gate::Min3, &[t1, t2, t3], h));
        // carry into partition k+1 first (2-NOT neighbor transfer) —
        // must precede the result copy, which reuses tmpR_0 (= cout_0).
        if k + 1 < n {
            bld.one(MicroOp::row(Gate::Not, &[cout], bld.col(k, SLOT_TMPS)));
            bld.one(MicroOp::row(
                Gate::Not,
                &[bld.col(k, SLOT_TMPS)],
                bld.col(k + 1, SLOT_NBB),
            ));
        }
        // result bit n+k = h (long-range 2-NOT copy through partition 0's
        // tmpR; transistors along the path close for the cycle).
        bld.one(MicroOp::row(Gate::Not, &[h], bld.col(0, SLOT_TMPR)));
        bld.one(MicroOp::row(Gate::Not, &[bld.col(0, SLOT_TMPR)], n + k));
    }

    let n_ = n;
    let a_cols: Vec<u32> = (0..n_).map(|k| bld.col(k, SLOT_A)).collect();
    let b_cols: Vec<u32> = (0..n_).map(|k| bld.col(k, SLOT_B)).collect();
    let width = bld.col(n_ - 1, SLOTS - 1) + 1;
    // Partition 0 spans the result field + its slots.
    let partition_starts: Vec<u32> =
        std::iter::once(0).chain((1..n_).map(|k| bld.base(k))).collect();
    let mut prog = bld.prog;
    prog.input_cols = a_cols.iter().chain(b_cols.iter()).copied().collect();
    prog.output_cols = (0..2 * n_).collect();
    prog.partition_starts = partition_starts.clone();
    let layout = MultLayout {
        n: n_,
        a_cols,
        b_cols,
        result: BitField::new(0, 2 * n_),
        width,
        partition_starts,
    };
    (prog, layout)
}

/// Serial shift-add baseline (single partition, no concurrency):
/// acc := acc + (a & b_i) << i, fully ripple-carried, O(n^2) cycles.
pub fn naive_mult_program(n: u32) -> (Program, MultLayout) {
    assert!(n >= 2);
    use crate::isa::program::RowProgramBuilder;
    let mut b = RowProgramBuilder::new(&format!("naive_mult{n}"));
    // layout: [a(n) | b(n) | acc(2n) | pp | t0..t3 | carry chain(2)]
    let a = BitField::new(0, n);
    let bf = BitField::new(n, n);
    let acc = BitField::new(2 * n, 2 * n);
    let pp = 4 * n;
    let t0 = 4 * n + 1;
    let t1 = 4 * n + 2;
    let t2 = 4 * n + 3;
    let t3 = 4 * n + 4;
    let na = 4 * n + 5;
    let nb = 4 * n + 6;
    let carry = 4 * n + 7;
    let carry2 = 4 * n + 8;
    let width = 4 * n + 9;
    b.inputs(&a.cols());
    b.inputs(&bf.cols());
    for i in 0..2 * n {
        b.set0(acc.col(i));
    }
    for i in 0..n {
        b.gate(Gate::Not, &[bf.col(i)], nb);
        b.set0(carry);
        for j in 0..n {
            // pp = a_j & b_i = NOR(!a_j, !b_i)
            b.gate(Gate::Not, &[a.col(j)], na);
            b.gate(Gate::Nor2, &[na, nb], pp);
            // acc[i+j] += pp with ripple carry.
            let d = acc.col(i + j);
            b.gate(Gate::Min3, &[pp, d, carry], t0);
            b.gate(Gate::Min3, &[pp, d, t0], t1);
            b.gate(Gate::Min3, &[pp, carry, t0], t2);
            b.gate(Gate::Min3, &[d, carry, t0], t3);
            // d (acc bit) is free after t3: overwrite with the sum.
            b.gate(Gate::Min3, &[t1, t2, t3], d);
            b.gate(Gate::Not, &[t0], carry2);
            // carry <- carry2 (2-NOT copy through t0, now free)
            b.gate(Gate::Not, &[carry2], t0);
            b.gate(Gate::Not, &[t0], carry);
        }
        // propagate the final carry into the remaining accumulator bits.
        let mut pos = i + n;
        while pos < 2 * n {
            let d = acc.col(pos);
            // (d, carry) = half-add(d, carry):
            //   new_d = d ^ carry ; new_carry = d & carry
            b.gate(Gate::Nand2, &[d, carry], t0); // !(d&c)
            b.gate(Gate::Nor2, &[d, carry], t1); // !(d|c)
            b.gate(Gate::Not, &[t0], t2); // d&c  (new carry)
            b.gate(Gate::Nor2, &[t1, t2], d); // d^c
            b.gate(Gate::Not, &[t2], t3);
            b.gate(Gate::Not, &[t3], carry);
            pos += 1;
        }
    }
    b.outputs(&acc.cols());
    let prog = b.finish();
    let layout = MultLayout {
        n,
        a_cols: a.cols(),
        b_cols: bf.cols(),
        result: acc,
        width,
        partition_starts: vec![0],
    };
    (prog, layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Cases;
    use crate::xbar::crossbar::Crossbar;
    use crate::xbar::partition::Partitions;

    fn run_mult(
        make: fn(u32) -> (Program, MultLayout),
        n: u32,
        pairs: &[(u64, u64)],
    ) -> Vec<u64> {
        let (prog, lay) = make(n);
        let mut x = Crossbar::new(pairs.len(), lay.width as usize);
        if lay.partition_starts.len() > 1 {
            x.set_col_partitions(Partitions::new(lay.width, lay.partition_starts.clone()));
        }
        for (r, &(av, bv)) in pairs.iter().enumerate() {
            for k in 0..n {
                x.state_mut().set(r, lay.a_cols[k as usize] as usize, (av >> k) & 1 == 1);
                x.state_mut().set(r, lay.b_cols[k as usize] as usize, (bv >> k) & 1 == 1);
            }
        }
        x.run_program(&prog, None).unwrap();
        pairs
            .iter()
            .enumerate()
            .map(|(r, _)| {
                let mut v = 0u64;
                for i in 0..2 * n {
                    if x.get(r, lay.result.col(i) as usize) {
                        v |= 1 << i;
                    }
                }
                v
            })
            .collect()
    }

    #[test]
    fn multpim_exhaustive_4bit() {
        let mut pairs = vec![];
        for a in 0..16u64 {
            for b in 0..16u64 {
                pairs.push((a, b));
            }
        }
        let got = run_mult(multpim_program, 4, &pairs);
        for (&(a, b), &p) in pairs.iter().zip(&got) {
            assert_eq!(p, a * b, "{a}*{b}");
        }
    }

    #[test]
    fn naive_exhaustive_4bit() {
        let mut pairs = vec![];
        for a in 0..16u64 {
            for b in 0..16u64 {
                pairs.push((a, b));
            }
        }
        let got = run_mult(naive_mult_program, 4, &pairs);
        for (&(a, b), &p) in pairs.iter().zip(&got) {
            assert_eq!(p, a * b, "{a}*{b}");
        }
    }

    #[test]
    fn multpim_random_16bit() {
        Cases::new(20).run(|g| {
            let a = g.u64() & 0xFFFF;
            let b = g.u64() & 0xFFFF;
            let got = run_mult(multpim_program, 16, &[(a, b)]);
            assert_eq!(got[0], a * b, "{a}*{b}");
        });
    }

    #[test]
    fn multpim_random_32bit_rowparallel() {
        // 32 multiplications at once (one per row) — the §VI workload.
        let mut pairs = vec![];
        let mut g = crate::util::rng::Pcg64::new(99, 0);
        for _ in 0..32 {
            pairs.push((g.next_u64() & 0xFFFF_FFFF, g.next_u64() & 0xFFFF_FFFF));
        }
        let got = run_mult(multpim_program, 32, &pairs);
        for (&(a, b), &p) in pairs.iter().zip(&got) {
            assert_eq!(p, a * b, "{a}*{b}");
        }
    }

    #[test]
    fn naive_random_8bit() {
        Cases::new(20).run(|g| {
            let a = g.u64() & 0xFF;
            let b = g.u64() & 0xFF;
            let got = run_mult(naive_mult_program, 8, &[(a, b)]);
            assert_eq!(got[0], a * b, "{a}*{b}");
        });
    }

    #[test]
    fn multpim_cost_model() {
        // O(N) cycles per iteration x N iterations; O(N^2) gates; the
        // partition-parallel latency advantage over the serial baseline.
        let (p32, _) = multpim_program(32);
        let (naive32, _) = naive_mult_program(32);
        let g = p32.logic_gates_per_lane();
        assert!(
            (9_000..13_000).contains(&g),
            "multpim-32 gate executions per lane = {g}"
        );
        assert!(p32.cycles() < naive32.cycles() / 8, "partitions must win on latency: {} vs {}", p32.cycles(), naive32.cycles());
        assert!(p32.max_parallelism() >= 32);
        assert_eq!(naive32.max_parallelism(), 1);
    }
}
