//! Column layout helpers for single-row functions.

/// A contiguous little-endian bit field within a row: bit `i` of the
/// value lives in column `base + i`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitField {
    pub base: u32,
    pub width: u32,
}

impl BitField {
    pub fn new(base: u32, width: u32) -> Self {
        assert!(width > 0);
        Self { base, width }
    }

    /// Column of bit `i`.
    pub fn col(&self, i: u32) -> u32 {
        assert!(i < self.width, "bit {i} outside field of width {}", self.width);
        self.base + i
    }

    pub fn cols(&self) -> Vec<u32> {
        (0..self.width).map(|i| self.base + i).collect()
    }

    pub fn end(&self) -> u32 {
        self.base + self.width
    }
}

/// Bump allocator for work columns while synthesizing a program.
#[derive(Clone, Debug)]
pub struct ColAlloc {
    next: u32,
    limit: u32,
    high_water: u32,
}

impl ColAlloc {
    pub fn new(start: u32, limit: u32) -> Self {
        assert!(start <= limit);
        Self { next: start, limit, high_water: start }
    }

    pub fn one(&mut self) -> u32 {
        let c = self.next;
        assert!(c < self.limit, "out of columns (limit {})", self.limit);
        self.next += 1;
        self.high_water = self.high_water.max(self.next);
        c
    }

    pub fn field(&mut self, width: u32) -> BitField {
        let base = self.next;
        assert!(base + width <= self.limit, "out of columns for field of {width}");
        self.next += width;
        self.high_water = self.high_water.max(self.next);
        BitField::new(base, width)
    }

    /// Roll back to a checkpoint (frees everything allocated after it) —
    /// used to reuse scratch columns across adder stages.
    pub fn checkpoint(&self) -> u32 {
        self.next
    }

    pub fn restore(&mut self, cp: u32) {
        assert!(cp <= self.next);
        self.next = cp;
    }

    /// Highest column ever allocated (area accounting).
    pub fn high_water(&self) -> u32 {
        self.high_water
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_columns() {
        let f = BitField::new(8, 4);
        assert_eq!(f.col(0), 8);
        assert_eq!(f.col(3), 11);
        assert_eq!(f.cols(), vec![8, 9, 10, 11]);
        assert_eq!(f.end(), 12);
    }

    #[test]
    #[should_panic]
    fn field_oob() {
        BitField::new(0, 4).col(4);
    }

    #[test]
    fn alloc_and_restore() {
        let mut a = ColAlloc::new(0, 100);
        let x = a.one();
        let f = a.field(10);
        assert_eq!(x, 0);
        assert_eq!(f.base, 1);
        let cp = a.checkpoint();
        let _ = a.field(20);
        assert_eq!(a.high_water(), 31);
        a.restore(cp);
        assert_eq!(a.one(), 11);
        assert_eq!(a.high_water(), 31, "high water survives restore");
    }

    #[test]
    #[should_panic]
    fn alloc_exhaustion_panics() {
        let mut a = ColAlloc::new(0, 4);
        a.field(5);
    }
}
