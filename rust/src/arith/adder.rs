//! N-bit ripple-carry addition as a single-row function (MAGIC/FELIX).
//!
//! The full adder uses the 6-gate Minority3 construction (after FELIX /
//! MultPIM): `min = Min3(a,b,cin)` gives the inverted carry; three more
//! Min3 gates against `min` plus a final Min3 produce the sum.

use crate::isa::program::{Program, RowProgramBuilder};
use crate::xbar::gate::Gate;

use super::layout::{BitField, ColAlloc};

/// Interface columns of a synthesized adder.
#[derive(Clone, Copy, Debug)]
pub struct AdderLayout {
    pub a: BitField,
    pub b: BitField,
    pub sum: BitField,
    pub cout: u32,
    /// Total columns used.
    pub width: u32,
}

/// Emit one full adder: (sum, cout) = a + b + cin. 6 logic gates.
pub fn full_adder_gates(
    bld: &mut RowProgramBuilder,
    alloc: &mut ColAlloc,
    a: u32,
    b: u32,
    cin: u32,
    sum: u32,
    cout: u32,
) {
    let cp = alloc.checkpoint();
    let t0 = alloc.one();
    let t1 = alloc.one();
    let t2 = alloc.one();
    let t3 = alloc.one();
    bld.gate(Gate::Min3, &[a, b, cin], t0); // !maj = !carry
    bld.gate(Gate::Not, &[t0], cout);
    bld.gate(Gate::Min3, &[a, b, t0], t1);
    bld.gate(Gate::Min3, &[a, cin, t0], t2);
    bld.gate(Gate::Min3, &[b, cin, t0], t3);
    bld.gate(Gate::Min3, &[t1, t2, t3], sum);
    alloc.restore(cp);
}

/// Synthesize an N-bit ripple-carry adder: sum = a + b (little-endian
/// fields), carry-out in `cout`. 6N logic gates, 12N + O(1) cycles with
/// auto-init.
pub fn ripple_adder(n: u32) -> (Program, AdderLayout) {
    assert!(n >= 1);
    let mut bld = RowProgramBuilder::new(&format!("add{n}"));
    // Layout: [a(n) | b(n) | sum(n) | carries(n+1) | scratch(4)]
    let a = BitField::new(0, n);
    let b = BitField::new(n, n);
    let sum = BitField::new(2 * n, n);
    let carries = BitField::new(3 * n, n + 1);
    let mut alloc = ColAlloc::new(carries.end(), carries.end() + 8);
    bld.inputs(&a.cols());
    bld.inputs(&b.cols());
    bld.set0(carries.col(0)); // cin = 0
    for i in 0..n {
        full_adder_gates(
            &mut bld,
            &mut alloc,
            a.col(i),
            b.col(i),
            carries.col(i),
            sum.col(i),
            carries.col(i + 1),
        );
    }
    bld.outputs(&sum.cols());
    bld.outputs(&[carries.col(n)]);
    let layout = AdderLayout { a, b, sum, cout: carries.col(n), width: alloc.high_water() };
    (bld.finish(), layout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Cases;
    use crate::xbar::crossbar::Crossbar;

    /// Execute the adder program for concrete operands in a given row.
    fn run_adder(n: u32, pairs: &[(u64, u64)]) -> Vec<(u64, bool)> {
        let (prog, lay) = ripple_adder(n);
        let mut x = Crossbar::new(pairs.len().max(1), lay.width as usize);
        for (r, &(av, bv)) in pairs.iter().enumerate() {
            for i in 0..n {
                x.state_mut().set(r, lay.a.col(i) as usize, (av >> i) & 1 == 1);
                x.state_mut().set(r, lay.b.col(i) as usize, (bv >> i) & 1 == 1);
            }
        }
        x.run_program(&prog, None).unwrap();
        pairs
            .iter()
            .enumerate()
            .map(|(r, _)| {
                let mut s = 0u64;
                for i in 0..n {
                    if x.get(r, lay.sum.col(i) as usize) {
                        s |= 1 << i;
                    }
                }
                (s, x.get(r, lay.cout as usize))
            })
            .collect()
    }

    #[test]
    fn exhaustive_4bit() {
        let mut pairs = vec![];
        for a in 0..16u64 {
            for b in 0..16u64 {
                pairs.push((a, b));
            }
        }
        let got = run_adder(4, &pairs);
        for (&(a, b), &(s, c)) in pairs.iter().zip(&got) {
            let full = a + b;
            assert_eq!(s, full & 0xF, "{a}+{b}");
            assert_eq!(c, full >> 4 == 1, "{a}+{b} carry");
        }
    }

    #[test]
    fn random_32bit() {
        Cases::new(40).run(|g| {
            let a = g.u64() & 0xFFFF_FFFF;
            let b = g.u64() & 0xFFFF_FFFF;
            let got = run_adder(32, &[(a, b)]);
            let full = a + b;
            assert_eq!(got[0].0, full & 0xFFFF_FFFF);
            assert_eq!(got[0].1, full >> 32 == 1);
        });
    }

    #[test]
    fn rows_are_independent() {
        // The same program across many rows computes many sums at once —
        // the row-parallel vector-add of §III-B.
        let pairs: Vec<(u64, u64)> = (0..64).map(|i| (i * 37 % 256, i * 91 % 256)).collect();
        let got = run_adder(8, &pairs);
        for (&(a, b), &(s, _)) in pairs.iter().zip(&got) {
            assert_eq!(s, (a + b) & 0xFF);
        }
    }

    #[test]
    fn cost_model() {
        let (prog, _) = ripple_adder(32);
        assert_eq!(prog.logic_gates_per_lane(), 6 * 32);
        // auto-init: one SET1 per logic gate + one SET0 for cin
        assert_eq!(prog.init_writes_per_lane(), 6 * 32 + 1);
        assert_eq!(prog.cycles(), 12 * 32 + 1);
    }
}
