//! Minimal hand-rolled HTTP/1.0 `GET /metrics` responder over std
//! TCP (`--metrics-addr` on `fabric-serve` and `fabric-route`) —
//! just enough HTTP for any standard Prometheus scraper or `curl`
//! to read the text exposition rendered by
//! [`crate::coordinator::render_prometheus`]. No external HTTP
//! stack exists in the offline vendor set, and none is needed: one
//! request per connection, response, close — the HTTP/1.0 model.
//!
//! This port is deliberately *outside* the PSK trust domain: the
//! exposition carries only aggregate counters (no request data), and
//! standard scrapers cannot speak the fabric's sealed framing. Bind
//! it to loopback or a scrape VLAN, exactly as you would any
//! `/metrics` port. Each connection is served on its own short-lived
//! thread under an overall [`CONN_DEADLINE`], so a trickling client
//! (one byte per read-timeout — the slowloris pattern) is cut off and
//! cannot starve a concurrent scraper; transient `accept` failures
//! back off and retry instead of silently killing the endpoint.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::server::{
    sleep_unless_stopped, transient_accept_error, ACCEPT_BACKOFF_MAX, ACCEPT_BACKOFF_START,
};

/// Longest request head we accept (a scrape GET is ~100 bytes).
const MAX_HEAD: usize = 8 * 1024;
/// Per-read socket timeout within a connection.
const CONN_TIMEOUT: Duration = Duration::from_secs(2);
/// Overall per-connection deadline: the whole request must be read
/// within this budget, however the client paces its bytes. Without it
/// a client trickling one byte per <[`CONN_TIMEOUT`] holds its
/// serving thread forever (and, before connections got their own
/// threads, monopolized the whole endpoint).
const CONN_DEADLINE: Duration = Duration::from_secs(5);

/// A running `/metrics` endpoint. Dropping it (or calling
/// [`MetricsHttp::shutdown`]) closes the listener and joins the
/// serving thread.
pub struct MetricsHttp {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsHttp {
    /// Bind `addr` (port 0 for ephemeral) and serve `GET /metrics`
    /// with the text `render` produces per scrape.
    pub fn serve<F>(addr: &str, render: F) -> Result<MetricsHttp>
    where
        F: Fn() -> String + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding /metrics endpoint to {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let render = Arc::new(render);
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                // One short-lived thread per connection (each bounded by
                // CONN_DEADLINE), so a slowloris trickler costs one
                // thread for a few seconds — never the accept loop, and
                // never a concurrent scraper's answer.
                let mut workers: Vec<JoinHandle<()>> = Vec::new();
                let mut backoff = ACCEPT_BACKOFF_START;
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            backoff = ACCEPT_BACKOFF_START;
                            let render = Arc::clone(&render);
                            workers.retain(|h| !h.is_finished());
                            workers.push(std::thread::spawn(move || {
                                let _ = serve_one(stream, &*render);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        // A reset mid-accept or a transient fd-limit
                        // squeeze must not kill the scrape endpoint:
                        // back off (bounded) and keep accepting.
                        Err(e) if transient_accept_error(&e) => {
                            eprintln!(
                                "metrics endpoint: transient accept error (retrying in \
                                 {backoff:?}): {e}"
                            );
                            sleep_unless_stopped(&stop2, backoff);
                            backoff = (backoff * 2).min(ACCEPT_BACKOFF_MAX);
                        }
                        Err(e) => {
                            eprintln!("metrics endpoint: FATAL: accept failed, stopping: {e}");
                            break;
                        }
                    }
                }
                for h in workers {
                    let _ = h.join();
                }
            })
            .expect("spawn metrics-http");
        Ok(MetricsHttp { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the serving thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Handle one connection: read the request head, answer, close. The
/// whole head must arrive within [`CONN_DEADLINE`]: every read timeout
/// is clamped to the time remaining, so a client pacing one byte per
/// read-timeout hits the overall deadline instead of extending it
/// indefinitely (the slowloris pattern).
fn serve_one<F: Fn() -> String>(mut stream: TcpStream, render: &F) -> std::io::Result<()> {
    let deadline = Instant::now() + CONN_DEADLINE;
    stream.set_write_timeout(Some(CONN_TIMEOUT))?;
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // Read until the blank line ending the request head (we ignore
    // headers and never read a body — scrape GETs have none).
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && !head.windows(2).any(|w| w == b"\n\n") {
        if head.len() > MAX_HEAD {
            return respond(&mut stream, "400 Bad Request", "request head too large\n");
        }
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return respond(&mut stream, "408 Request Timeout", "request too slow\n");
        }
        // set_read_timeout rejects a zero Duration; clamp up.
        stream.set_read_timeout(Some(remaining.min(CONN_TIMEOUT).max(Duration::from_millis(1))))?;
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(e) => return Err(e),
        }
    }
    let head = String::from_utf8_lossy(&head);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "only GET is served\n");
    }
    // Accept an optional query string; serve the one path we have.
    if path != "/metrics" && !path.starts_with("/metrics?") {
        return respond(&mut stream, "404 Not Found", "try /metrics\n");
    }
    let body = render();
    let header = format!(
        "HTTP/1.0 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn respond(stream: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    let reply = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(reply.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_rejects_other_paths() {
        let ep = MetricsHttp::serve("127.0.0.1:0", || "remus_test_metric 7\n".to_string())
            .unwrap();
        let addr = ep.local_addr();
        let ok = http_get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.ends_with("remus_test_metric 7\n"), "got: {ok}");
        let missing = http_get(addr, "/other");
        assert!(missing.starts_with("HTTP/1.0 404"), "got: {missing}");
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.0 405"), "got: {out}");
        ep.shutdown();
    }

    /// Regression: a slowloris client dribbling one byte at a time must
    /// neither starve a concurrent well-formed scrape nor hold its
    /// connection past [`CONN_DEADLINE`].
    #[test]
    fn slow_trickler_cannot_starve_concurrent_scrapes() {
        let ep = MetricsHttp::serve("127.0.0.1:0", || "remus_up 1\n".to_string()).unwrap();
        let addr = ep.local_addr();
        let trickler = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let started = Instant::now();
            // Dribble a request that never completes its head; the
            // endpoint must cut us off at the overall deadline.
            for b in b"GET /metrics HTTP/1.0\r\n".iter().cycle() {
                if stream.write_all(&[*b]).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
                assert!(
                    started.elapsed() < CONN_DEADLINE + Duration::from_secs(5),
                    "trickler connection was never cut off"
                );
            }
        });
        // While the trickler is mid-dribble, a normal scrape must be
        // answered promptly — not after the trickler's deadline.
        std::thread::sleep(Duration::from_millis(200));
        let started = Instant::now();
        let ok = http_get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "got: {ok}");
        assert!(
            started.elapsed() < CONN_TIMEOUT,
            "concurrent scrape starved for {:?}",
            started.elapsed()
        );
        trickler.join().unwrap();
        ep.shutdown();
    }
}
