//! Minimal hand-rolled HTTP/1.0 `GET /metrics` responder over std
//! TCP (`--metrics-addr` on `fabric-serve` and `fabric-route`) —
//! just enough HTTP for any standard Prometheus scraper or `curl`
//! to read the text exposition rendered by
//! [`crate::coordinator::render_prometheus`]. No external HTTP
//! stack exists in the offline vendor set, and none is needed: one
//! request per connection, response, close — the HTTP/1.0 model.
//!
//! This port is deliberately *outside* the PSK trust domain: the
//! exposition carries only aggregate counters (no request data), and
//! standard scrapers cannot speak the fabric's sealed framing. Bind
//! it to loopback or a scrape VLAN, exactly as you would any
//! `/metrics` port. Requests are served sequentially under a bounded
//! read timeout, so a stalled scraper delays — never wedges — the
//! endpoint.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

/// Longest request head we accept (a scrape GET is ~100 bytes).
const MAX_HEAD: usize = 8 * 1024;
/// Per-connection socket timeout: a trickling client is cut, not
/// served forever.
const CONN_TIMEOUT: Duration = Duration::from_secs(2);

/// A running `/metrics` endpoint. Dropping it (or calling
/// [`MetricsHttp::shutdown`]) closes the listener and joins the
/// serving thread.
pub struct MetricsHttp {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsHttp {
    /// Bind `addr` (port 0 for ephemeral) and serve `GET /metrics`
    /// with the text `render` produces per scrape.
    pub fn serve<F>(addr: &str, render: F) -> Result<MetricsHttp>
    where
        F: Fn() -> String + Send + 'static,
    {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding /metrics endpoint to {addr}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let _ = serve_one(stream, &render);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(e) => {
                            eprintln!("metrics endpoint: accept failed, stopping: {e}");
                            break;
                        }
                    }
                }
            })
            .expect("spawn metrics-http");
        Ok(MetricsHttp { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the serving thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Handle one connection: read the request head, answer, close.
fn serve_one<F: Fn() -> String>(mut stream: TcpStream, render: &F) -> std::io::Result<()> {
    stream.set_read_timeout(Some(CONN_TIMEOUT))?;
    stream.set_write_timeout(Some(CONN_TIMEOUT))?;
    let mut head = Vec::with_capacity(256);
    let mut buf = [0u8; 512];
    // Read until the blank line ending the request head (we ignore
    // headers and never read a body — scrape GETs have none).
    while !head.windows(4).any(|w| w == b"\r\n\r\n") && !head.windows(2).any(|w| w == b"\n\n") {
        if head.len() > MAX_HEAD {
            return respond(&mut stream, "400 Bad Request", "request head too large\n");
        }
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => head.extend_from_slice(&buf[..n]),
            Err(e) => return Err(e),
        }
    }
    let head = String::from_utf8_lossy(&head);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if method != "GET" {
        return respond(&mut stream, "405 Method Not Allowed", "only GET is served\n");
    }
    // Accept an optional query string; serve the one path we have.
    if path != "/metrics" && !path.starts_with("/metrics?") {
        return respond(&mut stream, "404 Not Found", "try /metrics\n");
    }
    let body = render();
    let header = format!(
        "HTTP/1.0 200 OK\r\n\
         Content-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\n\
         Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

fn respond(stream: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    let reply = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(reply.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(format!("GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").as_bytes())
            .unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_rejects_other_paths() {
        let ep = MetricsHttp::serve("127.0.0.1:0", || "remus_test_metric 7\n".to_string())
            .unwrap();
        let addr = ep.local_addr();
        let ok = http_get(addr, "/metrics");
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "got: {ok}");
        assert!(ok.contains("text/plain; version=0.0.4"));
        assert!(ok.ends_with("remus_test_metric 7\n"), "got: {ok}");
        let missing = http_get(addr, "/other");
        assert!(missing.starts_with("HTTP/1.0 404"), "got: {missing}");
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut out = String::new();
        stream.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.0 405"), "got: {out}");
        ep.shutdown();
    }
}
